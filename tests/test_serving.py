"""Serving engine: continuous batching must equal naive per-request
greedy decode, across prompt lengths and slot contention."""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.transformer import Transformer
from repro.serving.engine import Request, ServingEngine


def _naive(m, params, req, steps, max_len=128):
    cache = m.init_cache(1, max_len, dtype=jnp.float32)
    logits, cache, _ = m.apply(params, jnp.asarray(req.tokens)[None],
                               mode="prefill", cache=cache)
    gen = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(steps - 1):
        logits, cache, _ = m.apply(params, jnp.asarray([[gen[-1]]]),
                                   mode="decode", cache=cache)
        gen.append(int(jnp.argmax(logits[0, -1])))
    return gen


@pytest.mark.parametrize("arch", ["glm4-9b", "minicpm3-4b", "rwkv6-1.6b"])
def test_continuous_batching_matches_naive(arch):
    cfg = registry.get_smoke_config(arch).replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128,
                        cache_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(3, cfg.vocab_size,
                                        size=int(rng.integers(4, 30))),
                    max_new_tokens=5)
            for i in range(5)]
    outs = eng.run(copy.deepcopy(reqs))
    assert len(outs) == 5
    for r in outs:
        want = _naive(m, params, reqs[r.rid], 5)
        assert r.generated[:5] == want, r.rid


def test_engine_slot_reuse_and_metrics():
    cfg = registry.get_smoke_config("deepseek-7b").replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=64,
                        cache_dtype=jnp.float32)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, tokens=rng.integers(3, 100, size=8),
                    max_new_tokens=3) for i in range(3)]
    outs = eng.run(reqs)
    assert len(outs) == 3
    for r in outs:
        assert len(r.generated) == 3
        assert r.first_token_at is not None and r.finished_at is not None
        assert r.finished_at >= r.first_token_at >= r.submitted_at


def test_serve_step_factory_shapes():
    from repro.serving.engine import make_serve_step
    cfg = registry.get_smoke_config("olmoe-1b-7b").replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    cache = m.init_cache(2, 32, dtype=jnp.float32)
    # simulate a filled cache
    cache["pos"] = jnp.asarray([5, 9], jnp.int32)
    step = jax.jit(make_serve_step(cfg))
    nxt, new_cache = step(params, jnp.asarray([[4], [7]]), cache)
    assert nxt.shape == (2,)
    assert np.asarray(new_cache["pos"]).tolist() == [6, 10]
