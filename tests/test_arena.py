"""Grow-in-place MemoryStack arena: zero-restack ingest↔query.

Acceptance suite for the PR-4 tentpole invariant — with the
``MemoryArena`` (the ``SessionManager`` default), sessions allocate
their index / member / index_frame rows directly inside shared
``(S, capacity, …)`` device super-buffers, tick appends are donated
in-place writes, and the fused query path consumes the arena views
AS-IS: after warm-up, ``io_stats["stack_rebuilds"]`` must read 0 across
arbitrary interleavings of ingest ticks and query plans, while results
stay draw-for-draw identical to the per-session sequential path (and to
the ``use_arena=False`` detached/restack fallback, which must show ≥ 1
rebuild per round when sessions grow).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.memory import MemoryArena, MemoryStack, VenusMemory
from repro.core.queryplan import QuerySpec
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)

# max_partition_len forces ≥1 partition close per 64-frame chunk, so
# EVERY ingest tick grows every session — the adversarial schedule for
# a restacking stack cache
CFG = VenusConfig(max_partition_len=48)


def _worlds(n):
    return [VideoWorld(WorldConfig(n_scenes=4 + s, seed=20 + s))
            for s in range(n)]


def _manager(n_sessions, *, use_arena):
    mgr = SessionManager(CFG, PixelEmbedder(dim=64), embed_dim=64,
                         use_arena=use_arena)
    sids = [mgr.create_session() for _ in range(n_sessions)]
    return mgr, sids


def _tick(mgr, sids, worlds, t, chunk=64):
    # cycle through each world so any number of rounds keeps streaming
    # non-empty chunks (identical across the twin managers)
    def _chunk(w):
        lo = (t * chunk) % max(w.total_frames - chunk, 1)
        return w.frames[lo:lo + chunk]

    mgr.ingest_tick({sid: _chunk(w) for sid, w in zip(sids, worlds)})


def _round_queries(worlds, qsids, seed0):
    return np.stack([
        OracleEmbedder(worlds[s], dim=64).embed_queries(
            worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)])


def _assert_same_results(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        assert a.n_drawn == b.n_drawn


# ---------------------------------------------------------------------------
# acceptance: zero restacks across ≥5 interleaved ingest/query rounds
# ---------------------------------------------------------------------------


def test_zero_restacks_across_interleaved_rounds():
    """≥ 3 sessions, ≥ 5 interleaved ingest-tick/query-plan rounds:
    after warm-up the arena manager must report stack_rebuilds == 0
    while the fused results stay draw-for-draw identical to both the
    detached/restack manager and the fully sequential per-session query
    path; the detached manager must restack every round (its sessions
    grow every tick)."""
    worlds = _worlds(3)
    qsids = [0, 1, 1, 2]
    mgr_a, sids = _manager(3, use_arena=True)     # arena (default)
    mgr_d, _ = _manager(3, use_arena=False)       # detached / restack
    mgr_s, _ = _manager(3, use_arena=False)       # sequential baseline

    # --- warm-up: one ingest tick + one query round on each path
    for mgr in (mgr_a, mgr_d, mgr_s):
        _tick(mgr, sids, worlds, 0)
    qes = _round_queries(worlds, qsids, seed0=40)
    mgr_a.query_batch_cross(qsids, query_embs=qes)
    mgr_d.query_batch_cross(qsids, query_embs=qes)
    for s in sorted(set(qsids)):
        for j, q in enumerate(qsids):
            if q == s:
                mgr_s.query(s, "", query_emb=qes[j])

    mgr_a.reset_io_stats()
    mgr_d.reset_io_stats()

    # --- 5 rounds of (grow every session) → (query plan over all)
    rounds = 5
    for t in range(1, rounds + 1):
        for mgr in (mgr_a, mgr_d, mgr_s):
            _tick(mgr, sids, worlds, t)
        qes = _round_queries(worlds, qsids, seed0=50 + 7 * t)
        fused = mgr_a.query_batch_cross(qsids, query_embs=qes)
        detached = mgr_d.query_batch_cross(qsids, query_embs=qes)
        sequential = [None] * len(qsids)
        for s in sorted(set(qsids)):
            for j, q in enumerate(qsids):
                if q == s:
                    sequential[j] = mgr_s.query(s, "", query_emb=qes[j])
        _assert_same_results(fused, detached)
        _assert_same_results(fused, sequential)

    # the invariant: the arena NEVER restacked; the detached path had to
    # rebuild its device stacks every round because every session grew
    assert mgr_a.io_stats["stack_rebuilds"] == 0
    assert mgr_d.io_stats["stack_rebuilds"] >= rounds
    # and the fused accounting is unchanged: one fused scan per round
    assert mgr_a.io_stats["fused_scans"] == rounds
    assert mgr_a.io_stats["group_scans"] == rounds


def test_zero_restacks_mixed_strategy_plans():
    """Arbitrary strategy mixes (members / index / raw expansion) over
    the arena: every group consumes the arena views — still zero
    restacks, with one scan per group at the kops layer."""
    from repro.kernels import ops as kops

    worlds = _worlds(3)
    mgr, sids = _manager(3, use_arena=True)
    for t in range(2):
        _tick(mgr, sids, worlds, t)

    mix = ("akr", "topk", "uniform", "bolt", "sampling")

    def specs_for(seed0):
        qsids = [0, 1, 2, 0, 2]
        qes = _round_queries(worlds, qsids, seed0=seed0)
        return [QuerySpec(sid=s, embedding=qes[j], strategy=mix[j],
                          budget=4) for j, s in enumerate(qsids)]

    mgr.query_specs(specs_for(80))                # warm-up
    mgr.reset_io_stats()
    kops.reset_scan_counts()
    for t in range(3):
        _tick(mgr, sids, worlds, 2 + t)
        results = mgr.query_specs(specs_for(90 + 11 * t))
        assert all(r is not None for r in results)
    assert mgr.io_stats["stack_rebuilds"] == 0
    assert kops.scan_counts()["similarity_stack"] == 3 * len(mix)
    assert kops.scan_counts()["similarity"] == 0


# ---------------------------------------------------------------------------
# arena transfer accounting: appends only, zero uploads, zero rebuilds
# ---------------------------------------------------------------------------


def test_arena_appends_only_no_uploads():
    """Arena twin of the detached no-full-uploads regression test: the
    rows live in the arena from the start, so NOTHING is ever uploaded
    lazily (full_uploads == member_uploads == 0 forever) and post-ingest
    queries ride on donated appends alone."""
    worlds = _worlds(3)
    mgr, sids = _manager(3, use_arena=True)
    for t in range(2):
        _tick(mgr, sids, worlds, t)
    qes = _round_queries(worlds, sids, seed0=40)
    mgr.query_batch_cross(sids, query_embs=qes)

    for t in range(2, 5):
        _tick(mgr, sids, worlds, t)
        mgr.query_batch_cross(sids,
                              query_embs=_round_queries(worlds, sids,
                                                        seed0=50 + t))
    for s in sids:
        io = mgr[s].memory.io_stats
        assert io["full_uploads"] == 0
        assert io["member_uploads"] == 0
        assert io["index_frame_uploads"] == 0
        assert io["appended_rows"] > 0
    assert mgr.io_stats["stack_rebuilds"] == 0
    assert mgr.arena.io_stats["appends"] > 0
    assert mgr.arena.io_stats["appended_rows"] > 0


def test_arena_sizes_drive_valid_masks():
    """The per-session valid masks come from the arena sizes vector and
    track growth exactly."""
    mgr, sids = _manager(3, use_arena=True)
    rng = np.random.default_rng(0)
    arena = mgr.arena
    assert list(np.asarray(arena.sizes)) == [0, 0, 0]
    for k, (sid, n) in enumerate(zip(sids, (3, 0, 5))):
        if n:
            rows = rng.normal(0, 1, (n, 64)).astype(np.float32)
            mgr[sid].memory.insert_batch(
                rows, scene_ids=[0] * n, index_frames=list(range(n)),
                member_lists=[[i] for i in range(n)])
    np.testing.assert_array_equal(np.asarray(arena.sizes), [3, 0, 5])
    valid = np.asarray(arena.device_valid())
    assert valid.shape == (3, CFG.memory_capacity)
    np.testing.assert_array_equal(valid.sum(axis=1), [3, 0, 5])
    np.testing.assert_array_equal(np.asarray(arena.device_sizes()),
                                  [3, 0, 5])
    # arena rows == host mirrors, per session
    for sid in sids:
        m = mgr[sid].memory
        emb, v = m.device_index()
        np.testing.assert_array_equal(np.asarray(emb), m._emb)
        assert int(np.asarray(v).sum()) == m.size


def test_arena_grows_with_sessions():
    """Sessions created over time grow the arena (counted, warm-up-only
    copies); the stack view follows the new shape and queries against
    the grown arena still match a detached twin."""
    worlds = _worlds(3)
    mgr, _ = _manager(1, use_arena=True)
    mgr_d, _ = _manager(1, use_arena=False)
    sids = [0]
    _tick(mgr, sids, worlds, 0)
    _tick(mgr_d, sids, worlds, 0)
    assert mgr.arena.n_sessions == 1

    for k in (1, 2):                       # two more streams come online
        mgr.create_session()
        mgr_d.create_session()
        sids.append(k)
    assert mgr.arena.n_sessions == 3
    assert mgr.arena.io_stats["grows"] == 3
    _tick(mgr, sids, worlds, 1)
    _tick(mgr_d, sids, worlds, 1)

    qsids = [0, 1, 2, 2]
    qes = _round_queries(worlds, qsids, seed0=70)
    _assert_same_results(mgr.query_batch_cross(qsids, query_embs=qes),
                         mgr_d.query_batch_cross(qsids, query_embs=qes))


# ---------------------------------------------------------------------------
# MemoryStack over arena memories: coverage detection + subset fallback
# ---------------------------------------------------------------------------


def test_stack_arena_coverage_is_zero_copy():
    """A stack covering the whole arena in slot order returns the arena
    buffers themselves — no stack builds ever, views identical to the
    per-memory slices."""
    mgr, sids = _manager(3, use_arena=True)
    rng = np.random.default_rng(1)
    for sid, n in zip(sids, (4, 7, 2)):
        rows = rng.normal(0, 1, (n, 64)).astype(np.float32)
        mgr[sid].memory.insert_batch(
            rows, scene_ids=[0] * n, index_frames=list(range(n)),
            member_lists=[[i] for i in range(n)])
    stack = mgr.memory_stack(tuple(sids))
    assert stack.arena_view() is mgr.arena
    emb, valid = stack.device_stack()
    assert emb is mgr.arena.emb                     # the buffer, not a copy
    assert stack.io_stats["stack_builds"] == 0
    for k, sid in enumerate(sids):
        m = mgr[sid].memory
        np.testing.assert_array_equal(np.asarray(emb[k, :m.size]),
                                      m._emb[:m.size])
        assert int(np.asarray(valid[k]).sum()) == m.size


def test_stack_subset_of_arena_falls_back():
    """A stack over a strict subset of arena sessions cannot alias the
    super-buffers — it falls back to the detached jnp.stack path (and
    counts its rebuilds) while staying correct."""
    mgr, sids = _manager(3, use_arena=True)
    rng = np.random.default_rng(2)
    for sid, n in zip(sids, (5, 3, 6)):
        rows = rng.normal(0, 1, (n, 64)).astype(np.float32)
        mgr[sid].memory.insert_batch(
            rows, scene_ids=[0] * n, index_frames=list(range(n)),
            member_lists=[[i] for i in range(n)])
    rebuilds = {"stack_rebuilds": 0}
    stack = MemoryStack([mgr[sids[0]].memory, mgr[sids[2]].memory],
                        rebuild_stats=rebuilds)
    assert stack.arena_view() is None
    emb, valid = stack.device_stack()
    assert emb.shape[0] == 2
    assert rebuilds["stack_rebuilds"] == 1
    for k, sid in enumerate((sids[0], sids[2])):
        m = mgr[sid].memory
        np.testing.assert_array_equal(np.asarray(emb[k, :m.size]),
                                      m._emb[:m.size])
        assert int(np.asarray(valid[k]).sum()) == m.size


def test_stack_coverage_voided_by_new_session():
    """A session added AFTER a covering stack was built voids coverage:
    the old stack silently falls back to the detached view path with its
    original member list (correct shapes, stale-free data)."""
    mgr, sids = _manager(2, use_arena=True)
    rng = np.random.default_rng(3)
    for sid in sids:
        rows = rng.normal(0, 1, (3, 64)).astype(np.float32)
        mgr[sid].memory.insert_batch(
            rows, scene_ids=[0] * 3, index_frames=[0, 1, 2],
            member_lists=[[0], [1], [2]])
    stack = mgr.memory_stack(tuple(sids))
    assert stack.arena_view() is mgr.arena
    mgr.create_session()                            # arena grows to 3
    assert stack.arena_view() is None               # coverage voided
    emb, valid = stack.device_stack()
    assert emb.shape[0] == 2                        # original members
    np.testing.assert_array_equal(np.asarray(valid).sum(axis=1), [3, 3])


# ---------------------------------------------------------------------------
# detached fallback + arena plumbing invariants
# ---------------------------------------------------------------------------


def test_detached_memory_unchanged_by_default():
    """Standalone ``VenusMemory`` (no arena) keeps the PR-1 lazy-upload
    + in-place-append behaviour."""
    mem = VenusMemory(capacity=32, dim=8, member_cap=4)
    assert mem.arena is None
    rng = np.random.default_rng(0)
    rows = rng.normal(0, 1, (4, 8)).astype(np.float32)
    mem.insert_batch(rows, scene_ids=[0] * 4, index_frames=[0, 1, 2, 3],
                     member_lists=[[0], [1], [2], [3]])
    mem.search(jnp.asarray(rows[:1]), tau=0.1)
    assert mem.io_stats["full_uploads"] == 1


def test_arena_rejects_mismatched_memory_shapes():
    arena = MemoryArena(capacity=16, dim=8, member_cap=4)
    slot = arena.add_session()
    with pytest.raises(AssertionError):
        VenusMemory(capacity=32, dim=8, member_cap=4, arena=arena,
                    slot=slot)
    with pytest.raises(AssertionError):
        VenusMemory(capacity=16, dim=8, member_cap=4, arena=arena,
                    slot=0, incremental=False)


def test_service_io_stats_surface():
    """``VenusService.io_stats()`` aggregates manager + arena + memory
    counters under stable prefixes, with the zero-restack invariant
    visible at the service level."""
    from repro.serving.venus_service import VenusService

    worlds = _worlds(2)
    mgr, sids = _manager(2, use_arena=True)
    svc = VenusService(mgr, engine=None)
    for t in range(2):
        _tick(mgr, sids, worlds, t)
    mgr.query_batch_cross(sids, query_embs=_round_queries(worlds, sids,
                                                          seed0=40))
    stats = svc.io_stats()
    assert stats["stack_rebuilds"] == 0
    assert stats["arena_appends"] > 0
    assert stats["mem_appended_rows"] > 0
    assert stats["mem_full_uploads"] == 0


def test_arena_memory_search_matches_detached():
    """Per-session search over an arena row view == the same memory
    detached — the legacy single-session path is unaffected by where
    the buffers live."""
    rng = np.random.default_rng(5)
    arena = MemoryArena(capacity=32, dim=8, member_cap=4)
    m_a = VenusMemory(32, 8, 4, arena=arena, slot=arena.add_session())
    m_d = VenusMemory(32, 8, 4)
    rows = rng.normal(0, 1, (6, 8)).astype(np.float32)
    for m in (m_a, m_d):
        m.insert_batch(rows, scene_ids=[0] * 6,
                       index_frames=list(range(6)),
                       member_lists=[[i, i + 1] for i in range(6)])
    q = rng.normal(0, 1, (2, 8)).astype(np.float32)
    sa, pa = m_a.search(jnp.asarray(q), tau=0.1)
    sd, pd = m_d.search(jnp.asarray(q), tau=0.1)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sd),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pa), np.asarray(pd),
                               rtol=1e-6, atol=1e-6)
    # device expansion rides the arena rows too
    draws = np.asarray([0, 2, 5, -1])
    valid = np.asarray([True, True, True, True])
    np.testing.assert_array_equal(
        m_a.expand_draws_device(draws, valid, seed=3),
        m_d.expand_draws_device(draws, valid, seed=3))
