"""The cross-run bench ``trajectory`` merge (ISSUE 9 satellite).

Root cause of the perpetually length-1 trajectory: the artifact is
gitignored and ``actions/upload-artifact`` never lands files back in
the NEXT run's workspace, so in CI the bench's re-read-before-rewrite
always found nothing. Two pins here:

* ``write_json_artifact`` APPENDS to a pre-seeded artifact's
  trajectory (and starts fresh on a missing/corrupt one) — the merge
  logic itself,
* ``ci.yml`` actually restores the previous artifact before the bench
  runs (``actions/cache/restore``) and saves it after — without that
  step the merge logic never sees history, which was the bug.
"""

import json

import pytest

bench = pytest.importorskip(
    "benchmarks.bench_multistream",
    reason="bench module needs the repo root on sys.path")

ROWS = [{"name": "multistream/spill", "seconds": 1.25,
         "derived": {"sessions": 4}},
        {"name": "multistream/churn", "seconds": 0.5, "derived": {}}]
META = {"bench": "multistream", "sessions": 4, "queries": 8,
        "smoke": True, "parts": ["spill", "churn"],
        "index_dtype": "int8", "timestamp": 1000.0}


def test_trajectory_appends_to_preseeded_artifact(tmp_path):
    path = tmp_path / "BENCH_multistream.json"
    previous = [
        {"timestamp": 1.0, "parts": ["cross"], "smoke": True,
         "rows": {"multistream/cross": 0.111}},
        {"timestamp": 2.0, "parts": ["arena"], "smoke": False,
         "rows": {"multistream/arena": 0.222}}]
    path.write_text(json.dumps(
        {"meta": {"timestamp": 2.0}, "benchmarks": [],
         "trajectory": previous}))
    payload = bench.write_json_artifact(str(path), ROWS, dict(META))
    assert len(payload["trajectory"]) == 3
    # the pre-seeded history survives VERBATIM, in order
    assert payload["trajectory"][:2] == previous
    newest = payload["trajectory"][-1]
    assert newest["timestamp"] == 1000.0
    assert newest["parts"] == ["spill", "churn"]
    assert newest["rows"] == {"multistream/spill": 1.25,
                              "multistream/churn": 0.5}
    # this run's full rows replace the previous run's (only the
    # trajectory accumulates)
    assert payload["benchmarks"] == ROWS
    # and what's on disk is what was returned
    assert json.loads(path.read_text()) == payload


def test_trajectory_fresh_on_missing_or_corrupt(tmp_path):
    # no previous artifact -> trajectory starts at length 1
    path = tmp_path / "fresh.json"
    payload = bench.write_json_artifact(str(path), ROWS, dict(META))
    assert len(payload["trajectory"]) == 1
    # corrupt previous artifact -> same, not a crash
    bad = tmp_path / "corrupt.json"
    bad.write_text("{not json")
    payload = bench.write_json_artifact(str(bad), ROWS, dict(META))
    assert len(payload["trajectory"]) == 1


def test_trajectory_accumulates_run_over_run(tmp_path):
    path = tmp_path / "BENCH_multistream.json"
    for n in range(1, 4):
        payload = bench.write_json_artifact(str(path), ROWS, dict(META))
        assert len(payload["trajectory"]) == n
