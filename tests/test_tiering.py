"""Hierarchical two-level memory: coarse tier + two-stage retrieval.

Acceptance suite for the consolidation-tier subsystem
(``repro.core.tiering`` + the arena/session/queryplan integration):

* geometry + population: ``coarse_rows_for`` row layout, block
  summaries recomputed for dirty blocks, ``ConsolidationEviction``
  folding evictees into running-centroid summary rows (threshold fold /
  fresh row / full-tier degrade), recycled slots resetting the tier;
* equivalence: before the first consolidation — and always under the
  ``coarse=False`` escape hatch — the flat scan runs UNCHANGED, so a
  tiered build answers draw-for-draw like a coarse-less one;
* the bandwidth claim: with consolidation enabled, per-query scanned
  bytes (coarse scan + gathered fine candidates) stay BELOW the flat
  1×-capacity scan while ≥ 4× capacity of ingested history keeps
  top-k recall ≥ 0.8 vs an unbounded-capacity oracle — pinned by the
  ``kops`` counters, not by timing.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.memory import (ConsolidationEviction, MemoryArena,
                               VenusMemory, coarse_rows_for,
                               get_eviction_policy)
from repro.core.queryplan import QuerySpec
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import OracleEmbedder, PixelEmbedder, VideoWorld, \
    WorldConfig
from repro.kernels import ops as kops

DIM = 32


# small geometry so a few hundred direct inserts cover 4× capacity:
# n_blocks = 128/16 = 8, n_coarse = 8 + 32 = 40; a two-stage query
# streams 40 + topb·16 = 104 rows vs the flat scan's 128+
TIER_CFG = VenusConfig(memory_capacity=128, member_cap=8,
                       eviction="consolidate", coarse_capacity=32,
                       coarse_block=16, coarse_topb=4)


def _unit(rows):
    rows = np.asarray(rows, np.float32)
    return rows / (np.linalg.norm(rows, axis=-1, keepdims=True) + 1e-12)


class ArrayEmbedder:
    """Planner stub for managers fed by direct ``insert_batch`` calls."""

    def embed_queries(self, texts):
        raise AssertionError("tests pass explicit embeddings")

    def embed_frames(self, frames, aux=None, frame_ids=None):
        raise AssertionError("tests insert rows directly")


def _clustered_rows(rng, centroids, labels, noise=0.05):
    rows = centroids[labels] + noise * rng.normal(
        size=(len(labels), centroids.shape[1]))
    return _unit(rows)


def _direct_manager(cfg, **kw):
    return SessionManager(cfg, ArrayEmbedder(), embed_dim=DIM, **kw)


def _feed(mgr, sid, rows, fid0, chunk=16):
    """Insert rows straight into the session's memory, riding the same
    deferred arena scatter an ingest tick uses."""
    mem = mgr.sessions[sid].memory
    for lo in range(0, len(rows), chunk):
        batch = rows[lo:lo + chunk]
        fids = np.arange(fid0 + lo, fid0 + lo + len(batch))
        with mgr.arena.deferred_appends():
            mem.insert_batch(batch, scene_ids=[0] * len(batch),
                             index_frames=fids,
                             member_lists=[[int(f)] for f in fids])
    return fid0 + len(rows)


# ---------------------------------------------------------------------------
# geometry + population
# ---------------------------------------------------------------------------


def test_coarse_rows_layout():
    assert coarse_rows_for(128, 32, 16) == (8, 40)
    assert coarse_rows_for(100, 4, 16) == (7, 11)      # ragged last block
    assert coarse_rows_for(128, 0, 16) == (0, 0)       # disabled
    a = MemoryArena(128, DIM, 8, coarse_capacity=32, coarse_block=16)
    a.add_session()
    assert (a.n_blocks, a.n_coarse) == (8, 40)
    assert a.coarse_emb.shape == (1, 40, DIM)
    assert a.coarse_members.shape == (1, 40, 8)
    assert not a.has_consolidated()
    flat = MemoryArena(128, DIM, 8)
    flat.add_session()
    assert flat.n_coarse == 0 and flat.coarse_emb is None
    assert not flat.has_consolidated()


def test_block_summaries_track_live_rows():
    """Ingest marks blocks dirty; their summary rows become the valid
    centroid of the block's live rows (no reservoir), and eviction
    re-summarises the blocks it invalidated."""
    rng = np.random.default_rng(0)
    mgr = _direct_manager(TIER_CFG)
    sid = mgr.create_session()
    mem, a = mgr.sessions[sid].memory, mgr.arena
    rows = _unit(rng.normal(size=(24, DIM)))
    _feed(mgr, sid, rows, 0)
    # blocks 0 (full) and 1 (8/16 rows) valid, the rest not
    cv = a.coarse_valid[mem.slot]
    np.testing.assert_array_equal(cv[:a.n_blocks],
                                  [True, True] + [False] * 6)
    assert not cv[a.n_blocks:].any()           # nothing consolidated yet
    got = np.asarray(a.coarse_emb[mem.slot, 0])
    np.testing.assert_allclose(got, rows[:16].mean(0), atol=1e-5)
    got1 = np.asarray(a.coarse_emb[mem.slot, 1])
    np.testing.assert_allclose(got1, rows[16:24].mean(0), atol=1e-5)
    # block summaries carry no reservoir
    assert int(np.asarray(a.coarse_member_count[mem.slot, 0])) == 0


def test_consolidation_fold_rules():
    """Similar evictees fold into one running centroid + merged
    reservoir; dissimilar ones open fresh rows; a full region folds
    into the nearest row unconditionally instead of losing data."""
    cap, cc = 4, 2
    mem = VenusMemory(cap, DIM, member_cap=8,
                      eviction=ConsolidationEviction(threshold=0.9),
                      coarse_capacity=cc, coarse_block=4)
    e = np.eye(DIM, dtype=np.float32)
    rows = np.stack([e[0], e[0], e[1], e[2]])
    mem.insert_batch(rows, scene_ids=[0] * 4,
                     index_frames=[10, 11, 12, 13],
                     member_lists=[[10, 100], [11], [12], [13]])
    # evict rows 10+11 (both e0): first opens a summary, second folds
    mem.insert_batch(np.stack([e[3], e[4]]), scene_ids=[1] * 2,
                     index_frames=[14, 15], member_lists=[[14], [15]])
    assert mem.io_stats["consolidated_rows"] == 2
    assert mem._coarse_csize == 1
    assert int(mem._coarse_weight[0]) == 2
    np.testing.assert_allclose(mem._coarse_emb[0], e[0], atol=1e-6)
    got = set(mem._coarse_members[0, :mem._coarse_count[0]].tolist())
    assert got == {10, 100, 11}
    assert (int(mem._coarse_fid_lo[0]), int(mem._coarse_fid_hi[0])) \
        == (10, 100)
    # dissimilar evictee (e1) opens row 1; the NEXT dissimilar one (e2)
    # finds the region full and folds into its nearest row anyway
    mem.insert_batch(np.stack([e[5], e[6]]), scene_ids=[2] * 2,
                     index_frames=[16, 17], member_lists=[[16], [17]])
    assert mem._coarse_csize == 2
    assert mem.io_stats["consolidated_rows"] == 4
    assert int(mem._coarse_weight[0]) + int(mem._coarse_weight[1]) == 4
    # frame-window metadata keeps every folded frame ≥ its fid_lo
    assert mem.min_live_frame() <= 10


def test_consolidate_requires_coarse_capacity():
    mem = VenusMemory(4, DIM, member_cap=4, eviction="consolidate")
    rows = _unit(np.random.default_rng(1).normal(size=(4, DIM)))
    mem.insert_batch(rows, scene_ids=[0] * 4, index_frames=[0, 1, 2, 3],
                     member_lists=[[0], [1], [2], [3]])
    with pytest.raises(RuntimeError, match="coarse_capacity"):
        mem.insert_batch(rows[:1], scene_ids=[1], index_frames=[4],
                         member_lists=[[4]])


def test_merge_threshold_config_and_validation():
    """Satellite: the fold threshold is a first-class config knob,
    validated in ``get_eviction_policy``."""
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="threshold"):
            get_eviction_policy("cluster_merge", threshold=bad)
    assert get_eviction_policy("cluster_merge", threshold=0.5) \
        .threshold == 0.5
    assert get_eviction_policy("consolidate", threshold=1.0) \
        .threshold == 1.0
    assert get_eviction_policy("cluster_merge").threshold == 0.8
    # instances pass through; thresholds still validate
    pol = ConsolidationEviction(threshold=0.7)
    assert get_eviction_policy(pol) is pol
    with pytest.raises(ValueError):
        get_eviction_policy(pol, threshold=2.0)
    # threaded from VenusConfig into the session's policy
    cfg = VenusConfig(memory_capacity=32, eviction="cluster_merge",
                      merge_threshold=0.6)
    mgr = SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64)
    sid = mgr.create_session()
    assert mgr.sessions[sid].memory.eviction.threshold == 0.6


# ---------------------------------------------------------------------------
# equivalence: empty tier / escape hatch == the flat scan
# ---------------------------------------------------------------------------


def _drive(mgr, sid, world, ticks):
    chunk = 64
    for t in range(ticks):
        lo = (t * chunk) % max(world.total_frames - chunk, 1)
        mgr.ingest_tick({sid: world.frames[lo:lo + chunk]})


def test_flat_path_bit_identical_before_consolidation():
    """A tiered manager whose tier holds no consolidated rows answers
    draw-for-draw like a coarse-less build — the two-stage path must
    not even engage."""
    world = VideoWorld(WorldConfig(n_scenes=5, seed=21))
    cfg_tier = VenusConfig(max_partition_len=48,
                           eviction="consolidate", coarse_capacity=32,
                           coarse_block=64)
    cfg_flat = VenusConfig(max_partition_len=48)
    mt = SessionManager(cfg_tier, PixelEmbedder(dim=64), embed_dim=64)
    mf = SessionManager(cfg_flat, PixelEmbedder(dim=64), embed_dim=64)
    st, sf = mt.create_session(), mf.create_session()
    _drive(mt, st, world, 2)          # well under capacity: no eviction
    _drive(mf, sf, world, 2)
    assert not mt.arena.has_consolidated()
    qes = OracleEmbedder(world, dim=64).embed_queries(
        world.make_queries(3, seed=5))
    kops.reset_scan_counts()
    for strat in ("topk", "sampling", "akr"):
        specs_t = [QuerySpec(sid=st, embedding=q, strategy=strat,
                             budget=8) for q in qes]
        specs_f = [QuerySpec(sid=sf, embedding=q, strategy=strat,
                             budget=8) for q in qes]
        got = mt.execute(mt.plan(specs_t))
        want = mf.execute(mf.plan(specs_f))
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a.draws, b.draws)
            np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
            assert a.n_drawn == b.n_drawn
    sc = kops.scan_counts()
    assert sc["two_stage_scans"] == 0
    assert sc["coarse_scan_bytes"] == 0
    assert mt.io_stats["two_stage_groups"] == 0


def test_coarse_false_matches_sliding_window_twin():
    """With consolidated rows present, ``coarse=False`` still takes the
    flat scan — and because ``consolidate`` moves the fine window
    exactly like ``sliding_window``, it answers draw-for-draw like a
    sliding-window twin fed the same stream."""
    rng = np.random.default_rng(3)
    cen = _unit(rng.normal(size=(8, DIM)))
    labels = rng.integers(0, 8, size=4 * TIER_CFG.memory_capacity)
    rows = _clustered_rows(rng, cen, labels)

    win_cfg = VenusConfig(memory_capacity=TIER_CFG.memory_capacity,
                          member_cap=TIER_CFG.member_cap,
                          eviction="sliding_window")
    mt, mw = _direct_manager(TIER_CFG), _direct_manager(win_cfg)
    st, sw = mt.create_session(), mw.create_session()
    _feed(mt, st, rows, 0)
    _feed(mw, sw, rows, 0)
    assert mt.arena.has_consolidated()
    for j in range(4):
        spec_t = QuerySpec(sid=st, embedding=cen[j], strategy="topk",
                           budget=8, seed=7)
        spec_w = QuerySpec(sid=sw, embedding=cen[j], strategy="topk",
                           budget=8, seed=7)
        got = mt.execute(mt.plan([spec_t]), coarse=False)[0]
        want = mw.execute(mw.plan([spec_w]))[0]
        np.testing.assert_array_equal(got.draws, want.draws)
        np.testing.assert_array_equal(got.frame_ids, want.frame_ids)
    assert mt.io_stats["two_stage_groups"] == 0


# ---------------------------------------------------------------------------
# ACCEPTANCE: bandwidth pinned by counters, recall vs unbounded oracle
# ---------------------------------------------------------------------------


def test_two_stage_scans_fewer_bytes_than_flat():
    """The kops pin: with the tier populated, one query's coarse scan +
    gathered fine candidates stream fewer bytes than ONE flat
    1×-capacity scan, and both stages are counted."""
    rng = np.random.default_rng(5)
    cen = _unit(rng.normal(size=(8, DIM)))
    labels = rng.integers(0, 8, size=4 * TIER_CFG.memory_capacity)
    rows = _clustered_rows(rng, cen, labels)
    mgr = _direct_manager(TIER_CFG)
    sid = mgr.create_session()
    _feed(mgr, sid, rows, 0)
    a = mgr.arena
    assert a.has_consolidated()

    spec = QuerySpec(sid=sid, embedding=cen[0], strategy="topk",
                     budget=8)
    # flat baseline: one 1×-capacity scan
    kops.reset_scan_counts()
    mgr.execute(mgr.plan([spec]), coarse=False)
    flat_bytes = kops.scan_counts()["scan_bytes"]
    assert kops.scan_counts()["two_stage_scans"] == 0

    kops.reset_scan_counts()
    mgr.execute(mgr.plan([spec]))
    sc = kops.scan_counts()
    assert sc["two_stage_scans"] == 1
    assert mgr.io_stats["two_stage_groups"] == 1
    assert sc["coarse_scan_bytes"] > 0
    assert sc["fine_gather_rows"] == TIER_CFG.coarse_topb * \
        TIER_CFG.coarse_block
    itemsize = 4          # both tiers scan f32 here
    gathered_bytes = sc["fine_gather_rows"] * DIM * itemsize
    assert sc["coarse_scan_bytes"] + gathered_bytes < flat_bytes
    # effective capacity ≫ scanned rows: 4× capacity of history is
    # reachable while the scan streamed n_coarse + B·block rows
    scanned_rows = a.n_coarse + sc["fine_gather_rows"]
    assert scanned_rows < TIER_CFG.memory_capacity
    assert len(rows) == 4 * TIER_CFG.memory_capacity
    # and nothing restacked
    assert mgr.io_stats["stack_rebuilds"] == 0


def test_recall_vs_unbounded_oracle():
    """ACCEPTANCE: ≥ 4× capacity ingested, top-k recall ≥ 0.8 vs an
    unbounded-capacity oracle. Recall is measured on cluster identity:
    the fraction of returned frames belonging to the query's cluster
    (the oracle scores 1.0 by construction on this workload)."""
    rng = np.random.default_rng(11)
    n_clusters = 8
    cen = _unit(rng.normal(size=(n_clusters, DIM)))
    total = 4 * TIER_CFG.memory_capacity
    labels = rng.integers(0, n_clusters, size=total)
    rows = _clustered_rows(rng, cen, labels)

    mgr = _direct_manager(TIER_CFG)
    sid = mgr.create_session()
    _feed(mgr, sid, rows, 0)
    assert mgr.arena.has_consolidated()

    oracle_cfg = VenusConfig(memory_capacity=total, member_cap=8)
    om = _direct_manager(oracle_cfg)
    osid = om.create_session()
    _feed(om, osid, rows, 0)

    k = 8
    recalls, oracle_recalls = [], []
    for q in range(n_clusters):
        got = mgr.execute(mgr.plan([QuerySpec(
            sid=sid, embedding=cen[q], strategy="topk", budget=k)]))[0]
        want = om.execute(om.plan([QuerySpec(
            sid=osid, embedding=cen[q], strategy="topk", budget=k)]))[0]
        assert len(got.frame_ids) > 0
        recalls.append(np.mean(labels[got.frame_ids] == q))
        oracle_recalls.append(np.mean(labels[want.frame_ids] == q))
    assert np.mean(oracle_recalls) == 1.0      # workload sanity
    assert np.mean(recalls) >= 0.8, recalls
    # the two-stage path reaches frames the fine window evicted long ago
    assert mgr.io_stats["two_stage_groups"] == n_clusters


def test_sampling_akr_reach_consolidated_reservoirs():
    """Stochastic strategies expand through the CANDIDATE tables: draws
    landing on a consolidated summary return frames from its merged
    reservoir — history the fine window no longer holds."""
    rng = np.random.default_rng(13)
    cen = _unit(rng.normal(size=(4, DIM)))
    total = 4 * TIER_CFG.memory_capacity
    labels = rng.integers(0, 4, size=total)
    rows = _clustered_rows(rng, cen, labels)
    mgr = _direct_manager(TIER_CFG)
    sid = mgr.create_session()
    _feed(mgr, sid, rows, 0)
    evicted_horizon = total - TIER_CFG.memory_capacity
    reached_old = False
    for strat in ("sampling", "akr"):
        for j in range(4):
            res = mgr.execute(mgr.plan([QuerySpec(
                sid=sid, embedding=cen[j], strategy=strat,
                budget=16)]))[0]
            assert res.frame_ids.size > 0
            assert res.frame_ids.max() < total
            if res.frame_ids.min() < evicted_horizon:
                reached_old = True
    assert reached_old, "no draw ever reached consolidated history"


# ---------------------------------------------------------------------------
# lifecycle: recycled slots reset the tier
# ---------------------------------------------------------------------------


def test_recycled_slot_resets_coarse_tier():
    """close → create on the same slot: the new tenant must not see the
    old tenant's summary rows (validity cleared, buffers zeroed)."""
    rng = np.random.default_rng(17)
    cen = _unit(rng.normal(size=(4, DIM)))
    rows = _clustered_rows(
        rng, cen, rng.integers(0, 4, size=2 * TIER_CFG.memory_capacity))
    mgr = _direct_manager(TIER_CFG)
    sid = mgr.create_session()
    _feed(mgr, sid, rows, 0)
    a = mgr.arena
    slot = mgr.sessions[sid].memory.slot
    assert a.coarse_valid[slot].any()
    mgr.close_session(sid)
    assert not a.coarse_valid[slot].any()
    sid2 = mgr.create_session()
    assert mgr.sessions[sid2].memory.slot == slot    # recycled
    np.testing.assert_array_equal(np.asarray(a.coarse_emb[slot]), 0.0)
    assert not a.has_consolidated()
    mem2 = mgr.sessions[sid2].memory
    assert mem2._coarse_csize == 0
    # the recycled tenant consolidates from scratch and answers
    _feed(mgr, sid2, rows, 0)
    assert a.has_consolidated()
    res = mgr.execute(mgr.plan([QuerySpec(
        sid=sid2, embedding=cen[0], strategy="topk", budget=4)]))[0]
    assert res.frame_ids.size > 0
