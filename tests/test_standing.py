"""Standing queries: the inverted ask-then-scan loop
(``repro.core.standing`` + the commit_jobs/session/service wiring).

The headline contract is DIFFERENTIAL: a standing evaluation over a
tick's newly committed rows must be bitwise what an ad-hoc top-k
``QuerySpec`` produces against a fresh manager holding exactly those
rows — same frame ids in the same rank order, same top score — under
fp32 and the int8 quantised index, on flat and consolidated sessions,
across a ring-wrap, for S=1 and mixed-session ticks. On top of that:

* trigger semantics: threshold crossing fires once per excursion
  (two-sided hysteresis re-arm band), ``cooldown_ticks`` debounces
  re-fires, suppressed crossings are counted;
* delivery: ``poll_alerts`` is priority-ordered, callbacks observe the
  stream, alerts survive ``close_session``/slot-recycle without the
  recycled slot ghost-firing the old tenant's specs;
* the bandwidth claim: ``kops standing_scan_bytes`` is the padded-slab
  bytes — O(new_rows · d) per tick, never the arena capacity — with
  ``stack_rebuilds == 0``, on unsharded AND mesh-sharded managers.
"""

import jax
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.queryplan import QuerySpec
from repro.core.session import SessionManager, VenusConfig
from repro.core.standing import _pow2
from repro.data.video import PixelEmbedder
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh
from repro.serving.venus_service import VenusService

DIM = 32

FLAT = VenusConfig(memory_capacity=128, member_cap=8)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count")


def _unit(rows):
    rows = np.asarray(rows, np.float32)
    return rows / (np.linalg.norm(rows, axis=-1, keepdims=True) + 1e-12)


class ArrayEmbedder:
    """Planner stub for managers fed by direct ``insert_batch`` calls."""

    def embed_queries(self, texts):
        raise AssertionError("tests pass explicit embeddings")

    def embed_frames(self, frames, aux=None, frame_ids=None):
        raise AssertionError("tests insert rows directly")


def _direct_manager(cfg, **kw):
    return SessionManager(cfg, ArrayEmbedder(), embed_dim=DIM, **kw)


def _insert(mgr, sid, rows, fid0):
    """Insert rows straight into the session's memory (same deferred
    arena scatter an ingest tick uses); returns the physical slots."""
    mem = mgr.sessions[sid].memory
    fids = np.arange(fid0, fid0 + len(rows))
    with mgr.arena.deferred_appends():
        phys = mem.insert_batch(rows, scene_ids=[0] * len(rows),
                                index_frames=fids,
                                member_lists=[[int(f)] for f in fids])
    return np.asarray(phys)


def _rows_with_sims(rng, emb, sims):
    """Unit rows whose cosine similarity to ``emb`` is each of ``sims``
    (constructed in the plane spanned by emb and a random orthogonal
    direction, so the similarity is exact up to fp rounding)."""
    out = []
    for s in sims:
        r = rng.normal(size=emb.shape)
        u = r - (r @ emb) * emb
        u /= np.linalg.norm(u)
        out.append(s * emb + np.sqrt(max(1.0 - s * s, 0.0)) * u)
    return _unit(out)


def _twin_topk_ids(rows, fids, emb, budget, index_dtype="float32"):
    """The ad-hoc oracle: a FRESH flat manager holding exactly ``rows``
    answers a top-k plan — rank-ordered frame ids over the same rows
    the standing evaluation saw."""
    cfg = VenusConfig(memory_capacity=max(128, _pow2(len(rows))),
                      member_cap=8, index_dtype=index_dtype)
    mgr = _direct_manager(cfg)
    sid = mgr.create_session()
    mem = mgr.sessions[sid].memory
    with mgr.arena.deferred_appends():
        mem.insert_batch(rows, scene_ids=[0] * len(rows),
                         index_frames=np.asarray(fids),
                         member_lists=[[int(f)] for f in fids])
    res = mgr.query_specs([QuerySpec(sid=sid, embedding=emb,
                                     strategy="topk", budget=budget)])[0]
    return np.asarray(res.frame_ids)


def _evaluate(mgr, sid_phys):
    """Run one standing evaluation tick over the given {sid: phys}."""
    return mgr.standing.evaluate(
        mgr.sessions, {sid: [phys] for sid, phys in sid_phys.items()},
        mgr.io_stats)


# ---------------------------------------------------------------------------
# differential bit-identity: standing == ad-hoc top-k over the same rows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("index_dtype", ["float32", "int8"])
def test_differential_flat(index_dtype):
    """S=1 flat session: the alert's frame ids are EXACTLY the ad-hoc
    top-k plan's ids over the same rows, rank order included — under
    fp32 and the int8 quantised index (the slab quantises per-row,
    bitwise the arena's own rows)."""
    rng = np.random.default_rng(0)
    cfg = VenusConfig(memory_capacity=128, member_cap=8,
                      index_dtype=index_dtype)
    mgr = _direct_manager(cfg)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    rows = _rows_with_sims(rng, emb,
                           [0.2, 0.9, 0.4, 0.95, 0.1, 0.7, 0.3, 0.85,
                            0.5, 0.6])
    spec_id = mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=4),
        threshold=-1.0)
    phys = _insert(mgr, sid, rows, 100)
    fired = _evaluate(mgr, {sid: phys})
    assert len(fired) == 1 and fired[0].spec_id == spec_id
    want = _twin_topk_ids(rows, np.arange(100, 110), emb, 4,
                          index_dtype=index_dtype)
    np.testing.assert_array_equal(fired[0].frame_ids, want)


def test_differential_score_bitwise_vs_direct_kernel():
    """The alert's score is BITWISE a direct ``fused_retrieve_stack``
    launch over an independently reconstructed slab of the same rows
    (same pow2 padding) — no epsilon."""
    rng = np.random.default_rng(1)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    rows = _rows_with_sims(rng, emb, [0.3, 0.8, 0.55, 0.72, 0.15])
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=3),
        threshold=-1.0)
    phys = _insert(mgr, sid, rows, 0)
    fired = _evaluate(mgr, {sid: phys})
    n_pad = _pow2(len(rows))
    slab = np.zeros((1, n_pad, DIM), np.float32)
    slab[0, :len(rows)] = rows
    fr = kops.fused_retrieve_stack(
        jnp.asarray(emb[None, None, :]), jnp.asarray(slab),
        tau=FLAT.tau, valid=jnp.asarray([len(rows)], np.int32),
        targets=jnp.zeros((1, 1, 1), jnp.float32), n_topk=3)
    assert fired[0].score == float(np.asarray(fr.topk_v)[0, 0, 0])


@pytest.mark.parametrize("index_dtype", ["float32", "int8"])
def test_differential_consolidated(index_dtype):
    """A consolidated session changes NOTHING for standing evaluation:
    the slab gathers only the tick's new fine rows, so the alert still
    matches a flat twin holding just those rows."""
    rng = np.random.default_rng(2)
    cfg = VenusConfig(memory_capacity=128, member_cap=8,
                      eviction="consolidate", coarse_capacity=32,
                      coarse_block=16, coarse_topb=4,
                      index_dtype=index_dtype)
    mgr = _direct_manager(cfg)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    fid = 0
    for _ in range(5):                         # 160 rows > capacity 128
        _insert(mgr, sid, _unit(rng.normal(size=(32, DIM))), fid)
        fid += 32
    assert mgr.arena.has_consolidated()
    rows = _rows_with_sims(rng, emb,
                           [0.1, 0.88, 0.4, 0.93, 0.2, 0.66])
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=3),
        threshold=-1.0)
    phys = _insert(mgr, sid, rows, fid)
    fired = _evaluate(mgr, {sid: phys})
    want = _twin_topk_ids(rows, np.arange(fid, fid + len(rows)), emb, 3,
                          index_dtype=index_dtype)
    np.testing.assert_array_equal(fired[0].frame_ids, want)


def test_differential_ring_wrap():
    """New rows whose physical slots wrap the ring boundary gather
    correctly (physical addressing makes wrap a non-event): alert ids
    still match the flat twin over the same rows in commit order."""
    rng = np.random.default_rng(3)
    cfg = VenusConfig(memory_capacity=32, member_cap=8,
                      eviction="sliding_window")
    mgr = _direct_manager(cfg)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    _insert(mgr, sid, _unit(rng.normal(size=(28, DIM))), 0)
    rows = _rows_with_sims(rng, emb,
                           [0.3, 0.9, 0.5, 0.8, 0.2, 0.7, 0.6, 0.4])
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=4),
        threshold=-1.0)
    phys = _insert(mgr, sid, rows, 28)
    assert phys.max() > phys.min() and (np.diff(phys) < 0).any(), \
        "test must actually cross the ring boundary"
    fired = _evaluate(mgr, {sid: phys})
    want = _twin_topk_ids(rows, np.arange(28, 36), emb, 4)
    np.testing.assert_array_equal(fired[0].frame_ids, want)


def test_differential_mixed_session_tick():
    """One tick committing rows to three sessions — two with standing
    specs (of DIFFERENT budgets, batched into one launch at the max k;
    lax.top_k prefix-stability makes the smaller budget's ids identical
    to its own ad-hoc plan), one without. Each alert matches its own
    flat twin; the spec-less session contributes nothing."""
    rng = np.random.default_rng(4)
    mgr = _direct_manager(FLAT)
    sids = [mgr.create_session() for _ in range(3)]
    embs = [_unit(rng.normal(size=(1, DIM)))[0] for _ in range(3)]
    rows_a = _rows_with_sims(rng, embs[0], [0.4, 0.9, 0.1, 0.7, 0.55])
    rows_b = _rows_with_sims(
        rng, embs[1], [0.2, 0.85, 0.6, 0.95, 0.3, 0.5, 0.75, 0.1, 0.45])
    rows_c = _unit(rng.normal(size=(4, DIM)))
    ids = {
        "a3": mgr.register_standing(
            sids[0], QuerySpec(sid=sids[0], embedding=embs[0],
                               strategy="topk", budget=3),
            threshold=-1.0),
        "a5": mgr.register_standing(
            sids[0], QuerySpec(sid=sids[0], embedding=embs[0],
                               strategy="topk", budget=5),
            threshold=-1.0),
        "b4": mgr.register_standing(
            sids[1], QuerySpec(sid=sids[1], embedding=embs[1],
                               strategy="topk", budget=4),
            threshold=-1.0),
    }
    phys = {sids[0]: _insert(mgr, sids[0], rows_a, 0),
            sids[1]: _insert(mgr, sids[1], rows_b, 0),
            sids[2]: _insert(mgr, sids[2], rows_c, 0)}
    fired = {a.spec_id: a for a in _evaluate(mgr, phys)}
    assert set(fired) == set(ids.values())
    np.testing.assert_array_equal(
        fired[ids["a3"]].frame_ids,
        _twin_topk_ids(rows_a, np.arange(5), embs[0], 3))
    np.testing.assert_array_equal(
        fired[ids["a5"]].frame_ids,
        _twin_topk_ids(rows_a, np.arange(5), embs[0], 5))
    np.testing.assert_array_equal(
        fired[ids["b4"]].frame_ids,
        _twin_topk_ids(rows_b, np.arange(9), embs[1], 4))
    assert all(a.sid != sids[2] for a in fired.values())


# ---------------------------------------------------------------------------
# trigger state machine: hysteresis, cooldown, suppression accounting
# ---------------------------------------------------------------------------


def _drive_sims(mgr, sid, emb, sims, rng):
    """One single-row tick per similarity; returns fires-per-tick."""
    out, fid = [], 0
    for s in sims:
        row = _rows_with_sims(rng, emb, [s])
        phys = _insert(mgr, sid, row, fid)
        fid += 1
        out.append(len(_evaluate(mgr, {sid: phys})))
    return out


def test_hysteresis_fires_once_per_excursion():
    """threshold .5, hysteresis .2: a score flapping above the
    threshold fires once; it must fall through the re-arm band
    (<= .3) — NOT merely below the threshold — before firing again."""
    rng = np.random.default_rng(5)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=0.5, hysteresis=0.2)
    fires = _drive_sims(mgr, sid, emb,
                        [0.6, 0.6, 0.45, 0.6, 0.25, 0.6], rng)
    #                    fire  supp  band  supp  rearm fire
    assert fires == [1, 0, 0, 0, 0, 1]
    assert mgr.io_stats["alerts_fired"] == 2
    assert mgr.io_stats["alerts_suppressed"] == 2


def test_cooldown_debounces_refire():
    """cooldown_ticks=3: after a fire, a re-armed spec whose score
    crosses again while the cooldown drains is SUPPRESSED (counted),
    then fires once the cooldown reaches zero."""
    rng = np.random.default_rng(6)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=0.5, cooldown_ticks=3)
    fires = _drive_sims(mgr, sid, emb, [0.6, 0.2, 0.6, 0.6], rng)
    #                                   fire  rearm supp  fire
    assert fires == [1, 0, 0, 1]
    assert mgr.io_stats["alerts_suppressed"] == 1


def test_subthreshold_never_fires():
    rng = np.random.default_rng(7)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=0.9)
    fires = _drive_sims(mgr, sid, emb, [0.1, 0.5, 0.8, 0.85], rng)
    assert fires == [0, 0, 0, 0]
    assert mgr.io_stats["alerts_fired"] == 0
    assert mgr.standing.pending_alerts == 0


def test_alert_frame_ids_are_thresholded():
    """frame_ids carry only the rows AT OR ABOVE the threshold (within
    the budget) — not the whole top-k block."""
    rng = np.random.default_rng(8)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    rows = _rows_with_sims(rng, emb, [0.95, 0.3, 0.92, 0.1, 0.2])
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=4),
        threshold=0.9)
    fired = _evaluate(mgr, {sid: _insert(mgr, sid, rows, 0)})
    np.testing.assert_array_equal(fired[0].frame_ids, [0, 2])


# ---------------------------------------------------------------------------
# delivery: priority ordering, callbacks, lifecycle
# ---------------------------------------------------------------------------


def test_poll_alerts_priority_ordered():
    """poll_alerts drains priority desc, then score desc; max_alerts
    caps the drain and the remainder stays pending."""
    rng = np.random.default_rng(9)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    lo = mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=-1.0, priority=0.0)
    hi = mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=-1.0, priority=5.0)
    rows = _rows_with_sims(rng, emb, [0.8])
    _evaluate(mgr, {sid: _insert(mgr, sid, rows, 0)})
    assert mgr.standing.pending_alerts == 2
    first = mgr.poll_alerts(max_alerts=1)
    assert [a.spec_id for a in first] == [hi]
    assert mgr.standing.pending_alerts == 1
    assert [a.spec_id for a in mgr.poll_alerts()] == [lo]
    assert mgr.poll_alerts() == []


def test_on_alert_callback_observes_stream():
    rng = np.random.default_rng(10)
    mgr = _direct_manager(FLAT)
    svc = VenusService(mgr, engine=None)
    sid = svc.create_stream()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    seen = []
    svc.on_alert(seen.append)
    spec_id = svc.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=2),
        threshold=0.5)
    rows = _rows_with_sims(rng, emb, [0.9, 0.7, 0.2])
    _evaluate(mgr, {sid: _insert(mgr, sid, rows, 0)})
    assert [a.spec_id for a in seen] == [spec_id]
    # callbacks observe; poll still drains the same alert
    assert [a.spec_id for a in svc.poll_alerts()] == [spec_id]
    stats = svc.io_stats()
    assert stats["standing_specs"] == 1
    assert stats["alerts_fired"] == 1


def test_close_session_drops_specs_no_ghost_firing():
    """Closing a stream drops its standing specs; the NEXT tenant of
    the recycled arena slot must not fire them — while alerts already
    fired for the closed stream stay pollable."""
    rng = np.random.default_rng(11)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    spec_id = mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=0.5)
    rows = _rows_with_sims(rng, emb, [0.9])
    _evaluate(mgr, {sid: _insert(mgr, sid, rows, 0)})
    assert mgr.standing.pending_alerts == 1     # fired, not yet polled
    mgr.close_session(sid)
    assert mgr.standing.n_specs == 0
    sid2 = mgr.create_session()                 # recycles the slot
    assert mgr.sessions[sid2].memory.slot == 0
    fired = _evaluate(mgr, {sid2: _insert(mgr, sid2, rows, 0)})
    assert fired == []                          # no ghost-firing
    polled = mgr.poll_alerts()
    assert [a.spec_id for a in polled] == [spec_id]
    assert polled[0].sid == sid                 # the closed stream's


def test_unregister_stops_evaluation():
    rng = np.random.default_rng(12)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    spec_id = mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=-1.0)
    rows = _rows_with_sims(rng, emb, [0.9])
    assert len(_evaluate(mgr, {sid: _insert(mgr, sid, rows, 0)})) == 1
    mgr.unregister_standing(spec_id)
    assert mgr.standing.n_specs == 0
    assert _evaluate(mgr, {sid: _insert(mgr, sid, rows, 1)}) == []


# ---------------------------------------------------------------------------
# validation: only deterministic fused specs, sane trigger params
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["sampling", "akr", "bolt",
                                      "uniform"])
def test_register_rejects_non_deterministic_strategies(strategy):
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = np.ones(DIM, np.float32) / np.sqrt(DIM)
    with pytest.raises(ValueError, match="standing"):
        mgr.register_standing(
            sid, QuerySpec(sid=sid, embedding=emb, strategy=strategy,
                           budget=4),
            threshold=0.5)


def test_register_rejects_explicit_seed_and_bad_trigger_params():
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    emb = np.ones(DIM, np.float32) / np.sqrt(DIM)
    spec = QuerySpec(sid=sid, embedding=emb, strategy="topk", budget=4)
    with pytest.raises(ValueError, match="seed"):
        mgr.register_standing(
            sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                           budget=4, seed=7),
            threshold=0.5)
    with pytest.raises(ValueError, match="threshold"):
        mgr.register_standing(sid, spec, threshold=float("inf"))
    with pytest.raises(ValueError, match="hysteresis"):
        mgr.register_standing(sid, spec, threshold=0.5, hysteresis=-0.1)
    with pytest.raises(ValueError, match="cooldown"):
        mgr.register_standing(sid, spec, threshold=0.5,
                              cooldown_ticks=-1)
    assert mgr.standing.n_specs == 0


# ---------------------------------------------------------------------------
# the bandwidth claim: standing_scan_bytes = padded slab, not capacity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("index_dtype,itemsize", [("float32", 4),
                                                  ("int8", 1)])
def test_standing_scan_bytes_is_slab_sized(index_dtype, itemsize):
    """One tick over n new rows streams exactly the padded-slab bytes
    G · pow2(n) · d · itemsize — within 2× of n·d·itemsize and far
    below a capacity re-scan — with zero stack rebuilds."""
    rng = np.random.default_rng(13)
    cfg = VenusConfig(memory_capacity=4096, member_cap=8,
                      index_dtype=index_dtype)
    mgr = _direct_manager(cfg)
    sid = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=4),
        threshold=-1.0)
    n_new = 10
    rows = _unit(rng.normal(size=(n_new, DIM)))
    phys = _insert(mgr, sid, rows, 0)
    kops.reset_scan_counts()
    _evaluate(mgr, {sid: phys})
    got = kops.scan_counts()["standing_scan_bytes"]
    assert got == _pow2(n_new) * DIM * itemsize
    assert got <= 2 * n_new * DIM * itemsize
    assert got < cfg.memory_capacity * DIM * itemsize // 8
    assert mgr.io_stats["stack_rebuilds"] == 0


def test_empty_tick_scans_nothing():
    """Ticks with no new rows for any spec'd session launch nothing."""
    rng = np.random.default_rng(14)
    mgr = _direct_manager(FLAT)
    sid = mgr.create_session()
    other = mgr.create_session()
    emb = _unit(rng.normal(size=(1, DIM)))[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=emb, strategy="topk",
                       budget=1),
        threshold=-1.0)
    rows = _unit(rng.normal(size=(4, DIM)))
    phys = _insert(mgr, other, rows, 0)         # spec-less session only
    kops.reset_scan_counts()
    assert _evaluate(mgr, {other: phys}) == []
    assert kops.scan_counts()["standing_scan_bytes"] == 0


# ---------------------------------------------------------------------------
# end-to-end ingest path + sharded-manager pin
# ---------------------------------------------------------------------------


def _block_chunk(rng, n=16, hw=16, pool=8):
    """n identical frames of one block-structured scene: random values
    at the embedder's pool scale (zero-centred so distinct scenes pool
    to near-orthogonal vectors — whole-frame means would all collapse
    to the same gray direction)."""
    blocks = rng.uniform(-1, 1, (hw // pool, hw // pool, 3)
                         ).astype(np.float32)
    frame = np.kron(blocks, np.ones((pool, pool, 1), np.float32))
    return np.broadcast_to(frame, (n,) + frame.shape).copy()


def _target_chunk(n=16):
    return _block_chunk(np.random.default_rng(99), n=n)


def _ingest_alert_stream(mesh=None):
    """Alternate a constant 'target' scene with noise scenes through
    the REAL ingest path; return (manager, polled alerts)."""
    rng = np.random.default_rng(15)
    embedder = PixelEmbedder(dim=64)
    cfg = VenusConfig(max_partition_len=64, scene_threshold=0.075)
    mgr = SessionManager(cfg, embedder, embed_dim=64, mesh=mesh)
    sid = mgr.create_session()
    target = embedder.embed_frames(_target_chunk())[0]
    mgr.register_standing(
        sid, QuerySpec(sid=sid, embedding=np.asarray(target, np.float32),
                       strategy="topk", budget=4),
        threshold=0.9, hysteresis=0.05)
    for t in range(6):
        chunk = _target_chunk() if t % 2 == 0 else _block_chunk(rng)
        mgr.ingest_tick({sid: chunk})
    mgr.flush()
    return mgr, mgr.poll_alerts()


def test_ingest_path_fires_on_matching_scenes():
    """Registered once, the spec fires once per matching scene as its
    cluster commits — never for the noise scenes between them — and
    every alert's frames come from the matching chunks' id ranges."""
    mgr, alerts = _ingest_alert_stream()
    assert len(alerts) == 3
    matching = set()
    for t in (0, 2, 4):                        # constant-chunk ticks
        matching.update(range(16 * t, 16 * (t + 1)))
    for a in alerts:
        assert a.score > 0.99
        assert set(int(f) for f in a.frame_ids) <= matching
    assert mgr.io_stats["alerts_fired"] == 3
    assert mgr.io_stats["stack_rebuilds"] == 0
    assert kops.scan_counts()["standing_scan_bytes"] > 0


@multi_device
def test_sharded_manager_same_alerts_and_bytes():
    """A mesh-sharded arena takes the IDENTICAL standing path (the slab
    is a fresh compact unsharded operand): same alert stream, same
    slab-sized standing_scan_bytes, zero stack rebuilds."""
    base_mgr, base = _ingest_alert_stream()
    base_bytes = kops.scan_counts()["standing_scan_bytes"]
    kops.reset_scan_counts()
    mesh_mgr, got = _ingest_alert_stream(
        mesh=make_host_mesh(model=len(jax.devices())))
    assert len(got) == len(base) == 3
    for a, b in zip(got, base):
        assert (a.sid, a.spec_id, a.tick) == (b.sid, b.spec_id, b.tick)
        assert a.score == b.score
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
    assert kops.scan_counts()["standing_scan_bytes"] == base_bytes > 0
    assert mesh_mgr.io_stats["stack_rebuilds"] == 0
    assert base_mgr.io_stats["stack_rebuilds"] == 0
