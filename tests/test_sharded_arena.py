"""Sharded MemoryArena: slab placement, shard_map scan fan-out, and the
double-buffered ingest/query overlap (PR-7 tentpole acceptance).

Equivalence discipline:

* K == 1 (mesh with a size-1 ``model`` axis, or no mesh) must be
  BIT-identical to the unsharded arena path — the kops entries
  short-circuit, growth stays single-slot, the free-list stays LIFO.
* K > 1 (host-platform devices via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the
  multi-device CI lane) must match the single-device oracle
  draw-for-draw: the stack kernels are pure per-lane programs, so a
  shard_map over contiguous slot slabs is exactly the single-device
  computation restricted to each slab, concatenated.
* Double buffering is a pure scheduling change: the front buffer after
  every flush is bitwise the single-buffer state.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.memory import MemoryArena
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh

CFG = VenusConfig(max_partition_len=48)
EVICT_CFG = VenusConfig(max_partition_len=32, memory_capacity=16,
                        eviction="sliding_window")

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count")


def _worlds(n):
    return [VideoWorld(WorldConfig(n_scenes=4 + s, seed=20 + s))
            for s in range(n)]


def _manager(cfg, **kw):
    return SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64, **kw)


def _chunk(w, t, chunk=64):
    lo = (t * chunk) % max(w.total_frames - chunk, 1)
    return w.frames[lo:lo + chunk]


def _tick(mgr, stream_map, t):
    mgr.ingest_tick({sid: _chunk(w, t) for sid, w in stream_map.items()})


def _queries(worlds, qsids, seed0):
    return np.stack([
        OracleEmbedder(worlds[s], dim=64).embed_queries(
            worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)])


def _assert_same_results(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        assert a.n_drawn == b.n_drawn


def _drive(mgr, worlds, sids, *, ticks=3, seed0=300):
    for t in range(ticks):
        _tick(mgr, dict(zip(sids, worlds)), t)
    qsids = [0, 1, 1, 0]
    qes = _queries(worlds, qsids, seed0=seed0)
    return mgr.query_batch_cross([sids[s] for s in qsids], query_embs=qes)


# ---------------------------------------------------------------------------
# K == 1: the sharded code path must BE the PR-6 path
# ---------------------------------------------------------------------------


def test_k1_mesh_bit_identical_to_unsharded():
    """A mesh whose model axis has size 1 must change nothing: same
    draws, same frame ids, same arena buffer bytes, single-slot growth,
    and zero sharded launches counted."""
    worlds = _worlds(2)
    mesh = make_host_mesh(model=1)
    plain = _manager(CFG)
    sharded = _manager(CFG, mesh=mesh, double_buffer=False)
    sids_p = [plain.create_session() for _ in range(2)]
    sids_s = [sharded.create_session() for _ in range(2)]
    assert sids_s == sids_p
    kops.reset_scan_counts()
    want = _drive(plain, worlds, sids_p)
    got = _drive(sharded, worlds, sids_s)
    _assert_same_results(got, want)
    assert sharded.arena.n_shards == 1
    assert sharded.arena.n_sessions == plain.arena.n_sessions == 2
    assert sharded.arena.virgin_slots == []
    np.testing.assert_array_equal(np.asarray(sharded.arena.emb),
                                  np.asarray(plain.arena.emb))
    assert kops.scan_counts()["sharded_stack_launches"] == 0
    assert sharded.io_stats["sharded_group_scans"] == 0


def test_double_buffer_front_matches_single_buffer():
    """double_buffer=True is a pure scheduling change: after every tick
    the front super-buffers are bitwise the single-buffer state and
    queries answer identically; the back set trails by one tick and the
    replay counters account for it."""
    worlds = _worlds(2)
    single = _manager(CFG, double_buffer=False)
    double = _manager(CFG, double_buffer=True)
    sids = [single.create_session() for _ in range(2)]
    sids_d = [double.create_session() for _ in range(2)]
    for t in range(3):
        _tick(single, dict(zip(sids, worlds)), t)
        _tick(double, dict(zip(sids_d, worlds)), t)
        for name in ("emb", "members", "member_count", "index_frame"):
            np.testing.assert_array_equal(
                np.asarray(getattr(double.arena, name)),
                np.asarray(getattr(single.arena, name)),
                err_msg=f"front {name} diverged at tick {t}")
    qsids = [0, 1, 1]
    qes = _queries(worlds, qsids, seed0=310)
    _assert_same_results(
        double.query_batch_cross([sids_d[s] for s in qsids],
                                 query_embs=qes),
        single.query_batch_cross([sids[s] for s in qsids],
                                 query_embs=qes))
    io = double.arena.io_stats
    assert io["double_flushes"] == io["appends"] > 0
    assert io["carry_rows"] > 0          # later ticks replayed a carry
    assert single.arena.io_stats["double_flushes"] == 0


def test_double_buffer_slot_recycle_filters_carry():
    """A recycled slot must not be resurrected by last tick's replay:
    close a session right after an ingest tick (its blocks sit in the
    carry), recycle the slot, ingest — the recycled lane must hold only
    the new tenant's rows."""
    worlds = _worlds(3)
    mgr = _manager(CFG, double_buffer=True)
    sids = [mgr.create_session() for _ in range(2)]
    _tick(mgr, dict(zip(sids, worlds[:2])), 0)      # carry now holds both
    freed = mgr[sids[1]].memory.slot
    mgr.close_session(sids[1])
    new_sid = mgr.create_session()
    assert mgr[new_sid].memory.slot == freed
    _tick(mgr, {sids[0]: worlds[0], new_sid: worlds[2]}, 1)
    _tick(mgr, {sids[0]: worlds[0], new_sid: worlds[2]}, 2)
    # the recycled lane's window rows all belong to the new tenant
    fresh = _manager(CFG, double_buffer=False)
    f0 = fresh.create_session()
    f1 = fresh.create_session()
    _tick(fresh, {f0: worlds[0]}, 0)
    _tick(fresh, {f0: worlds[0], f1: worlds[2]}, 1)
    _tick(fresh, {f0: worlds[0], f1: worlds[2]}, 2)
    qes = _queries(worlds, [0, 2], seed0=320)
    _assert_same_results(
        mgr.query_batch_cross([sids[0], new_sid], query_embs=qes),
        fresh.query_batch_cross([f0, f1], query_embs=qes))


# ---------------------------------------------------------------------------
# K > 1: multi-device equivalence (host-platform CI lane)
# ---------------------------------------------------------------------------


@multi_device
def test_block_growth_and_balanced_placement():
    """The arena grows in blocks of K slots (S always divides the mesh
    axis); allocation balances live sessions across slabs and recycles
    freed slots without growth."""
    k = len(jax.devices())
    mesh = make_host_mesh(model=k)
    a = MemoryArena(16, 8, mesh=mesh)
    assert a.n_shards == k
    s0 = a.add_session()
    assert a.n_sessions == k and a.io_stats["grows"] == 1
    assert sorted(a.virgin_slots + [s0]) == list(range(k))
    slots = [a.add_session() for _ in range(k - 1)]
    assert a.virgin_slots == [] and a.io_stats["grows"] == 1
    # one session per slab: perfectly balanced
    assert sorted([s0] + slots) == list(range(k))
    assert {a._shard_of(s) for s in [s0] + slots} == set(range(k))
    nxt = a.add_session()                     # block 2
    assert a.n_sessions == 2 * k and a.io_stats["grows"] == 2
    a.release_slot(nxt)
    assert a.add_session() == nxt             # recycled, not grown
    assert a.io_stats["grows"] == 2 and a.io_stats["slot_reuses"] == 1
    # placement respects the sharding spec end to end
    assert a.emb.shape[0] % k == 0


@multi_device
def test_sharded_manager_matches_single_device_oracle():
    """ACCEPTANCE: a manager whose arena is sharded over every
    host-platform device answers draw-for-draw like the unsharded
    oracle — including a sliding-window (ring) session — while the
    fused launches fan out per shard."""
    k = len(jax.devices())
    worlds = _worlds(2)
    mesh = make_host_mesh(model=k)
    oracle = _manager(CFG)
    mgr = _manager(CFG, mesh=mesh)
    assert mgr.double_buffer                   # defaults on with a mesh
    sids_o = [oracle.create_session() for _ in range(2)]
    sids_s = [mgr.create_session() for _ in range(2)]
    kops.reset_scan_counts()
    want = _drive(oracle, worlds, sids_o, seed0=330)
    got = _drive(mgr, worlds, sids_s, seed0=330)
    _assert_same_results(got, want)
    c = kops.scan_counts()
    assert c["sharded_stack_launches"] > 0
    assert mgr.io_stats["sharded_group_scans"] > 0
    assert mgr.io_stats["stack_rebuilds"] == 0
    assert mgr.arena.n_sessions % k == 0


@multi_device
def test_sharded_eviction_ring_matches_oracle():
    """Ring sessions (sliding-window eviction past capacity) keep their
    window semantics under sharding: the (S, 2) windows array is the
    shard-local valid operand, split along the slot axis."""
    k = len(jax.devices())
    worlds = _worlds(2)
    mesh = make_host_mesh(model=k)
    oracle = _manager(EVICT_CFG)
    mgr = _manager(EVICT_CFG, mesh=mesh)
    sids_o = [oracle.create_session() for _ in range(2)]
    sids_s = [mgr.create_session() for _ in range(2)]
    for t in range(8):                         # far past capacity
        _tick(oracle, dict(zip(sids_o, worlds)), t)
        _tick(mgr, dict(zip(sids_s, worlds)), t)
    for sid in sids_s:
        assert mgr[sid].memory.io_stats["evicted_rows"] > 0
    qsids = [0, 1, 1]
    qes = _queries(worlds, qsids, seed0=340)
    _assert_same_results(
        mgr.query_batch_cross([sids_s[s] for s in qsids], query_embs=qes),
        oracle.query_batch_cross([sids_o[s] for s in qsids],
                                 query_embs=qes))


@multi_device
def test_shard_gather_bytes_exclude_dense_term():
    """The fused sharded launch's cross-shard traffic is its OUTPUTS —
    O(S·Q·(T+K)) candidate/draw arrays — never an O(S·Q·capacity)
    score tensor. The counter measures actual output sizes, so a dense
    leak would show up immediately."""
    k = len(jax.devices())
    worlds = _worlds(2)
    mesh = make_host_mesh(model=k)
    mgr = _manager(CFG, mesh=mesh)
    sids = [mgr.create_session() for _ in range(2)]
    for t in range(2):
        _tick(mgr, dict(zip(sids, worlds)), t)
    kops.reset_scan_counts()
    qes = _queries(worlds, [0, 1], seed0=350)
    mgr.query_batch_cross(sids, query_embs=qes)
    c = kops.scan_counts()
    assert c["sharded_stack_launches"] >= 1
    s, q, cap = mgr.arena.n_sessions, 1, mgr.arena.capacity
    dense = s * q * cap * 4                   # one f32 (S,Q,cap) tensor
    assert 0 < c["shard_gather_bytes"] < dense


# ---------------------------------------------------------------------------
# hierarchical coarse tier under sharding (two-stage kops pin, CI lane)
# ---------------------------------------------------------------------------


TIER_DIM = 32
# n_blocks = 128/16 = 8, n_coarse = 40; one two-stage query streams
# 40 coarse + topb·16 = 104 rows vs the flat scan's full capacity
TIER_CFG = VenusConfig(memory_capacity=128, member_cap=8,
                       eviction="consolidate", coarse_capacity=32,
                       coarse_block=16, coarse_topb=4)


class _ArrayEmbedder:
    def embed_queries(self, texts):
        raise AssertionError("tests pass explicit embeddings")

    def embed_frames(self, frames, aux=None, frame_ids=None):
        raise AssertionError("tests insert rows directly")


def _tier_feed(mgr, sid, rows):
    mem = mgr.sessions[sid].memory
    for lo in range(0, len(rows), 16):
        batch = rows[lo:lo + 16]
        fids = np.arange(lo, lo + len(batch))
        with mgr.arena.deferred_appends():
            mem.insert_batch(batch, scene_ids=[0] * len(batch),
                             index_frames=fids,
                             member_lists=[[int(f)] for f in fids])


@multi_device
def test_sharded_two_stage_matches_oracle_and_pins_bytes():
    """ACCEPTANCE (multi-device lane): the two-stage path on a K-sharded
    arena answers draw-for-draw like the single-device tiered oracle —
    stage 1 fans out per slab, stage 2's candidate scan is epilogue-sized
    and unsharded — and the kops counters pin coarse + gathered-fine
    bytes BELOW one flat 1×-capacity scan."""
    k = len(jax.devices())
    rng = np.random.default_rng(23)
    cen = rng.normal(size=(8, TIER_DIM)).astype(np.float32)
    cen /= np.linalg.norm(cen, axis=-1, keepdims=True)
    labels = rng.integers(0, 8, size=4 * TIER_CFG.memory_capacity)
    rows = cen[labels] + 0.05 * rng.normal(size=(len(labels), TIER_DIM))
    rows = (rows / np.linalg.norm(rows, axis=-1, keepdims=True)
            ).astype(np.float32)

    mesh = make_host_mesh(model=k)
    mgr = SessionManager(TIER_CFG, _ArrayEmbedder(), embed_dim=TIER_DIM,
                         mesh=mesh)
    oracle = SessionManager(TIER_CFG, _ArrayEmbedder(),
                            embed_dim=TIER_DIM)
    sid = mgr.create_session()
    osid = oracle.create_session()
    _tier_feed(mgr, sid, rows)
    _tier_feed(oracle, osid, rows)
    assert mgr.arena.n_shards == k > 1
    assert mgr.arena.has_consolidated()

    from repro.core.queryplan import QuerySpec
    spec = lambda s, j: QuerySpec(sid=s, embedding=cen[j],
                                  strategy="topk", budget=8)
    # flat baseline bytes on the sharded manager
    kops.reset_scan_counts()
    mgr.execute(mgr.plan([spec(sid, 0)]), coarse=False)
    flat_bytes = kops.scan_counts()["scan_bytes"]

    kops.reset_scan_counts()
    for j in range(4):
        got = mgr.execute(mgr.plan([spec(sid, j)]))[0]
        want = oracle.execute(oracle.plan([spec(osid, j)]))[0]
        np.testing.assert_array_equal(got.draws, want.draws)
        np.testing.assert_array_equal(got.frame_ids, want.frame_ids)
    c = kops.scan_counts()
    # the kops counters are process-global: 4 sharded + 4 oracle queries
    assert c["two_stage_scans"] == 8
    assert c["coarse_scan_bytes"] > 0
    assert c["fine_gather_rows"] > 0
    per_query_fine = TIER_CFG.coarse_topb * TIER_CFG.coarse_block
    # per-query bytes (one sharded query): coarse + gathered fine < flat
    coarse_per_q = mgr.arena.n_coarse * mgr.arena.n_sessions \
        * TIER_DIM * 4
    assert coarse_per_q + per_query_fine * TIER_DIM * 4 < flat_bytes
    assert mgr.io_stats["two_stage_groups"] == 4
    assert mgr.io_stats["stack_rebuilds"] == 0
