"""Property test for the two-tier ``FrameStore``: random
append/trim/get sequences against an unbounded twin.

The invariant (ISSUE 9 / ARCHITECTURE.md "Storage tiers"): for EVERY
absolute id ever archived, ``get(i)`` is bit-identical to an unbounded
single-tier twin whenever the id is live or spilled, and raises
``IndexError`` only for ids below the spill floor — which is 0 with
spill enabled (everything faults back in) and the host base with spill
disabled (trimmed means deleted).
"""

import os
import shutil
import tempfile

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.memory import FrameStore  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(data=st.data(), spill=st.booleans())
def test_random_ops_match_unbounded_twin(data, spill):
    tmp = tempfile.mkdtemp() if spill else None
    try:
        fs = FrameStore(os.path.join(tmp, "s") if spill else None,
                        segment_frames=3, cache_segments=2)
        twin = FrameStore()
        counter = 0
        for _ in range(data.draw(st.integers(2, 12))):
            op = data.draw(st.sampled_from(["append", "trim", "get"]))
            if op == "append":
                k = data.draw(st.integers(1, 5))
                frames = (np.arange(counter, counter + k,
                                    dtype=np.float32)[:, None, None, None]
                          * np.ones((1, 2, 2, 3), np.float32))
                counter += k
                fs.append(frames)
                twin.append(frames)
            elif op == "trim" and len(fs):
                fs.trim(data.draw(st.integers(0, len(fs))))
            elif op == "get" and len(fs):
                i = data.draw(st.integers(0, len(fs) - 1))
                if i >= fs.spill_floor:
                    assert (fs.get([i]).tobytes()
                            == twin.get([i]).tobytes())
                else:
                    with pytest.raises(IndexError):
                        fs.get([i])
        assert fs.spill_floor == (0 if spill else fs.base)
        # demotion accounting holds at every stopping point
        assert fs.io_stats["spilled_frames"] == (fs.trimmed if spill
                                                 else 0)
        for i in range(len(fs)):            # final exhaustive sweep
            if i >= fs.spill_floor:
                assert fs.get([i]).tobytes() == twin.get([i]).tobytes()
            else:
                with pytest.raises(IndexError):
                    fs.get([i])
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)
