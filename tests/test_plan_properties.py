"""Hypothesis properties for the query-plan layer.

Randomised invariants over ``build_plan``/``execute_plan``:

* **Equivalence** — for random mixes of sessions (including size-0
  sessions), strategies, budgets, and seed policies, the fused plan
  path (arena-backed by default) returns exactly what the direct
  per-strategy ``retrieval.py`` calls return, with the per-session PRNG
  chains consumed in the executor's canonical order.
* **Planner shape** — ``plan.n_scans`` equals the number of distinct
  (strategy, resolved budget, scan-param) groups, the groups partition
  the specs, and per-session arrival order is preserved.

Run with a fixed seed in CI (``--hypothesis-seed=0``) for
reproducibility.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import retrieval as rt  # noqa: E402
from repro.core.queryplan import (QuerySpec, build_plan,  # noqa: E402
                                  strategies)
from repro.core.session import SessionManager, VenusConfig  # noqa: E402

DIM = 8
CFG = VenusConfig(memory_capacity=32, member_cap=8, n_max=8)
ALL_STRATEGIES = strategies()          # every registered retrieval rule
BUDGETS = (None, 3, 5)                 # None ⇒ cfg.n_max
_settings = settings(max_examples=10, deadline=None)
_settings_fast = settings(max_examples=30, deadline=None)


class _NoQueryEmbedder:
    """Specs in this suite always carry embeddings — any embedder call
    would mean the plan path diverged from the direct path."""

    def embed_queries(self, texts):
        raise AssertionError("plan unexpectedly embedded query text")


@st.composite
def plan_cases(draw):
    n_sessions = draw(st.integers(1, 3))
    sizes = draw(st.lists(st.integers(0, 12), min_size=n_sessions,
                          max_size=n_sessions))
    n_specs = draw(st.integers(1, 4))
    spec_descs = [(draw(st.integers(0, n_sessions - 1)),
                   draw(st.sampled_from(ALL_STRATEGIES)),
                   draw(st.sampled_from(BUDGETS)),
                   draw(st.sampled_from([None, None, 7])))  # bias: chain
                  for _ in range(n_specs)]
    data_seed = draw(st.integers(0, 2 ** 31 - 1))
    return sizes, spec_descs, data_seed


def _twin_managers(sizes, data_seed):
    """Two managers with identical sessions/memories/PRNG chains —
    one drives the plan path, one the direct per-strategy calls."""
    rng = np.random.default_rng(data_seed)
    payload = []
    for n in sizes:
        rows = rng.normal(0, 1, (n, DIM)).astype(np.float32)
        members = [list(range(i * 5, i * 5 + int(rng.integers(0, CFG.member_cap + 1))))
                   for i in range(n)]
        payload.append((rows, members))
    mgrs = []
    for _ in range(2):
        mgr = SessionManager(CFG, _NoQueryEmbedder(), embed_dim=DIM)
        for sid, (n, (rows, members)) in enumerate(zip(sizes, payload)):
            mgr.create_session()
            mgr[sid].stats["frames_seen"] = 3 * n + 5
            if n:
                mgr[sid].memory.insert_batch(
                    rows, scene_ids=[0] * n,
                    index_frames=list(range(n)), member_lists=members)
        mgrs.append(mgr)
    return mgrs


def _direct_one(mgr, spec, key_budget):
    """The strategy's direct retrieval.py call for one spec — consumes
    the session chain iff the spec is chain-policy (seed=None)."""
    cfg = mgr.cfg
    sess = mgr[spec.sid]
    budget = key_budget
    emb, valid = sess.memory.device_index()
    sims, probs = sess.memory.search(
        jnp.asarray(spec.embedding, jnp.float32)[None], tau=cfg.tau)
    sims0, probs0 = sims[0], probs[0]
    strategy = spec.strategy
    if strategy in ("sampling", "akr"):
        sub = (sess.next_keys(1)[0] if spec.seed is None
               else jax.random.key(int(spec.seed)))
        if strategy == "sampling":
            draws, _ = rt.sampling_retrieve(probs0, sub, budget)
            draws = np.asarray(draws)
            fids = sess.memory.expand_draws(
                draws, np.ones(budget, bool), seed=cfg.seed)
        else:
            res = rt.akr_progressive(probs0, sub, theta=cfg.theta,
                                     beta=cfg.beta, n_max=budget)
            draws = np.asarray(res.draws)
            fids = sess.memory.expand_draws(
                draws, np.asarray(res.valid), seed=cfg.seed)
    elif strategy == "topk":
        draws = np.asarray(rt.topk_retrieve(sims0, valid, budget))
        fids = sess.memory.index_frames(draws)
    elif strategy == "uniform":
        draws = np.asarray(rt.uniform_retrieve(
            sess.stats["frames_seen"], budget))
        fids = draws
    elif strategy == "bolt":
        draws = np.asarray(rt.bolt_inverse_transform(
            sims0, valid, budget, tau=cfg.tau))
        fids = sess.memory.index_frames(draws)
    elif strategy == "mdf":
        draws = np.asarray(rt.mdf_retrieve(emb, valid, budget))
        fids = sess.memory.index_frames(draws)
    elif strategy == "aks":
        draws = np.asarray(rt.aks_retrieve(sims0, valid, budget))
        fids = sess.memory.index_frames(draws)
    else:
        raise AssertionError(strategy)
    return draws, np.asarray(fids)


@_settings
@given(case=plan_cases())
def test_plan_path_equals_direct_retrieval_calls(case):
    """Random session mixes (incl. size-0), strategies, budgets, and
    seed policies: execute_plan == the direct per-strategy call chain,
    draw-for-draw (same index draws, same frame ids)."""
    sizes, spec_descs, data_seed = case
    mgr_plan, mgr_direct = _twin_managers(sizes, data_seed)
    rng = np.random.default_rng(data_seed + 1)
    qes = rng.normal(0, 1, (len(spec_descs), DIM)).astype(np.float32)
    specs = [QuerySpec(sid=sid, embedding=qes[j], strategy=strategy,
                       budget=budget, seed=seed)
             for j, (sid, strategy, budget, seed) in enumerate(spec_descs)]

    plan = mgr_plan.plan(specs)
    got = mgr_plan.execute(plan)

    # direct path: consume PRNG chains in the executor's canonical
    # order (plan group order; ascending sid within a group; arrival
    # order within a session)
    want = [None] * len(specs)
    for group in plan.groups:
        for sid in sorted(group.order):
            for j in group.order[sid]:
                want[j] = _direct_one(mgr_direct, specs[j],
                                      group.key.budget)

    for res, (draws, fids) in zip(got, want):
        np.testing.assert_array_equal(res.draws, draws)
        np.testing.assert_array_equal(res.frame_ids, fids)


@_settings_fast
@given(data=st.data())
def test_n_scans_equals_distinct_groups(data):
    """``plan.n_scans`` == the number of distinct (strategy, resolved
    budget, tau, theta, beta) combinations; groups partition the specs;
    per-session arrival order is preserved."""
    n_specs = data.draw(st.integers(1, 8))
    e = np.zeros(DIM, np.float32)
    specs = []
    for _ in range(n_specs):
        specs.append(QuerySpec(
            sid=data.draw(st.integers(0, 3)), embedding=e,
            strategy=data.draw(st.sampled_from(ALL_STRATEGIES)),
            budget=data.draw(st.sampled_from(BUDGETS)),
            tau=data.draw(st.sampled_from([None, 0.2])),
            theta=data.draw(st.sampled_from([None, 0.5])),
            beta=data.draw(st.sampled_from([None, 2.0]))))
    plan = build_plan(specs, CFG)

    resolved = {(s.strategy,
                 s.budget if s.budget is not None else CFG.n_max,
                 s.tau if s.tau is not None else CFG.tau,
                 s.theta if s.theta is not None else CFG.theta,
                 s.beta if s.beta is not None else CFG.beta)
                for s in specs}
    assert plan.n_scans == len(plan.groups) == len(resolved)

    # groups partition spec positions
    all_idx = sorted(j for g in plan.groups for j in g.indices)
    assert all_idx == list(range(n_specs))
    for g in plan.groups:
        # per-session arrival order == spec arrival order
        for sid, idxs in g.order.items():
            assert idxs == sorted(idxs)
            assert all(specs[j].sid == sid for j in idxs)
        assert sorted(j for js in g.order.values() for j in js) \
            == sorted(g.indices)
        assert g.qmax == max(len(v) for v in g.order.values())
