"""§Perf attention variants must be EXACT rewrites of the naive path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


@pytest.mark.parametrize("window", [0, 300])
@pytest.mark.parametrize("with_lens", [False, True])
def test_chunked_sdpa_matches_naive(window, with_lens):
    ks = jax.random.split(jax.random.key(0), 3)
    b, s, h, hkv, d = 2, 1024, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    lens = jnp.asarray([700, 1024]) if with_lens else None
    out_c = A._sdpa_causal_chunked(q, k, v, 0.17, 0.0, 2, window, lens)
    mask = A.causal_window_mask(s, s, window)
    if lens is not None:
        mask = mask[None] & (jnp.arange(s)[None, None, :]
                             < lens[:, None, None])
    out_n = A._sdpa(q, k, v, mask, 0.17, 0.0, 2)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               rtol=1e-5, atol=1e-5)


def test_chunked_flag_default_and_model_parity():
    """Model forward identical with chunked on/off (chunk-sized seq)."""
    from repro.configs import registry
    from repro.models.transformer import Transformer
    cfg = registry.get_smoke_config("glm4-9b").replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (1, 512), 0,
                             cfg.vocab_size)
    # chunk boundary exercised: SDPA_Q_CHUNK=512 with S=512 falls back;
    # force a smaller chunk so the loop path runs inside the model
    old_chunk, old_flag = A.SDPA_Q_CHUNK, A.CHUNKED_SDPA
    try:
        A.CHUNKED_SDPA = False
        ref, _, _ = m.apply(params, tok, mode="train")
        A.CHUNKED_SDPA = True
        A.SDPA_Q_CHUNK = 128
        out, _, _ = m.apply(params, tok, mode="train")
    finally:
        A.SDPA_Q_CHUNK, A.CHUNKED_SDPA = old_chunk, old_flag
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_seq_parallel_disabled_by_default():
    assert A._SEQ_PARALLEL_SPEC is None
    # no-op without a spec
    q = jnp.zeros((1, 4, 2, 8))
    q2, k2, v2 = A._seq_shard(q, q, q)
    assert q2 is q
