"""Multi-session refactor invariants: batched querying must equal the
sequential path, interleaved multi-stream ingestion must equal separate
single-stream ingestion, the vectorised expansion must match the loop
reference, and the device index must update in place after inserts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.memory import VenusMemory
from repro.core.pipeline import VenusConfig, VenusSystem
from repro.core.session import SessionManager
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)


def _ingested_system(world, embedder, chunk=64, cfg=VenusConfig()):
    system = VenusSystem(cfg, embedder, embed_dim=64)
    for i in range(0, world.total_frames, chunk):
        system.ingest(world.frames[i:i + chunk])
    system.flush()
    return system


# ---------------------------------------------------------------------------
# query_batch == sequential query
# ---------------------------------------------------------------------------


def test_query_batch_matches_sequential_queries():
    """query_batch(Q=8) draws the same subkeys as 8 sequential query()
    calls, so draws / frame ids / mass must match exactly."""
    world = VideoWorld(WorldConfig(n_scenes=8, seed=3))
    oracle = OracleEmbedder(world, dim=64)
    sys_seq = _ingested_system(world, oracle)
    sys_bat = _ingested_system(world, OracleEmbedder(world, dim=64))

    queries = world.make_queries(8, seed=9)
    qes = OracleEmbedder(world, dim=64).embed_queries(queries)

    seq = [sys_seq.query(q.text, query_emb=qes[j])
           for j, q in enumerate(queries)]
    bat = sys_bat.query_batch([q.text for q in queries], query_embs=qes)
    assert len(bat) == 8
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        assert a.n_drawn == b.n_drawn
        np.testing.assert_allclose(a.mass, b.mass, rtol=1e-6)


def test_query_batch_fixed_budget_matches_sequential():
    world = VideoWorld(WorldConfig(n_scenes=6, seed=5))
    oracle = OracleEmbedder(world, dim=64)
    sys_seq = _ingested_system(world, oracle)
    sys_bat = _ingested_system(world, OracleEmbedder(world, dim=64))
    queries = world.make_queries(4, seed=11)
    qes = OracleEmbedder(world, dim=64).embed_queries(queries)

    seq = [sys_seq.query(q.text, budget=6, use_akr=False, query_emb=qes[j])
           for j, q in enumerate(queries)]
    bat = sys_bat.query_batch([q.text for q in queries], query_embs=qes,
                              budget=6, use_akr=False)
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)


def test_akr_batch_matches_scalar():
    rng = np.random.default_rng(0)
    probs = rng.random((5, 64)).astype(np.float32)
    probs /= probs.sum(-1, keepdims=True)
    keys = jax.random.split(jax.random.key(42), 5)
    bat = rt.akr_progressive_batch(jnp.asarray(probs), keys, theta=0.85,
                                   beta=1.0, n_max=16)
    for i in range(5):
        one = rt.akr_progressive(jnp.asarray(probs[i]), keys[i],
                                 theta=0.85, beta=1.0, n_max=16)
        np.testing.assert_array_equal(np.asarray(bat.draws[i]),
                                      np.asarray(one.draws))
        assert int(bat.n_drawn[i]) == int(one.n_drawn)
        np.testing.assert_allclose(float(bat.mass[i]), float(one.mass),
                                   rtol=1e-6)


def test_sampling_batch_matches_scalar():
    rng = np.random.default_rng(1)
    probs = rng.random((3, 32)).astype(np.float32)
    probs /= probs.sum(-1, keepdims=True)
    keys = jax.random.split(jax.random.key(7), 3)
    draws_b, counts_b = rt.sampling_retrieve_batch(jnp.asarray(probs),
                                                   keys, 12)
    for i in range(3):
        draws, counts = rt.sampling_retrieve(jnp.asarray(probs[i]),
                                             keys[i], 12)
        np.testing.assert_array_equal(np.asarray(draws_b[i]),
                                      np.asarray(draws))
        np.testing.assert_array_equal(np.asarray(counts_b[i]),
                                      np.asarray(counts))


# ---------------------------------------------------------------------------
# interleaved sessions == separate streams
# ---------------------------------------------------------------------------


def test_interleaved_sessions_match_separate_ingestion():
    """Two genuinely different streams interleaved tick-by-tick through
    one SessionManager must build exactly the memories that separate
    single-stream ingestion builds."""
    worlds = [VideoWorld(WorldConfig(n_scenes=5, seed=21)),
              VideoWorld(WorldConfig(n_scenes=5, seed=22))]
    n = min(w.total_frames for w in worlds)

    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sids = [mgr.create_session(), mgr.create_session()]
    for i in range(0, n, 50):
        mgr.ingest_tick({sid: w.frames[i:i + 50]
                         for sid, w in zip(sids, worlds)})
    mgr.flush()

    for sid, world in zip(sids, worlds):
        solo = VenusSystem(VenusConfig(), PixelEmbedder(dim=64),
                           embed_dim=64)
        for i in range(0, n, 50):
            solo.ingest(world.frames[i:i + 50])
        solo.flush()
        a, b = mgr[sid].memory, solo.memory
        assert a.size == b.size
        np.testing.assert_array_equal(a._emb[:a.size], b._emb[:b.size])
        np.testing.assert_array_equal(a._members[:a.size],
                                      b._members[:b.size])
        np.testing.assert_array_equal(a._member_count[:a.size],
                                      b._member_count[:b.size])
        np.testing.assert_array_equal(a._index_frame[:a.size],
                                      b._index_frame[:b.size])
        np.testing.assert_array_equal(a._scene_id[:a.size],
                                      b._scene_id[:b.size])
        assert mgr[sid].stats == solo.stats


# ---------------------------------------------------------------------------
# vectorised expand_draws == loop reference
# ---------------------------------------------------------------------------


def _member_memory(n_clusters=12, members_per=10):
    mem = VenusMemory(capacity=64, dim=8, member_cap=16)
    for i in range(n_clusters):
        mem.insert_cluster(np.ones(8, np.float32), scene_id=0,
                           index_frame=i,
                           member_frames=list(range(i * 100,
                                                    i * 100 + members_per)))
    return mem


def test_expand_draws_vectorised_matches_loop():
    mem = _member_memory()
    rng = np.random.default_rng(4)
    draws = rng.integers(-1, 12, size=40)
    valid = rng.random(40) > 0.3
    for seed in (0, 5, 99):
        got = mem.expand_draws(draws, valid, seed=seed)
        want = mem._expand_draws_loop(draws, valid, seed=seed)
        np.testing.assert_array_equal(got, want)


def test_expand_draws_batch_matches_per_row():
    mem = _member_memory()
    rng = np.random.default_rng(8)
    draws = rng.integers(-1, 12, size=(6, 20))
    valid = rng.random((6, 20)) > 0.25
    rows = mem.expand_draws_batch(draws, valid, seed=3)
    assert len(rows) == 6
    for i in range(6):
        np.testing.assert_array_equal(
            rows[i], mem.expand_draws(draws[i], valid[i], seed=3))


def test_expand_draws_empty_and_zero_count():
    mem = VenusMemory(capacity=8, dim=4, member_cap=4)
    mem.insert_cluster(np.ones(4, np.float32), scene_id=0, index_frame=0,
                       member_frames=[])
    out = mem.expand_draws(np.asarray([0, 0]), np.asarray([True, True]))
    assert out.size == 0
    out = mem.expand_draws(np.asarray([], np.int32),
                           np.asarray([], bool))
    assert out.size == 0


# ---------------------------------------------------------------------------
# device-resident index: no full re-upload after inserts
# ---------------------------------------------------------------------------


def _fill(mem, rows):
    lo = mem.size
    n = len(rows)
    mem.insert_batch(rows, scene_ids=[0] * n,
                     index_frames=list(range(lo, lo + n)),
                     member_lists=[[i] for i in range(lo, lo + n)])


def test_insert_then_search_updates_device_in_place():
    """After the initial upload, insert → search must append on device
    (no full (capacity, dim) retransfer) and return the same result a
    freshly built memory would."""
    rng = np.random.default_rng(0)
    mem = VenusMemory(capacity=256, dim=16, member_cap=4)
    first = rng.normal(0, 1, (20, 16)).astype(np.float32)
    _fill(mem, first)
    q = rng.normal(0, 1, (2, 16)).astype(np.float32)
    mem.search(jnp.asarray(q), tau=0.1)
    assert mem.io_stats["full_uploads"] == 1

    second = rng.normal(0, 1, (7, 16)).astype(np.float32)
    _fill(mem, second)
    sims, probs = mem.search(jnp.asarray(q), tau=0.1)
    assert mem.io_stats["full_uploads"] == 1          # no retransfer
    assert mem.io_stats["appended_rows"] > 0

    fresh = VenusMemory(capacity=256, dim=16, member_cap=4)
    _fill(fresh, np.concatenate([first, second]))
    sims2, probs2 = fresh.search(jnp.asarray(q), tau=0.1)
    np.testing.assert_allclose(np.asarray(probs), np.asarray(probs2),
                               rtol=1e-6, atol=1e-7)


def test_seed_mode_reuploads_every_insert():
    rng = np.random.default_rng(1)
    mem = VenusMemory(capacity=64, dim=8, member_cap=4,
                      incremental=False)
    _fill(mem, rng.normal(0, 1, (4, 8)).astype(np.float32))
    q = rng.normal(0, 1, (1, 8)).astype(np.float32)
    mem.search(jnp.asarray(q), tau=0.1)
    _fill(mem, rng.normal(0, 1, (4, 8)).astype(np.float32))
    mem.search(jnp.asarray(q), tau=0.1)
    assert mem.io_stats["full_uploads"] == 2


def test_capacity_guard_batched():
    mem = VenusMemory(capacity=4, dim=4)
    _fill(mem, np.ones((3, 4), np.float32))
    with pytest.raises(RuntimeError):
        _fill(mem, np.ones((2, 4), np.float32))


def test_cross_session_queries_no_full_uploads_after_stack():
    """io_stats regression for the DETACHED (use_arena=False) fallback:
    once the cross-session stack is built, N post-ingest fused queries
    must report 0 additional full index uploads — inserts extend the
    per-session device buffers in place and the stack rebuilds
    device-side from them. (The arena default never uploads at all —
    see tests/test_arena.py for its twin.)"""
    from repro.data.video import OracleEmbedder
    worlds = [VideoWorld(WorldConfig(n_scenes=4 + s, seed=40 + s))
              for s in range(3)]
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64, use_arena=False)
    sids = [mgr.create_session() for _ in worlds]
    half = min(w.total_frames for w in worlds) // 2
    for i in range(0, half, 64):
        mgr.ingest_tick({sid: w.frames[i:i + 64]
                         for sid, w in zip(sids, worlds)})

    def qes(seed0):
        return np.stack([OracleEmbedder(w, dim=64).embed_queries(
            w.make_queries(1, seed=seed0 + j))[0]
            for j, w in enumerate(worlds)])

    mgr.query_batch_cross(sids, query_embs=qes(50))    # builds the stack
    uploads = {s: mgr[s].memory.io_stats["full_uploads"] for s in sids}
    assert all(v == 1 for v in uploads.values())

    # keep ingesting, then query repeatedly: appends only, no re-uploads
    for i in range(half, half + 192, 64):
        mgr.ingest_tick({sid: w.frames[i:i + 64]
                         for sid, w in zip(sids, worlds)})
    for k in range(4):
        mgr.query_batch_cross(sids, query_embs=qes(60 + 7 * k))
    for s in sids:
        io = mgr[s].memory.io_stats
        assert io["full_uploads"] == uploads[s]        # 0 additional
        assert io["member_uploads"] == 1
        assert io["appended_rows"] > 0


# ---------------------------------------------------------------------------
# query_topk routes through the accounted device-index path
# ---------------------------------------------------------------------------


def test_query_topk_uses_device_index_accounting():
    """query_topk must hit the same device-resident index as query /
    query_batch: scans are counted and no extra full upload happens
    after the index is on device."""
    from repro.data.video import OracleEmbedder
    world = VideoWorld(WorldConfig(n_scenes=5, seed=17))
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sid = mgr.create_session()
    for i in range(0, world.total_frames, 64):
        mgr.ingest_tick({sid: world.frames[i:i + 64]})
    mgr.flush()

    qe = OracleEmbedder(world, dim=64).embed_queries(
        world.make_queries(1, seed=5))[0]
    mgr.query(sid, "", query_emb=qe)                   # index now on device
    mem_io = dict(mgr[sid].memory.io_stats)
    mgr_io = dict(mgr.io_stats)
    frames = mgr.query_topk(sid, "", k=4, query_emb=qe)
    assert len(frames) == 4
    io = mgr[sid].memory.io_stats
    assert io["scans"] == mem_io["scans"] + 1          # scan accounted
    assert io["full_uploads"] == mem_io["full_uploads"]  # no re-upload
    assert mgr.io_stats["scans"] == mgr_io["scans"] + 1


# ---------------------------------------------------------------------------
# serving bridge: retrieved frames feed the VLM engine
# ---------------------------------------------------------------------------


def test_venus_service_multi_tenant_round_trip():
    """Two camera streams behind one engine: queries retrieve from their
    own session, frames become vision_embeds, the VLM answers all."""
    from repro.configs import registry
    from repro.models.transformer import Transformer
    from repro.serving.engine import ServingEngine
    from repro.serving.venus_service import StreamQuery, VenusService

    worlds = [VideoWorld(WorldConfig(n_scenes=3, seed=31)),
              VideoWorld(WorldConfig(n_scenes=3, seed=32))]
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)

    cfg = registry.get_smoke_config("qwen2-vl-7b")
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128)
    svc = VenusService(mgr, eng, max_frames=2)

    sids = [svc.create_stream() for _ in worlds]
    n = min(w.total_frames for w in worlds)
    for i in range(0, n, 50):
        svc.ingest_tick({sid: w.frames[i:i + 50]
                         for sid, w in zip(sids, worlds)})
    svc.flush()
    for sid in sids:
        assert mgr[sid].memory.size > 0

    rng = np.random.default_rng(0)
    queries = [StreamQuery(rid=r, sid=sids[r % 2], text=f"query {r}",
                           prompt_tokens=rng.integers(
                               3, cfg.vocab_size, size=8),
                           max_new_tokens=3)
               for r in range(3)]
    done = svc.answer(queries)
    assert [r.rid for r in done] == [0, 1, 2]
    for r in done:
        assert len(r.generated) == 3
        assert r.vision_embeds is not None
        assert r.vision_embeds.shape == (cfg.vision_tokens, cfg.d_model)
    # retrieval actually ran per stream
    assert all(q.frame_ids is not None for q in queries)
