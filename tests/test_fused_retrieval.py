"""One-launch fused retrieval + int8 quantised index (PR-6 tentpole).

Three layers of guarantees:

* **Kernel**: ``fused_retrieve_stack`` (draws + drawn probabilities +
  top-k + softmax stats in one launch) matches the materialised
  two-launch path draw-for-draw on both backends, across the edge
  shapes that historically break scan kernels — size-0 sessions,
  ``(start, size)`` ring windows that wrap, capacities that don't
  divide the block size, S == 1, capacity < DRAW_BLK. Integer outputs
  (draws, top-k indices) are bitwise-exact everywhere; on the default
  jnp backend the float by-products are bitwise too (shared
  materialisation), while the Pallas kernel's in-register recompute of
  p = exp(s/τ − m)/l may differ from a separate launch's epilogue by a
  few ulps (different XLA programs contract the chain differently), so
  drawn_p/p_max get allclose there.
* **Contract**: no O(S·Q·cap) output — a ``lower()``/``cost_analysis``
  guard pins the launch-boundary contract the bandwidth win rests on.
* **System**: the plan executor routes sampling/AKR/top-k through the
  fused launch (``fused_draw_launches``) with BOLT et al. falling back
  to dense scores, ``fused=False`` forces dense with identical results,
  and the int8 arena quantises at the append scatter, streams 4× fewer
  bytes per scan, and keeps top-k recall within drift bounds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.memory import quantise_rows
from repro.core.queryplan import QuerySpec
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import OracleEmbedder, PixelEmbedder, VideoWorld, \
    WorldConfig
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.draws import categorical_from_targets, draw_targets


@pytest.fixture(params=["jnp", "pallas"])
def backend(request):
    old = kops.backend()
    kops.set_backend(request.param)
    yield request.param
    kops.set_backend(old)


def _case(S, Q, N, d, T, K, valid_kind, seed, sizes=None, wins=None,
          dtype="float32"):
    ks = jax.random.split(jax.random.key(seed), 4)
    query = jax.random.normal(ks[0], (S, Q, d))
    index = jax.random.normal(ks[1], (S, N, d))
    if dtype == "int8":
        index = jnp.asarray(np.stack(
            [quantise_rows(np.asarray(index[s]))[0] for s in range(S)]))
    if valid_kind == "sizes":
        valid = jnp.asarray(sizes, jnp.int32)
    elif valid_kind == "wins":
        valid = jnp.asarray(wins, jnp.int32)
    else:
        valid = jax.random.uniform(ks[2], (S, N)) < 0.7
    tkeys = jax.random.split(ks[3], S * Q)
    targets = jnp.stack([draw_targets(k, T) for k in tkeys]
                        ).reshape(S, Q, T)
    return query, index, valid, targets


# the edge shapes: size-0 session, S==1, cap % DRAW_BLK != 0, ring
# window wrapping around capacity, cap < DRAW_BLK, int8 index rows
CASES = [
    dict(S=3, Q=2, N=512, d=32, T=8, K=4, valid_kind="mask", seed=0),
    dict(S=1, Q=1, N=200, d=16, T=6, K=3, valid_kind="sizes", seed=1,
         sizes=[0]),
    dict(S=3, Q=2, N=700, d=16, T=6, K=3, valid_kind="sizes", seed=2,
         sizes=[0, 700, 123]),
    dict(S=2, Q=2, N=300, d=16, T=5, K=2, valid_kind="wins", seed=3,
         wins=[[250, 120], [0, 300]]),
    dict(S=2, Q=1, N=100, d=8, T=4, K=2, valid_kind="mask", seed=4),
    dict(S=2, Q=2, N=512, d=32, T=8, K=4, valid_kind="mask", seed=5,
         dtype="int8"),
]


@pytest.mark.parametrize("case", CASES,
                         ids=[f"case{i}" for i in range(len(CASES))])
def test_fused_matches_materialised(backend, case):
    """Fused draws/top-k == the materialised scan + canonical chunked
    inverse-CDF + lax.top_k, per (s, q) lane, within one backend."""
    case = dict(case)
    tau, K = 0.1, case["K"]
    query, index, valid, targets = _case(**case)
    S, Q, N = case["S"], case["Q"], case["N"]

    fused = kops.fused_retrieve_stack(query, index, tau=tau, valid=valid,
                                      targets=targets, n_topk=K)
    sims, probs = kops.similarity_stack(query, index, tau=tau,
                                        valid=valid)
    vmask = ref.as_valid_mask(valid, N)
    for s in range(S):
        for q in range(Q):
            p0 = probs[s, q]
            draws = categorical_from_targets(p0, targets[s, q])
            np.testing.assert_array_equal(
                np.asarray(fused.draws[s, q]), np.asarray(draws))
            np.testing.assert_array_equal(
                np.asarray(fused.topk_i[s, q]),
                np.asarray(rt.topk_retrieve(sims[s, q], vmask[s], K)))
            dp = p0[draws]
            if backend == "jnp":     # shared materialisation: bitwise
                np.testing.assert_array_equal(
                    np.asarray(fused.drawn_p[s, q]), np.asarray(dp))
                np.testing.assert_array_equal(
                    float(fused.p_max[s, q, 0]), float(jnp.max(p0)))
            else:                    # separate programs: ulp-level drift
                np.testing.assert_allclose(
                    np.asarray(fused.drawn_p[s, q]), np.asarray(dp),
                    rtol=1e-5, atol=1e-8)
                np.testing.assert_allclose(
                    float(fused.p_max[s, q, 0]), float(jnp.max(p0)),
                    rtol=1e-5)


def test_fused_akr_stops_like_progressive(backend):
    """AKR over the fused outputs == akr_progressive over materialised
    probabilities, lane for lane (the stop rule consumes in-launch draw
    state — no re-scoring)."""
    case = dict(S=3, Q=2, N=512, d=32, T=16, K=1, valid_kind="mask",
                seed=7)
    query, index, valid, targets = _case(**case)
    fused = kops.fused_retrieve_stack(query, index, tau=0.1, valid=valid,
                                      targets=targets, n_topk=1)
    _, probs = kops.similarity_stack(query, index, tau=0.1, valid=valid)
    got = jax.vmap(jax.vmap(lambda d, p, pm: rt.akr_from_draws(
        d, p, pm, theta=0.9, beta=1.0, n_max=16)))(
            fused.draws, fused.drawn_p, fused.p_max[..., 0])
    for s in range(case["S"]):
        for q in range(case["Q"]):
            draws = categorical_from_targets(probs[s, q], targets[s, q])
            want = rt.akr_from_draws(
                draws, probs[s, q][draws].astype(jnp.float32),
                jnp.max(probs[s, q]), theta=0.9, beta=1.0, n_max=16)
            np.testing.assert_array_equal(np.asarray(got.draws[s, q]),
                                          np.asarray(want.draws))
            assert int(got.n_drawn[s, q]) == int(want.n_drawn)


def test_no_dense_output_in_fused_contract():
    """The launch-boundary contract the bandwidth win rests on: lowering
    the fused retrieval yields outputs totalling O(S·Q·(T+K)) elements —
    nothing O(S·Q·cap) crosses the boundary."""
    S, Q, N, d, T, K = 2, 3, 2048, 32, 8, 4
    fn = lambda q, x, v, t: kops.fused_retrieve_stack(
        q, x, tau=0.1, valid=v, targets=t, n_topk=K)
    args = (jax.ShapeDtypeStruct((S, Q, d), jnp.float32),
            jax.ShapeDtypeStruct((S, N, d), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.int32),
            jax.ShapeDtypeStruct((S, Q, T), jnp.float32))
    out = jax.eval_shape(fn, *args)
    n_out = sum(int(np.prod(o.shape))
                for o in jax.tree_util.tree_leaves(out))
    assert n_out == S * Q * (2 * T + 2 * K + 3)     # draws+dp+topk²+stats
    assert n_out < S * Q * N / 16                    # nowhere near dense

    lowered = jax.jit(fn).lower(*args)
    ca = lowered.cost_analysis() or {}
    out_bytes = [v for k, v in ca.items()
                 if k.startswith("bytes accessed output")]
    if out_bytes:    # backend reports per-output byte traffic: pin it
        assert max(out_bytes) < S * Q * N * 4 / 16


def _ingest(worlds, cfg, chunk=96):
    mgr = SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64)
    for sid, w in enumerate(worlds):
        mgr.create_session(sid)
        for i in range(0, w.total_frames, chunk):
            mgr.ingest_tick({sid: w.frames[i:i + chunk]})
    mgr.flush()
    return mgr


@pytest.fixture(scope="module")
def worlds():
    return [VideoWorld(WorldConfig(n_scenes=3 + s, seed=160 + s))
            for s in range(2)]


def _specs(worlds, strategy, budget=6, seed0=240):
    qsids = [0, 1, 0]
    qes = [OracleEmbedder(worlds[s], dim=64).embed_queries(
        worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)]
    return [QuerySpec(sid=s, embedding=qes[j], strategy=strategy,
                      budget=budget) for j, s in enumerate(qsids)]


def test_executor_routes_fused_vs_dense(worlds):
    """sampling/akr/topk groups cost fused launches (no dense score
    tensor); BOLT keeps the dense fallback; ``fused=False`` forces
    dense for everything."""
    mgr = _ingest(worlds, VenusConfig())
    specs = (_specs(worlds, "sampling") + _specs(worlds, "akr")
             + _specs(worlds, "topk"))
    plan = mgr.plan(specs)
    assert len(plan.groups) == 3
    kops.reset_scan_counts()
    mgr.execute(plan)
    c = kops.scan_counts()
    assert c["fused_draw_launches"] == 3
    assert c["dense_score_launches"] == 0
    assert c["similarity_stack"] == 3      # PR-3 invariant unchanged

    kops.reset_scan_counts()
    mgr.execute(mgr.plan(_specs(worlds, "bolt")))
    c = kops.scan_counts()
    assert (c["fused_draw_launches"], c["dense_score_launches"]) == (0, 1)

    kops.reset_scan_counts()
    mgr.execute(mgr.plan(_specs(worlds, "akr")), fused=False)
    c = kops.scan_counts()
    assert (c["fused_draw_launches"], c["dense_score_launches"]) == (0, 1)


@pytest.mark.parametrize("strategy", ["sampling", "akr", "topk"])
def test_fused_and_dense_paths_identical(worlds, strategy):
    """The escape hatch is an A/B switch, not a semantic fork: twin
    managers answering the same specs through the fused and the dense
    executor paths return identical draws and frame ids."""
    cfg = VenusConfig()
    mgr_f, mgr_d = _ingest(worlds, cfg), _ingest(worlds, cfg)
    specs = _specs(worlds, strategy)
    got_f = mgr_f.execute(mgr_f.plan(specs), fused=True)
    got_d = mgr_d.execute(mgr_d.plan(specs), fused=False)
    for a, b in zip(got_f, got_d):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        assert a.n_drawn == b.n_drawn


# ---------------------------------------------------------------------------
# int8 quantised index
# ---------------------------------------------------------------------------


def test_int8_arena_end_to_end(worlds):
    """cfg.index_dtype="int8": the arena stores int8 rows + f32 scales
    (written by the same tick scatter), queries run unchanged, and every
    scan streams 4× fewer index bytes than the fp32 twin."""
    mgr8 = _ingest(worlds, VenusConfig(index_dtype="int8"))
    mgr32 = _ingest(worlds, VenusConfig())
    assert mgr8.arena.emb.dtype == jnp.int8
    assert mgr8.arena.emb_scale.shape == mgr8.arena.emb.shape[:2]
    # scales cover exactly the occupied rows (zero rows keep scale 0
    # until written; written rows get scale > 0)
    for s in range(2):
        size = mgr8[s].memory.size
        assert np.all(np.asarray(mgr8.arena.emb_scale[s, :size]) > 0)

    specs = _specs(worlds, "akr")
    kops.reset_scan_counts()
    res8 = mgr8.query_specs(specs)
    b8 = kops.scan_counts()["scan_bytes"]
    kops.reset_scan_counts()
    res32 = mgr32.query_specs(specs)
    b32 = kops.scan_counts()["scan_bytes"]
    assert b32 == 4 * b8 and b8 > 0
    assert all(len(r.frame_ids) > 0 for r in res8)
    # int8 is lossy vs fp32 — but fused vs dense on the SAME int8 index
    # stays draw-for-draw identical (same buffer, same canonical CDF)
    mgr8b = _ingest(worlds, VenusConfig(index_dtype="int8"))
    res8b = mgr8b.execute(mgr8b.plan(specs), fused=False)
    for a, b in zip(res8, res8b):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
    del res32


def test_int8_slot_recycle_resets_scales(worlds):
    mgr = _ingest(worlds, VenusConfig(index_dtype="int8"))
    assert np.any(np.asarray(mgr.arena.emb_scale[0]) > 0)
    mgr.close_session(0)
    mgr.create_session(5)
    slot = mgr[5].memory.slot
    assert slot == 0                       # recycled, not grown
    assert np.all(np.asarray(mgr.arena.emb_scale[0]) == 0)


def test_int8_topk_recall_drift_bounded():
    """Quantisation is allowed to perturb ranks, not retrieval: on
    clustered data (the regime the index actually stores — cluster
    centroids), int8 top-k overlaps fp32 top-k ≥ 0.9 on average."""
    rng = np.random.default_rng(11)
    C, per, d, k = 8, 32, 64, 16
    centers = rng.standard_normal((C, d)).astype(np.float32)
    rows = np.repeat(centers, per, 0) + 0.15 * rng.standard_normal(
        (C * per, d)).astype(np.float32)
    q8 = jnp.asarray(quantise_rows(rows)[0])
    q32 = jnp.asarray(rows)
    valid = jnp.ones((rows.shape[0],), bool)
    overlaps = []
    for ci in range(C):
        query = jnp.asarray(centers[ci] + 0.05 * rng.standard_normal(d),
                            jnp.float32)[None]
        top32 = np.asarray(rt.topk_retrieve(
            kops.similarity(query, q32, tau=0.1, valid=valid)[0][0],
            valid, k))
        top8 = np.asarray(rt.topk_retrieve(
            kops.similarity(query, q8, tau=0.1, valid=valid)[0][0],
            valid, k))
        overlaps.append(len(set(top32) & set(top8)) / k)
    assert np.mean(overlaps) >= 0.9, overlaps
