"""Venus core behaviour: scene segmentation, clustering, memory,
retrieval (Eq. 1–7) and the end-to-end claims on synthetic ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.clustering import cluster_partition, frame_vectors
from repro.core.memory import FrameStore, VenusMemory
from repro.core.pipeline import VenusConfig, VenusSystem
from repro.core.scene import StreamSegmenter, scene_scores, segment
from repro.data.video import OracleEmbedder, VideoWorld, WorldConfig


# ---------------------------------------------------------------------------
# scene segmentation
# ---------------------------------------------------------------------------


def test_segment_boundaries_at_threshold():
    phi = jnp.asarray([0.0, 0.01, 0.5, 0.02, 0.02, 0.9, 0.01])
    boundary, part_id, carry = segment(phi, threshold=0.1,
                                       max_partition_len=100)
    assert np.asarray(boundary).tolist() == [True, False, True, False,
                                             False, True, False]
    assert np.asarray(part_id).tolist() == [0, 0, 1, 1, 1, 2, 2]


def test_segment_max_partition_rule():
    phi = jnp.zeros((10,))
    boundary, part_id, _ = segment(phi, threshold=0.5, max_partition_len=4)
    # static stream still cuts every max_partition_len frames
    assert np.asarray(part_id).max() >= 1


def test_stream_segmenter_matches_world_scenes():
    world = VideoWorld(WorldConfig(n_scenes=6, seed=1))
    seg = StreamSegmenter(threshold=0.075, max_partition_len=512)
    parts = []
    for i in range(0, world.total_frames, 50):
        parts += seg.ingest(jnp.asarray(world.frames[i:i + 50]))
    parts += seg.flush()
    starts = sorted(p.start for p in parts)
    true_starts = sorted(s.start for s in world.scenes)
    assert starts == true_starts
    assert parts[-1].end == world.total_frames


def test_stream_segmenter_chunk_invariance():
    world = VideoWorld(WorldConfig(n_scenes=4, seed=2))
    def run(chunk):
        seg = StreamSegmenter(threshold=0.075, max_partition_len=512)
        out = []
        for i in range(0, world.total_frames, chunk):
            out += seg.ingest(jnp.asarray(world.frames[i:i + chunk]))
        out += seg.flush()
        return [(p.start, p.end) for p in out]
    assert run(17) == run(64)


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------


def test_cluster_partition_groups_similar_frames():
    rng = np.random.default_rng(0)
    a = rng.random((1, 8)) + np.zeros((5, 8))
    b = rng.random((1, 8)) + 5.0 + np.zeros((4, 8))
    vecs = jnp.asarray(np.concatenate([a, b]) +
                       rng.normal(0, 0.01, (9, 8)))
    res = cluster_partition(vecs, threshold=1.0, max_clusters=8)
    assert int(res.n_clusters) == 2
    assign = np.asarray(res.assignments)
    assert len(set(assign[:5])) == 1 and len(set(assign[5:])) == 1
    assert assign[0] != assign[5]
    # index frames are members of their clusters
    for c in range(2):
        idx = int(res.index_frames[c])
        assert assign[idx] == c


def test_cluster_every_frame_assigned_and_within_capacity():
    vecs = jax.random.normal(jax.random.key(0), (33, 16)) * 10
    res = cluster_partition(vecs, threshold=0.1, max_clusters=4)
    assign = np.asarray(res.assignments)
    assert ((assign >= 0) & (assign < 4)).all()
    assert int(res.n_clusters) <= 4
    assert int(np.asarray(res.counts).sum()) == 33


def test_frame_vectors_pooling():
    frames = jnp.ones((3, 16, 16, 3))
    v = frame_vectors(frames, pool=8)
    assert v.shape == (3, 2 * 2 * 3)
    np.testing.assert_allclose(np.asarray(v), 1.0)


# ---------------------------------------------------------------------------
# memory
# ---------------------------------------------------------------------------


def test_memory_insert_search_roundtrip():
    mem = VenusMemory(capacity=64, dim=8, member_cap=16)
    e0 = np.eye(8, dtype=np.float32)[0]
    e1 = np.eye(8, dtype=np.float32)[1]
    i0 = mem.insert_cluster(e0, scene_id=0, index_frame=3,
                            member_frames=[0, 1, 2, 3])
    i1 = mem.insert_cluster(e1, scene_id=1, index_frame=7,
                            member_frames=[5, 6, 7])
    sims, probs = mem.search(jnp.asarray(e0)[None], tau=0.05)
    s = np.asarray(sims[0])
    assert s[i0] > 0.99 and abs(s[i1]) < 1e-5
    p = np.asarray(probs[0])
    assert p[:2].sum() > 0.999 and p[i0] > 0.99


def test_memory_member_reservoir_bounded():
    mem = VenusMemory(capacity=4, dim=4, member_cap=8)
    i = mem.insert_cluster(np.ones(4, np.float32), scene_id=0,
                           index_frame=0, member_frames=list(range(100)))
    frames = mem.expand_draws(np.asarray([i] * 20), np.ones(20, bool))
    assert len(frames) <= 8
    assert all(0 <= f < 100 for f in frames)


def test_memory_capacity_guard():
    mem = VenusMemory(capacity=1, dim=4)
    mem.insert_cluster(np.ones(4, np.float32), scene_id=0, index_frame=0,
                       member_frames=[0])
    with pytest.raises(RuntimeError):
        mem.insert_cluster(np.ones(4, np.float32), scene_id=0,
                           index_frame=1, member_frames=[1])


def test_frame_store():
    fs = FrameStore()
    fs.append(np.zeros((3, 4, 4, 3)))
    fs.append(np.ones((2, 4, 4, 3)))
    assert len(fs) == 5
    got = fs.get([0, 4])
    assert got.shape == (2, 4, 4, 3)
    assert got[1].max() == 1.0


# ---------------------------------------------------------------------------
# retrieval: Venus sampling vs Top-K (the paper's Fig. 5/10 claim)
# ---------------------------------------------------------------------------


def test_sampling_covers_dispersed_modes_topk_does_not():
    """Two relevant regions: one slightly stronger. Top-K (k=4) collapses
    onto the stronger one; sampling covers both (diversity)."""
    cap = 32
    sims = np.full((cap,), 0.1, np.float32)
    sims[0:4] = 0.95          # region A (stronger)
    sims[20:24] = 0.90        # region B
    valid = jnp.ones((cap,), bool)
    topk = np.asarray(rt.topk_retrieve(jnp.asarray(sims), valid, 4))
    assert set(topk).issubset(set(range(0, 4)))          # collapsed
    probs = jax.nn.softmax(jnp.where(valid, jnp.asarray(sims) / 0.05,
                                     -1e30))
    draws, counts = rt.sampling_retrieve(probs, jax.random.key(0), 16)
    picked = set(np.asarray(draws).tolist())
    assert picked & set(range(0, 4))
    assert picked & set(range(20, 24))                   # B covered too


def test_akr_narrow_vs_dispersed_budgets():
    """Peaked P ⇒ few draws; dispersed P ⇒ more draws (paper Fig. 9)."""
    cap = 64
    peaked = np.full((cap,), 1e-6, np.float32)
    peaked[5] = 1.0
    peaked /= peaked.sum()
    res_p = rt.akr_progressive(jnp.asarray(peaked), jax.random.key(0),
                               theta=0.9, n_max=32)
    dispersed = np.full((cap,), 1e-6, np.float32)
    dispersed[:16] = 1.0 / 16
    dispersed /= dispersed.sum()
    res_d = rt.akr_progressive(jnp.asarray(dispersed), jax.random.key(0),
                               theta=0.9, n_max=32)
    assert int(res_p.n_drawn) <= 3
    assert int(res_d.n_drawn) > int(res_p.n_drawn)
    assert float(res_d.mass) >= 0.9 or int(res_d.n_drawn) == 32


def test_end_to_end_oracle_world_coverage():
    world = VideoWorld(WorldConfig(n_scenes=8, seed=3))
    oe = OracleEmbedder(world, dim=64)
    system = VenusSystem(VenusConfig(), oe, embed_dim=64)
    for i in range(0, world.total_frames, 64):
        system.ingest(world.frames[i:i + 64])
    system.flush()
    assert system.stats["partitions"] == len(world.scenes)
    # far fewer embeddings than frames (the paper's ingestion claim)
    assert system.stats["frames_embedded"] < 0.25 * world.total_frames
    covs = []
    for q in world.make_queries(6, seed=9):
        qe = oe.embed_query(q)
        res = system.query(q.text, query_emb=qe)
        hit = {int(world.scene_of_frame[f]) for f in res.frame_ids}
        rel = set(q.relevant_scenes)
        covs.append(len(rel & hit) / len(rel))
    # absolute floor; the sampling-vs-Top-K relative claim is exercised on
    # the dense (vanilla) index in test_sampling_covers_dispersed_modes and
    # benchmarks/bench_fig10 — on a ~13-cluster index Top-K is trivially
    # diverse (Venus's own clustering removes the redundancy that breaks
    # greedy selection; see DESIGN.md)
    assert np.mean(covs) >= 0.6
