"""Unified query-plan API: QuerySpec → planner → fused executor.

Equivalence suite for the PR-3 tentpole invariant — every registered
retrieval strategy executed through the fused plan path (one
``similarity_scan_stack`` launch per execution group, vmapped
post-processing, device-side expansion) must match its direct
``retrieval.py`` call on identical inputs, for unequal session sizes
and the S=1 degenerate stack. Plus planner semantics (grouping,
validation, inspectability), the one-scan-per-group accounting at both
the manager and the kernel-dispatch layer, the seed policy, and the
``reset_io_stats`` helpers.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.queryplan import (QuerySpec, build_plan, get_strategy,
                                  strategies)
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)
from repro.kernels import ops as kops

ALL_STRATEGIES = ("sampling", "akr", "topk", "uniform", "bolt", "mdf",
                  "aks")
BUDGET = 6


def _ingest(worlds, chunk=96):
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sids = [mgr.create_session() for _ in worlds]
    for sid, w in zip(sids, worlds):
        for i in range(0, w.total_frames, chunk):
            mgr.ingest_tick({sid: w.frames[i:i + chunk]})
    mgr.flush()
    return mgr


@pytest.fixture(scope="module")
def setups():
    """(worlds, plan-path manager, direct-path manager) per S, built
    once — the equivalence tests consume both managers' PRNG chains in
    lockstep, so sharing them across strategies is sound."""
    cache = {}

    def get(n_sessions):
        if n_sessions not in cache:
            worlds = [VideoWorld(WorldConfig(n_scenes=3 + s, seed=60 + s))
                      for s in range(n_sessions)]
            cache[n_sessions] = (worlds, _ingest(worlds), _ingest(worlds))
        return cache[n_sessions]

    return get


def _query_embs(worlds, qsids, seed0=40):
    return np.stack([
        OracleEmbedder(worlds[s], dim=64).embed_queries(
            worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)])


def _direct_results(mgr, qsids, qes, strategy, budget):
    """The strategy's direct retrieval.py call per query, sessions in
    the executor's canonical order (sorted sid, arrival order within a
    session — the order the PRNG chains are consumed in)."""
    cfg = mgr.cfg
    order = {}
    for j, s in enumerate(qsids):
        order.setdefault(s, []).append(j)
    out = [None] * len(qsids)
    for s in sorted(order):
        st = mgr[s]
        for j in order[s]:
            emb, valid = st.memory.device_index()
            sims, probs = st.memory.search(jnp.asarray(qes[j])[None],
                                           tau=cfg.tau)
            sims0, probs0 = sims[0], probs[0]
            if strategy == "sampling":
                sub = st.next_keys(1)[0]
                draws, _ = rt.sampling_retrieve(probs0, sub, budget)
                draws = np.asarray(draws)
                fids = st.memory.expand_draws(
                    draws, np.ones(budget, bool), seed=cfg.seed)
            elif strategy == "akr":
                sub = st.next_keys(1)[0]
                res = rt.akr_progressive(probs0, sub, theta=cfg.theta,
                                         beta=cfg.beta, n_max=budget)
                draws = np.asarray(res.draws)
                fids = st.memory.expand_draws(
                    draws, np.asarray(res.valid), seed=cfg.seed)
            elif strategy == "topk":
                draws = np.asarray(rt.topk_retrieve(sims0, valid, budget))
                fids = st.memory.index_frames(draws)
            elif strategy == "uniform":
                draws = np.asarray(rt.uniform_retrieve(
                    st.stats["frames_seen"], budget))
                fids = draws
            elif strategy == "bolt":
                draws = np.asarray(rt.bolt_inverse_transform(
                    sims0, valid, budget, tau=cfg.tau))
                fids = st.memory.index_frames(draws)
            elif strategy == "mdf":
                draws = np.asarray(rt.mdf_retrieve(emb, valid, budget))
                fids = st.memory.index_frames(draws)
            elif strategy == "aks":
                draws = np.asarray(rt.aks_retrieve(sims0, valid, budget))
                fids = st.memory.index_frames(draws)
            else:
                raise AssertionError(strategy)
            out[j] = (draws, np.asarray(fids))
    return out


# ---------------------------------------------------------------------------
# every registry strategy: fused plan path == direct retrieval.py call
# ---------------------------------------------------------------------------


def test_registry_covers_all_retrieval_strategies():
    assert strategies() == tuple(sorted(ALL_STRATEGIES))


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("n_sessions,qsids", [
    (1, [0, 0]),                     # S=1: degenerate stack
    (3, [0, 1, 1, 2, 0]),            # S=3: unequal sizes + query counts
])
def test_strategy_plan_path_matches_direct(setups, strategy, n_sessions,
                                           qsids):
    worlds, mgr_plan, mgr_direct = setups(n_sessions)
    if n_sessions > 1:               # genuinely unequal session sizes
        assert len({mgr_plan[s].memory.size
                    for s in range(n_sessions)}) > 1
    qes = _query_embs(worlds, qsids, seed0=40 + 11 * len(strategy))

    specs = [QuerySpec(sid=s, embedding=qes[j], strategy=strategy,
                       budget=BUDGET) for j, s in enumerate(qsids)]
    plan = mgr_plan.plan(specs)
    assert len(plan.groups) == 1     # one strategy/budget ⇒ one group
    got = mgr_plan.execute(plan)
    want = _direct_results(mgr_direct, qsids, qes, strategy, BUDGET)

    for res, (draws, fids) in zip(got, want):
        np.testing.assert_array_equal(res.draws, draws)
        np.testing.assert_array_equal(res.frame_ids, fids)


# ---------------------------------------------------------------------------
# planner: grouping, validation, inspectability
# ---------------------------------------------------------------------------


def test_planner_groups_by_strategy_and_budget_class():
    e = np.zeros(8, np.float32)
    specs = [QuerySpec(sid=0, embedding=e, strategy="akr"),
             QuerySpec(sid=1, embedding=e, strategy="akr"),
             QuerySpec(sid=0, embedding=e, strategy="topk", budget=4),
             QuerySpec(sid=2, embedding=e, strategy="akr", budget=16),
             QuerySpec(sid=1, embedding=e, strategy="topk", budget=4)]
    plan = build_plan(specs, VenusConfig())
    assert plan.n_scans == len(plan.groups) == 3
    assert [g.key.strategy for g in plan.groups] == ["akr", "topk", "akr"]
    # same (strategy, budget class) fuses across sessions
    assert plan.groups[0].sids == (0, 1)
    assert plan.groups[1].sids == (0, 1)
    assert plan.groups[1].indices == [2, 4]
    # akr with an explicit n_max is a different budget class
    assert plan.groups[2].key.budget == 16
    assert "topk" in plan.describe()


def test_planner_parameter_overrides_split_groups():
    e = np.zeros(8, np.float32)
    specs = [QuerySpec(sid=0, embedding=e, strategy="akr"),
             QuerySpec(sid=0, embedding=e, strategy="akr", theta=0.5),
             QuerySpec(sid=0, embedding=e, strategy="akr", tau=0.2)]
    plan = build_plan(specs, VenusConfig())
    assert len(plan.groups) == 3
    assert {g.key.theta for g in plan.groups} == {0.9, 0.5}
    assert {g.key.tau for g in plan.groups} == {0.1, 0.2}


def test_planner_rejects_bad_specs():
    with pytest.raises(KeyError, match="unknown retrieval strategy"):
        build_plan([QuerySpec(sid=0, text="q", strategy="nope")],
                   VenusConfig())
    with pytest.raises(ValueError, match="text or embedding"):
        build_plan([QuerySpec(sid=0)], VenusConfig())
    with pytest.raises(KeyError):
        get_strategy("nope")


# ---------------------------------------------------------------------------
# acceptance: ONE similarity_scan_stack launch per execution group
# ---------------------------------------------------------------------------


def test_one_stack_launch_per_group_all_strategies(setups):
    """A mixed-strategy plan over 3 sessions: kernel-dispatch counters
    must show exactly len(groups) similarity_scan_stack launches, zero
    per-session 2-D scans, and zero host reservoir gathers."""
    worlds, mgr, _ = setups(3)
    qsids = [0, 1, 2, 0, 1, 2, 1]
    strat_of = [ALL_STRATEGIES[j % len(ALL_STRATEGIES)]
                for j in range(len(qsids))]
    qes = _query_embs(worlds, qsids, seed0=90)
    specs = [QuerySpec(sid=s, embedding=qes[j], strategy=strat_of[j],
                       budget=BUDGET) for j, s in enumerate(qsids)]
    plan = mgr.plan(specs)
    assert len(plan.groups) == len(set(strat_of))

    kops.reset_scan_counts()
    before = dict(mgr.io_stats)
    host_gathers = sum(mgr[s].memory.io_stats["host_expand_gathers"]
                       for s in range(3))
    results = mgr.execute(plan)
    counts = kops.scan_counts()
    assert counts["similarity_stack"] == len(plan.groups)
    assert counts["similarity"] == 0
    assert (mgr.io_stats["group_scans"]
            == before["group_scans"] + len(plan.groups))
    assert sum(mgr[s].memory.io_stats["host_expand_gathers"]
               for s in range(3)) == host_gathers
    assert all(r is not None and len(r.frame_ids) > 0 for r in results)


# ---------------------------------------------------------------------------
# seed policy: explicit seeds detach from the session PRNG chain
# ---------------------------------------------------------------------------


def test_fixed_seed_specs_leave_chain_untouched(setups):
    worlds, mgr_a, mgr_b = setups(1)
    qes = _query_embs(worlds, [0, 0], seed0=120)

    # two identical fixed-seed specs on mgr_a only: reproducible, and
    # the session chain must not advance
    spec = QuerySpec(sid=0, embedding=qes[0], strategy="akr", seed=7)
    r1 = mgr_a.query_specs([spec])[0]
    r2 = mgr_a.query_specs([spec])[0]
    np.testing.assert_array_equal(r1.draws, r2.draws)
    np.testing.assert_array_equal(r1.frame_ids, r2.frame_ids)

    # chain-policy follow-up still matches the twin manager that never
    # ran the seeded queries ⇒ the chain position is unchanged
    a = mgr_a.query(0, "", query_emb=qes[1])
    b = mgr_b.query(0, "", query_emb=qes[1])
    np.testing.assert_array_equal(a.draws, b.draws)
    np.testing.assert_array_equal(a.frame_ids, b.frame_ids)


# ---------------------------------------------------------------------------
# io_stats reset helpers
# ---------------------------------------------------------------------------


def test_reset_io_stats_manager_and_memory(setups):
    worlds, mgr, _ = setups(1)
    qes = _query_embs(worlds, [0], seed0=150)
    mgr.query(0, "", query_emb=qes[0])
    mem = mgr[0].memory
    assert any(v for v in mgr.io_stats.values())
    assert any(v for v in mem.io_stats.values())

    held_mgr, held_mem = mgr.io_stats, mem.io_stats
    mgr.reset_io_stats()
    assert all(v == 0 for v in mgr.io_stats.values())
    assert all(v == 0 for v in mem.io_stats.values())
    # dict identity preserved: held references observe the live counters
    assert mgr.io_stats is held_mgr and mem.io_stats is held_mem
    mgr.query(0, "", query_emb=qes[0])
    assert held_mgr["scans"] == 1 and held_mem["scans"] == 1
