"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All Pallas kernels run in interpret mode on CPU (the TPU path shares the
same kernel body)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import gqa_decode, mla_decode
from repro.kernels.scene_score import scene_score
from repro.kernels.similarity import similarity_scan, similarity_scan_stack


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,d,c,blk", [
    (1, 4, 4, 64, 128, 64),       # MHA
    (2, 8, 2, 64, 256, 64),       # GQA 4:1
    (2, 8, 1, 128, 192, 64),      # MQA, non-pow2 cache
    (3, 16, 4, 32, 64, 64),       # single block
])
def test_gqa_decode_matches_ref(dtype, b, h, hkv, d, c, blk):
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    k = jax.random.normal(ks[1], (b, c, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, c, hkv, d), dtype)
    lens = jax.random.randint(ks[3], (b, 1), 1, c + 1)
    valid = jnp.arange(c)[None] < lens
    out = gqa_decode(q, k, v, valid, scale=d ** -0.5, q_per_kv=h // hkv,
                     blk_s=blk)
    want = ref.decode_attention_ref(q, k, v, valid, scale=d ** -0.5,
                                    q_per_kv=h // hkv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_gqa_decode_softcap():
    ks = jax.random.split(jax.random.key(1), 3)
    b, h, d, c = 2, 4, 32, 128
    q = jax.random.normal(ks[0], (b, 1, h, d)) * 4
    k = jax.random.normal(ks[1], (b, c, h, d))
    v = jax.random.normal(ks[2], (b, c, h, d))
    valid = jnp.ones((b, c), bool)
    out = gqa_decode(q, k, v, valid, scale=0.3, softcap=20.0, blk_s=64)
    want = ref.decode_attention_ref(q, k, v, valid, scale=0.3, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,r,dr,c,blk", [
    (1, 8, 64, 16, 128, 64),
    (2, 16, 128, 64, 256, 128),
    (2, 4, 32, 16, 96, 32),       # non-pow2 cache
])
def test_mla_decode_matches_ref(dtype, b, h, r, dr, c, blk):
    ks = jax.random.split(jax.random.key(2), 5)
    qa = jax.random.normal(ks[0], (b, 1, h, r), dtype)
    qr = jax.random.normal(ks[1], (b, 1, h, dr), dtype)
    ckv = jax.random.normal(ks[2], (b, c, r), dtype)
    kr = jax.random.normal(ks[3], (b, c, dr), dtype)
    lens = jax.random.randint(ks[4], (b, 1), 1, c + 1)
    valid = jnp.arange(c)[None] < lens
    out = mla_decode(qa, qr, ckv, kr, valid, scale=0.1, blk_s=blk)
    want = ref.mla_decode_attention_ref(qa, qr, ckv, kr, valid, scale=0.1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("q,n,d,blk", [
    (1, 256, 64, 64),
    (4, 512, 128, 128),
    (2, 192, 32, 64),             # non-pow2 index
])
def test_similarity_matches_ref(dtype, q, n, d, blk):
    ks = jax.random.split(jax.random.key(3), 3)
    query = jax.random.normal(ks[0], (q, d), dtype)
    index = jax.random.normal(ks[1], (n, d), dtype)
    nvalid = int(jax.random.randint(ks[2], (), 1, n + 1))
    valid = jnp.arange(n) < nvalid
    sims, m, l = similarity_scan(query, index, valid, tau=0.07, blk_n=blk)
    want_s, want_p = ref.similarity_ref(query, index, tau=0.07, valid=valid)
    probs = jnp.exp(jnp.where(valid[None], sims / 0.07, -1e30) - m) / l
    np.testing.assert_allclose(np.asarray(sims, np.float32),
                               np.asarray(want_s, np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(probs), np.asarray(want_p),
                               rtol=1e-4, atol=1e-5)
    assert np.isclose(np.asarray(probs).sum(axis=-1), 1.0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,q,n,d,blk", [
    (1, 2, 256, 64, 64),          # S=1 degenerate stack
    (3, 4, 512, 128, 128),
    (2, 3, 192, 32, 64),          # non-pow2 capacity, divides blk
    (3, 2, 200, 16, 64),          # capacity NOT divisible by blk (pad)
    (2, 1, 100, 32, 64),          # ... with Q=1
])
def test_similarity_stack_matches_ref(dtype, s, q, n, d, blk):
    """3D cross-session scan vs the vmapped jnp oracle, including
    capacities the block size does not divide (wrapper pads with invalid
    lanes — they must not perturb sims or the softmax statistics)."""
    ks = jax.random.split(jax.random.key(6), 3)
    query = jax.random.normal(ks[0], (s, q, d), dtype)
    index = jax.random.normal(ks[1], (s, n, d), dtype)
    nvalid = jax.random.randint(ks[2], (s,), 1, n + 1)
    valid = jnp.arange(n)[None, :] < nvalid[:, None]
    sims, m, l = similarity_scan_stack(query, index, valid, tau=0.07,
                                       blk_n=blk)
    assert sims.shape == (s, q, n)
    want_s, want_p = ref.similarity_stack_ref(query, index, tau=0.07,
                                              valid=valid)
    probs = jnp.exp(jnp.where(valid[:, None], sims / 0.07, -1e30) - m) / l
    np.testing.assert_allclose(np.asarray(sims, np.float32),
                               np.asarray(want_s, np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(probs), np.asarray(want_p),
                               rtol=1e-4, atol=1e-5)
    assert np.isclose(np.asarray(probs).sum(axis=-1), 1.0).all()


@pytest.mark.parametrize("s,q,n,d,blk,empty", [
    (3, 2, 192, 16, 64, (1,)),     # size-0 middle session
    (4, 3, 128, 16, 64, (0, 2)),   # several size-0 sessions
    (1, 2, 64, 8, 64, (0,)),       # S==1 degenerate AND size 0
    (1, 1, 130, 8, 64, ()),        # S==1 degenerate, cap % blk != 0
    (3, 2, 200, 32, 64, ()),       # cap % blk != 0 (pad lanes), S>1
    (2, 1, 63, 16, 64, ()),        # capacity SMALLER than the block
])
def test_similarity_stack_edge_cases_match_ref(s, q, n, d, blk, empty):
    """Edge-case parity for the stacked scan: sessions with size == 0
    (their lane must yield the same degenerate softmax as the oracle),
    the S == 1 degenerate stack, and capacities the block size does not
    divide — Pallas kernel vs the jnp oracle, exact to float tolerance.
    (Size-0 lanes pair with block-divisible capacities: pad lanes enter
    the oracle-free denominator only when NO real entry dominates.)"""
    ks = jax.random.split(jax.random.key(11), 3)
    query = jax.random.normal(ks[0], (s, q, d))
    index = jax.random.normal(ks[1], (s, n, d))
    nvalid = np.array(jax.random.randint(ks[2], (s,), 1, n + 1))
    for e in empty:
        nvalid[e] = 0
    valid = jnp.arange(n)[None, :] < jnp.asarray(nvalid)[:, None]
    sims, m, l = similarity_scan_stack(query, index, valid, tau=0.07,
                                       blk_n=blk)
    want_s, want_p = ref.similarity_stack_ref(query, index, tau=0.07,
                                              valid=valid)
    probs = jnp.exp(jnp.where(valid[:, None], sims / 0.07, -1e30) - m) / l
    np.testing.assert_allclose(np.asarray(sims), np.asarray(want_s),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(probs), np.asarray(want_p),
                               rtol=1e-4, atol=1e-5)
    # size-0 lanes degenerate to the uniform distribution in both paths
    for e in empty:
        np.testing.assert_allclose(np.asarray(probs)[e], 1.0 / n,
                                   rtol=1e-5)


@pytest.mark.parametrize("s,n,cap_divides", [(3, 192, True),
                                             (2, 100, False)])
def test_similarity_stack_sizes_matches_mask(s, n, cap_divides):
    """The (S,) sizes form of ``valid`` (the arena path — masks derive
    on device from the sizes) must match the explicit (S, N) bool mask
    form bit-for-bit, on the Pallas kernel, the oracle, and the ops
    dispatch layer."""
    from repro.kernels import ops
    d, q = 16, 2
    ks = jax.random.split(jax.random.key(12), 3)
    query = jax.random.normal(ks[0], (s, q, d))
    index = jax.random.normal(ks[1], (s, n, d))
    sizes = jax.random.randint(ks[2], (s,), 0, n + 1)
    mask = jnp.arange(n)[None, :] < sizes[:, None]

    out_sizes = similarity_scan_stack(query, index, sizes.astype(jnp.int32),
                                      tau=0.1, blk_n=64)
    out_mask = similarity_scan_stack(query, index, mask, tau=0.1, blk_n=64)
    for a, b in zip(out_sizes, out_mask):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    ref_sizes = ref.similarity_stack_ref(query, index, tau=0.1,
                                         valid=sizes.astype(jnp.int32))
    ref_mask = ref.similarity_stack_ref(query, index, tau=0.1, valid=mask)
    for a, b in zip(ref_sizes, ref_mask):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    old = ops.backend()
    try:
        for backend in ("jnp", "pallas"):
            ops.set_backend(backend)
            s_a, p_a = ops.similarity_stack(query, index, tau=0.1,
                                            valid=sizes.astype(jnp.int32))
            s_b, p_b = ops.similarity_stack(query, index, tau=0.1,
                                            valid=mask)
            np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
            np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
    finally:
        ops.set_backend(old)


@pytest.mark.parametrize("s,n,windows", [
    # wrap-around ring / empty lane / full capacity seen from mid-ring
    (3, 192, [(180, 30), (0, 0), (5, 192)]),
    # capacity NOT divisible by blk (pad lanes) + wrapping window
    (2, 100, [(70, 60), (0, 40)]),
    # S==1 degenerate, full ring whose head sits on the last row
    (1, 64, [(63, 64)]),
    # boundary: window ends exactly at the wrap point (no actual wrap)
    (2, 128, [(100, 28), (127, 1)]),
])
def test_similarity_stack_windows_match_mask(s, n, windows):
    """The (S, 2) ``[start, size)`` ring-window form of ``valid`` (the
    eviction path — a sliding-window session's valid region wraps
    around capacity) must match the explicit (S, N) bool mask form
    bit-for-bit, on the Pallas kernel, the oracle, and the ops dispatch
    layer — including wrap-around windows, size-0 lanes, and
    full-capacity rings."""
    from repro.kernels import ops
    d, q = 16, 2
    ks = jax.random.split(jax.random.key(13), 2)
    query = jax.random.normal(ks[0], (s, q, d))
    index = jax.random.normal(ks[1], (s, n, d))
    wins = jnp.asarray(windows, jnp.int32)
    heads = np.asarray([w[0] for w in windows])
    sizes = np.asarray([w[1] for w in windows])
    mask = jnp.asarray(
        (np.arange(n)[None, :] - heads[:, None]) % n < sizes[:, None])

    out_win = similarity_scan_stack(query, index, wins, tau=0.1, blk_n=64)
    out_mask = similarity_scan_stack(query, index, mask, tau=0.1, blk_n=64)
    for a, b in zip(out_win, out_mask):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    ref_win = ref.similarity_stack_ref(query, index, tau=0.1, valid=wins)
    ref_mask = ref.similarity_stack_ref(query, index, tau=0.1, valid=mask)
    for a, b in zip(ref_win, ref_mask):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    old = ops.backend()
    try:
        for backend in ("jnp", "pallas"):
            ops.set_backend(backend)
            s_a, p_a = ops.similarity_stack(query, index, tau=0.1,
                                            valid=wins)
            s_b, p_b = ops.similarity_stack(query, index, tau=0.1,
                                            valid=mask)
            np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
            np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
    finally:
        ops.set_backend(old)


def test_window_form_generalises_sizes_form():
    """A ``[0, size)`` window IS the sizes form: ``as_valid_mask`` must
    yield identical masks for both, and a bool (S, 2) array must still
    be treated as an explicit mask (no dtype confusion at N == 2)."""
    sizes = jnp.asarray([0, 3, 7], jnp.int32)
    wins = jnp.stack([jnp.zeros_like(sizes), sizes], axis=1)
    np.testing.assert_array_equal(
        np.asarray(ref.as_valid_mask(sizes, 7)),
        np.asarray(ref.as_valid_mask(wins, 7)))
    bool_mask = jnp.asarray([[True, False], [False, True]])
    assert ref.as_valid_mask(bool_mask, 2) is bool_mask


def test_similarity_stack_lanes_match_2d_scan():
    """Each session lane of the stacked scan equals an independent 2D
    ``similarity_scan`` over that session's index."""
    ks = jax.random.split(jax.random.key(7), 3)
    s, q, n, d = 3, 2, 256, 32
    query = jax.random.normal(ks[0], (s, q, d))
    index = jax.random.normal(ks[1], (s, n, d))
    nvalid = jax.random.randint(ks[2], (s,), 1, n + 1)
    valid = jnp.arange(n)[None, :] < nvalid[:, None]
    sims3, m3, l3 = similarity_scan_stack(query, index, valid, tau=0.1,
                                          blk_n=64)
    for k in range(s):
        sims2, m2, l2 = similarity_scan(query[k], index[k], valid[k],
                                        tau=0.1, blk_n=64)
        np.testing.assert_allclose(np.asarray(sims3[k]),
                                   np.asarray(sims2), rtol=1e-6,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(m3[k]), np.asarray(m2),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(l3[k]), np.asarray(l2),
                                   rtol=1e-6, atol=1e-6)


def test_ops_similarity_stack_dispatch():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.key(8), 2)
    query = jax.random.normal(ks[0], (2, 3, 32))
    index = jax.random.normal(ks[1], (2, 100, 32))
    valid = jnp.arange(100)[None, :] < jnp.asarray([57, 100])[:, None]
    old = ops.backend()
    try:
        ops.set_backend("jnp")
        s_a, p_a = ops.similarity_stack(query, index, tau=0.1, valid=valid)
        ops.set_backend("pallas")
        s_b, p_b = ops.similarity_stack(query, index, tau=0.1, valid=valid)
    finally:
        ops.set_backend(old)
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_a), np.asarray(p_b),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("t,h,w", [(4, 16, 16), (7, 32, 24), (2, 8, 128)])
@pytest.mark.parametrize("weights", [(1.0, 1.0, 1.0, 2.0),
                                     (0.5, 2.0, 1.0, 0.0)])
def test_scene_score_matches_ref(t, h, w, weights):
    frames = jax.random.uniform(jax.random.key(4), (t, h, w, 3))
    phi = scene_score(frames, weights)
    want = ref.scene_score_ref(frames, weights)
    np.testing.assert_allclose(np.asarray(phi), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert float(phi[0]) == 0.0


def test_scene_score_detects_cut():
    a = jnp.zeros((3, 16, 16, 3)) + 0.2
    b = jnp.zeros((3, 16, 16, 3)) + 0.9
    frames = jnp.concatenate([a, b])
    phi = np.asarray(scene_score(frames, (1.0, 1.0, 1.0, 2.0)))
    assert phi[3] > 10 * max(phi[1], phi[2], phi[4], phi[5], 1e-9)


def test_ops_dispatch_backends():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    valid = jnp.ones((2, 64), bool)
    old = ops.backend()
    try:
        ops.set_backend("jnp")
        a = ops.decode_attention(q, k, v, valid, scale=0.2)
        ops.set_backend("pallas")
        b = ops.decode_attention(q, k, v, valid, scale=0.2)
    finally:
        ops.set_backend(old)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
