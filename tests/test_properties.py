"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import retrieval as rt
from repro.core.clustering import cluster_partition
from repro.core.scene import segment
from repro.kernels import ref

_settings = settings(max_examples=25, deadline=None)


@st.composite
def prob_vectors(draw, max_n=64):
    n = draw(st.integers(4, max_n))
    raw = draw(st.lists(st.floats(1e-4, 1.0), min_size=n, max_size=n))
    p = np.asarray(raw, np.float32)
    return p / p.sum()


@_settings
@given(probs=prob_vectors(),
       theta=st.floats(0.3, 0.95),
       n_max=st.integers(4, 48),
       seed=st.integers(0, 2**31 - 1))
def test_akr_invariants(probs, theta, n_max, seed):
    """AKR terminates; N_min ≤ draws ≤ N_max; at stop, either the Eq. 6
    mass threshold holds or N_max was hit."""
    res = rt.akr_progressive(jnp.asarray(probs), jax.random.key(seed),
                             theta=theta, beta=1.0, n_max=n_max)
    n = int(res.n_drawn)
    assert 1 <= n <= n_max
    assert n >= min(int(res.n_min), n_max)
    mass = float(res.mass)
    if n < n_max:
        assert mass >= theta - 1e-5
    draws = np.asarray(res.draws)
    valid = np.asarray(res.valid)
    assert valid.sum() == n
    assert ((draws[valid] >= 0) & (draws[valid] < len(probs))).all()
    # mass equals the sum of probs over the distinct drawn indices
    distinct = np.unique(draws[valid])
    np.testing.assert_allclose(mass, probs[distinct].sum(), rtol=1e-4,
                               atol=1e-5)


@_settings
@given(probs=prob_vectors(), n=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_sampling_counts_consistent(probs, n, seed):
    draws, counts = rt.sampling_retrieve(jnp.asarray(probs),
                                         jax.random.key(seed), n)
    counts = np.asarray(counts)
    assert counts.sum() == n
    assert (counts >= 0).all()
    d = np.asarray(draws)
    for i in np.unique(d):
        assert counts[i] == (d == i).sum()


@_settings
@given(st.data())
def test_similarity_probs_are_softmax(data):
    q = data.draw(st.integers(1, 3))
    n = data.draw(st.integers(4, 32))
    d = data.draw(st.sampled_from([8, 16]))
    nvalid = data.draw(st.integers(1, n))
    key = jax.random.key(data.draw(st.integers(0, 1000)))
    ks = jax.random.split(key, 2)
    query = jax.random.normal(ks[0], (q, d))
    index = jax.random.normal(ks[1], (n, d))
    valid = jnp.arange(n) < nvalid
    sims, probs = ref.similarity_ref(query, index, tau=0.1, valid=valid)
    p = np.asarray(probs)
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert (p[:, nvalid:] == 0).all() or nvalid == n
    s = np.asarray(sims)
    assert (s <= 1.0 + 1e-5).all() and (s >= -1.0 - 1e-5).all()


@_settings
@given(st.data())
def test_similarity_tau_monotonicity(data):
    """Lower temperature ⇒ the argmax entry's probability cannot drop."""
    n, d = 16, 8
    key = jax.random.key(data.draw(st.integers(0, 1000)))
    ks = jax.random.split(key, 2)
    query = jax.random.normal(ks[0], (1, d))
    index = jax.random.normal(ks[1], (n, d))
    valid = jnp.ones((n,), bool)
    _, p_hi = ref.similarity_ref(query, index, tau=0.5, valid=valid)
    _, p_lo = ref.similarity_ref(query, index, tau=0.05, valid=valid)
    top = int(np.argmax(np.asarray(p_hi)[0]))
    assert np.asarray(p_lo)[0, top] >= np.asarray(p_hi)[0, top] - 1e-6


@_settings
@given(st.data())
def test_clustering_invariants(data):
    t = data.draw(st.integers(2, 40))
    d = 8
    kmax = data.draw(st.integers(2, 8))
    thr = data.draw(st.floats(0.1, 5.0))
    key = jax.random.key(data.draw(st.integers(0, 1000)))
    vecs = jax.random.normal(key, (t, d))
    res = cluster_partition(vecs, threshold=thr, max_clusters=kmax)
    assign = np.asarray(res.assignments)
    n = int(res.n_clusters)
    assert 1 <= n <= kmax
    # every frame assigned to a live cluster
    assert ((assign >= 0) & (assign < n)).all()
    # counts match assignments
    counts = np.asarray(res.counts)
    for c in range(n):
        assert counts[c] == (assign == c).sum()
    assert counts[:n].sum() == t
    # index frames are members
    for c in range(n):
        assert assign[int(res.index_frames[c])] == c


@_settings
@given(st.data())
def test_segment_boundary_iff_rule(data):
    t = data.draw(st.integers(2, 64))
    thr = data.draw(st.floats(0.05, 0.5))
    maxlen = data.draw(st.integers(2, 16))
    key = jax.random.key(data.draw(st.integers(0, 1000)))
    phi = jax.random.uniform(key, (t,)) * 0.6
    boundary, part_id, _ = segment(phi, threshold=thr,
                                   max_partition_len=maxlen)
    b = np.asarray(boundary)
    p = np.asarray(phi)
    assert b[0]
    since = 1
    for i in range(1, t):
        want = (p[i] > thr) or (since >= maxlen)
        assert b[i] == want, i
        since = 1 if want else since + 1
    # partition ids are contiguous non-decreasing
    pid = np.asarray(part_id)
    assert (np.diff(pid) >= 0).all() and (np.diff(pid) <= 1).all()


@_settings
@given(st.data())
def test_kv_ring_buffer_consistency(data):
    """Decode attention over a ring-buffer window == attention over the
    explicit last-W tokens (order invariance of softmax)."""
    w = data.draw(st.sampled_from([4, 8]))
    total = data.draw(st.integers(1, 20))
    h, dim = 2, 16
    key = jax.random.key(data.draw(st.integers(0, 1000)))
    ks = jax.random.split(key, 3)
    keys = jax.random.normal(ks[0], (total, h, dim))
    vals = jax.random.normal(ks[1], (total, h, dim))
    q = jax.random.normal(ks[2], (1, 1, h, dim))
    # ring layout: token t at slot t % w
    kbuf = np.zeros((1, w, h, dim), np.float32)
    vbuf = np.zeros((1, w, h, dim), np.float32)
    for t in range(total):
        kbuf[0, t % w] = keys[t]
        vbuf[0, t % w] = vals[t]
    nvalid = min(total, w)
    valid = (jnp.arange(w) < nvalid)[None]
    out = ref.decode_attention_ref(q, jnp.asarray(kbuf), jnp.asarray(vbuf),
                                   valid, scale=0.25)
    # explicit window
    lo = max(0, total - w)
    ke = keys[lo:total][None]
    ve = vals[lo:total][None]
    out2 = ref.decode_attention_ref(q, ke, ve,
                                    jnp.ones((1, total - lo), bool),
                                    scale=0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# on-device reservoir expansion == seed-style loop reference
# ---------------------------------------------------------------------------


@st.composite
def member_memories(draw):
    """A VenusMemory with random member reservoirs — including empty
    reservoirs and clusters at the member_cap bound."""
    from repro.core.memory import VenusMemory
    cap = draw(st.integers(4, 32))
    mcap = draw(st.sampled_from([4, 8, 16]))
    n_clusters = draw(st.integers(1, cap))
    sizes = draw(st.lists(st.integers(0, 2 * mcap), min_size=n_clusters,
                          max_size=n_clusters))
    mem = VenusMemory(capacity=cap, dim=4, member_cap=mcap, seed=0)
    base = 0
    for i, m in enumerate(sizes):
        mem.insert_cluster(np.ones(4, np.float32), scene_id=0,
                           index_frame=base,
                           member_frames=list(range(base, base + m)))
        base += max(m, 1)
    return mem


@_settings
@given(mem=member_memories(), data=st.data())
def test_expand_draws_device_matches_loop(mem, data):
    """The jit'd device gather over the device-resident members table is
    draw-for-draw equal to the seed-style host loop — random draws and
    valid masks, empty reservoirs, and negative (padding-slot) draws."""
    n = data.draw(st.integers(0, 40))
    draws = np.asarray(data.draw(st.lists(
        st.integers(-2, mem.capacity - 1), min_size=n, max_size=n)),
        np.int64)
    valid = np.asarray(data.draw(st.lists(st.booleans(), min_size=n,
                                          max_size=n)), bool)
    seed = data.draw(st.integers(0, 2**31 - 1))
    got = mem.expand_draws_device(draws, valid, seed=seed)
    want = mem._expand_draws_loop(draws, valid, seed=seed)
    np.testing.assert_array_equal(got, want)
    # and the vectorised host path agrees too (shared variate sequence)
    np.testing.assert_array_equal(mem.expand_draws(draws, valid,
                                                   seed=seed), want)


@_settings
@given(mem=member_memories(), data=st.data())
def test_expand_draws_device_all_invalid_rows(mem, data):
    """All-invalid masks and empty draw vectors expand to nothing."""
    n = data.draw(st.integers(1, 16))
    draws = np.asarray(data.draw(st.lists(
        st.integers(0, mem.capacity - 1), min_size=n, max_size=n)),
        np.int64)
    seed = data.draw(st.integers(0, 1000))
    out = mem.expand_draws_device(draws, np.zeros(n, bool), seed=seed)
    assert out.size == 0
    out = mem.expand_draws_device(np.asarray([], np.int64),
                                  np.asarray([], bool), seed=seed)
    assert out.size == 0
