"""The two-tier host+disk ``FrameStore`` (ARCHITECTURE.md "Storage
tiers") and its session wiring:

* spill-on ``trim`` is a DEMOTION — dropped frames land in npy segment
  files and ``get`` faults them back bit-identically through the LRU
  segment cache; spill-off keeps the historical delete-and-raise
  contract (pinned against an unbounded twin; the hypothesis property
  test over random append/trim/get sequences lives in
  ``tests/test_spill_properties.py``),
* ``VenusConfig(spill_dir=..., host_retain=...)`` bounds the HOST tier
  of ``eviction="none"`` sessions: ``_trim_archives`` demotes their
  cold frames, keeping ``retained <= host_retain`` while every
  historical absolute id stays readable (the 24/7 RSS-leak fix),
* ``cluster_merge``'s folded-reservoir ids and ``uniform``-strategy
  reads succeed from disk after the host window moved past them,
* ``close_session`` releases BOTH tiers — churned sessions leak
  neither RSS nor disk (usage returns to baseline),
* ``build_plan`` rejects ``uniform`` against window-evicting sessions
  up front when spill is off (deep ``IndexError`` otherwise), and
  accepts it again when spill is on,
* ``VenusService.io_stats()`` accounts for every demotion and fault
  (``spilled_frames``/``spilled_bytes``/``spill_faults``/
  ``spill_cache_hits`` + the ``spill_disk_bytes`` gauge).
"""

import os

import numpy as np
import pytest

from repro.core.memory import FrameStore
from repro.core.queryplan import QuerySpec, build_plan
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import PixelEmbedder, VideoWorld, WorldConfig
from repro.serving.venus_service import VenusService

CHUNK = 32


def _worlds(n):
    return [VideoWorld(WorldConfig(n_scenes=4 + s, seed=50 + s))
            for s in range(n)]


def _mgr(cfg):
    return SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64)


def _chunk_at(w, t, chunk=CHUNK):
    lo = (t * chunk) % max(w.total_frames - chunk, 1)
    return np.asarray(w.frames[lo:lo + chunk], np.float32)


def _disk_usage(root) -> int:
    total = 0
    for d, _, files in os.walk(root):
        for f in files:
            total += os.path.getsize(os.path.join(d, f))
    return total


# ---------------------------------------------------------------- unit tier


def test_spill_roundtrip_bit_identical(tmp_path):
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=4,
                    cache_segments=2)
    twin = FrameStore()                     # unbounded single-tier twin
    rng = np.random.default_rng(0)
    for _ in range(5):
        chunk = rng.standard_normal((7, 4, 4, 3)).astype(np.float32)
        fs.append(chunk)
        twin.append(chunk)
        fs.trim(len(fs) - 6)
    assert fs.retained == 6 and len(fs) == len(twin) == 35
    assert fs.base == 29 and fs.spill_floor == 0
    ids = list(range(len(fs)))
    assert fs.get(ids).tobytes() == twin.get(ids).tobytes()
    # demotion accounting: everything that left the host was spilled
    assert fs.io_stats["spilled_frames"] == fs.trimmed == 29
    assert fs.io_stats["spilled_bytes"] == fs.disk_bytes > 0


def test_segment_chunking_and_sync(tmp_path):
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    fs.append(np.arange(10 * 12, dtype=np.float32).reshape(10, 2, 2, 3))
    fs.trim(10)
    # 10 demoted frames chunk into ceil(10/4) = 3 append-only segments
    segs = sorted(os.listdir(tmp_path / "s0"))
    assert len(segs) == 3 and all(s.endswith(".npy") for s in segs)
    assert fs.sync() == 3                   # first sync flushes all 3
    assert fs.sync() == 0                   # nothing new -> no-op
    fs.trim(10)                             # no-op trim spills nothing
    assert fs.sync() == 0


def test_lru_cache_hit_and_fault_counters(tmp_path):
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=2,
                    cache_segments=1)
    fs.append(np.arange(8 * 12, dtype=np.float32).reshape(8, 2, 2, 3))
    fs.trim(6)                              # segments [0,2) [2,4) [4,6)
    fs.get([0])                             # fault seg0
    fs.get([1])                             # hit   seg0
    fs.get([2])                             # fault seg1 (evicts seg0)
    fs.get([0])                             # fault seg0 again
    assert fs.io_stats["spill_faults"] == 3
    assert fs.io_stats["spill_cache_hits"] == 1


def test_reopen_recovers_intact_segments(tmp_path):
    """A clean reopen adopts every on-disk segment: the store resumes
    at the spilled base and faults the history back bit-identically."""
    frames = np.random.default_rng(1).standard_normal(
        (16, 2, 2, 3)).astype(np.float32)
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    fs.append(frames)
    fs.trim(12)
    fs.sync()
    fs2 = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    assert fs2.recovered_frames == 12 and fs2.dropped_segments == 0
    assert fs2.base == len(fs2) == 12 and fs2.spill_floor == 0
    assert fs2.get(list(range(12))).tobytes() == frames[:12].tobytes()


def test_reopen_detects_truncated_segment(tmp_path):
    """Crash mid-write: the NEWEST segment file is truncated to half
    its bytes. The next open must detect it — adopt only the intact
    prefix, delete the short file, and fail reads past the recovered
    base — instead of returning garbage frames."""
    frames = np.random.default_rng(2).standard_normal(
        (16, 2, 2, 3)).astype(np.float32)
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    fs.append(frames)
    fs.trim(12)                             # segments [0,4) [4,8) [8,12)
    fs.sync()
    segs = sorted(os.listdir(tmp_path / "s0"))
    newest = tmp_path / "s0" / segs[-1]
    with open(newest, "r+b") as f:
        f.truncate(os.path.getsize(newest) // 2)
    fs2 = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    assert fs2.recovered_frames == 8 and fs2.dropped_segments == 1
    assert fs2.base == len(fs2) == 8
    assert not newest.exists()              # short file cleaned up
    assert fs2.get(list(range(8))).tobytes() == frames[:8].tobytes()
    with pytest.raises(IndexError):
        fs2.get([9])
    # the store is fully live again: appends resume at the new base
    fs2.append(frames[:2])
    assert len(fs2) == 10
    assert fs2.get([8, 9]).tobytes() == frames[:2].tobytes()


def test_reopen_ignores_gapped_and_foreign_files(tmp_path):
    """Recovery adopts the longest contiguous prefix only: a segment
    whose start doesn't tile onto the previous end (a gap) is dropped
    along with everything after it; non-segment files are ignored."""
    frames = np.random.default_rng(3).standard_normal(
        (12, 2, 2, 3)).astype(np.float32)
    fs = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    fs.append(frames)
    fs.trim(12)
    fs.sync()
    segs = sorted(os.listdir(tmp_path / "s0"))
    os.remove(tmp_path / "s0" / segs[1])    # gap at [4,8)
    (tmp_path / "s0" / "notes.txt").write_text("not a segment")
    fs2 = FrameStore(str(tmp_path / "s0"), segment_frames=4)
    assert fs2.recovered_frames == 4 and fs2.dropped_segments == 1
    assert fs2.get([0, 1, 2, 3]).tobytes() == frames[:4].tobytes()
    assert (tmp_path / "s0" / "notes.txt").exists()  # not ours: kept


def test_spill_off_contract_unchanged():
    fs = FrameStore()
    fs.append(np.ones((5, 2, 2, 3), np.float32))
    fs.trim(3)
    assert fs.spill_floor == fs.base == 3 and fs.trimmed == 3
    with pytest.raises(IndexError, match="trimmed from the archive"):
        fs.get([2])
    assert fs.sync() == 0                   # no spill tier -> no-op
    assert fs.disk_bytes == 0
    assert fs.io_stats["spilled_frames"] == 0


def test_close_releases_disk(tmp_path):
    spill = tmp_path / "s0"
    fs = FrameStore(str(spill), segment_frames=2)
    fs.append(np.ones((6, 2, 2, 3), np.float32))
    fs.trim(4)
    fs.get([0])
    assert fs.disk_bytes > 0 and os.path.exists(spill)
    fs.close()
    assert fs.disk_bytes == 0 and fs.retained == 0
    assert not os.path.exists(spill)
    fs.close()                              # idempotent
    # counters survive close, for the manager's closed-session fold
    assert fs.io_stats["spilled_frames"] == 4


def test_config_validation(tmp_path):
    with pytest.raises(ValueError, match="requires spill_dir"):
        VenusConfig(host_retain=64)
    with pytest.raises(ValueError, match="host_retain must be >= 1"):
        VenusConfig(spill_dir=str(tmp_path), host_retain=0)
    with pytest.raises(ValueError, match="spill_segment_frames"):
        VenusConfig(spill_segment_frames=0)
    with pytest.raises(ValueError, match="spill_cache_segments"):
        VenusConfig(spill_cache_segments=-1)
    VenusConfig(spill_dir=str(tmp_path), host_retain=64)  # valid


# ----------------------------------------------------------- session tier


def test_none_session_host_retain_bounded_and_bit_identical(tmp_path):
    """The acceptance criterion: an ``eviction="none"`` session
    ingesting >= 4x ``host_retain`` frames keeps ``retained`` within
    budget while EVERY historical absolute id reads back bit-identical
    to an unbounded twin, with the counters accounting for every
    demotion and fault and zero restacks throughout."""
    retain = 48
    cfg = VenusConfig(max_partition_len=32, spill_dir=str(tmp_path),
                      host_retain=retain, spill_segment_frames=16)
    mgr = _mgr(cfg)
    sid = mgr.create_session()              # eviction="none" (default)
    assert mgr[sid].memory.eviction.name == "none"
    w = _worlds(1)[0]
    twin = FrameStore()
    t = 0
    while len(twin) < 4 * retain:
        c = _chunk_at(w, t)
        t += 1
        twin.append(c)
        mgr.ingest_tick({sid: c})
        assert mgr[sid].frames.retained <= retain
    fs = mgr[sid].frames
    assert len(fs) == len(twin) >= 4 * retain
    assert fs.retained <= retain
    # every demotion accounted for
    assert (fs.io_stats["spilled_frames"] == fs.trimmed
            == len(fs) - fs.retained > 0)
    # any historical id: bit-identical to the unbounded twin
    ids = list(range(len(fs)))
    assert fs.get(ids).tobytes() == twin.get(ids).tobytes()
    # every fault accounted for: each spilled-id read was either a
    # segment load or a cache hit
    assert (fs.io_stats["spill_faults"] + fs.io_stats["spill_cache_hits"]
            == fs.trimmed)
    assert fs.io_stats["spill_faults"] >= 1
    assert mgr.io_stats["stack_rebuilds"] == 0
    assert mgr.io_stats["archive_trimmed_frames"] == fs.trimmed


def test_cluster_merge_folded_reservoirs_fault_from_disk(tmp_path):
    """Under ``cluster_merge`` + an aggressive ``host_retain``, folded
    member reservoirs reference frames the host tier demoted; their
    reads must fault from disk bit-identically (spill-off would raise
    here), including on a RECYCLED arena slot."""
    cfg = VenusConfig(max_partition_len=32, memory_capacity=16,
                      eviction="cluster_merge", spill_dir=str(tmp_path),
                      host_retain=40, spill_segment_frames=8)
    mgr = _mgr(cfg)
    w = _worlds(1)[0]

    def drive(sid):
        twin = FrameStore()
        for t in range(8):
            c = _chunk_at(w, t)
            twin.append(c)
            mgr.ingest_tick({sid: c})
        fs = mgr[sid].frames
        lo = mgr[sid].memory.min_live_frame()
        # the demotion horizon passed live reservoir references — the
        # exact situation that used to IndexError
        assert lo < fs.base, (lo, fs.base)
        assert fs.get([lo]).tobytes() == twin.get([lo]).tobytes()
        # a members-expanding query's frame ids all read back fine
        res = mgr.query(sid, "anything",
                        query_emb=np.full(64, 0.125, np.float32))
        got = fs.get(res.frame_ids)
        assert got.tobytes() == twin.get(res.frame_ids).tobytes()
        return fs

    fs = drive(mgr.create_session())
    assert fs.io_stats["spill_faults"] >= 1
    mgr.close_session(0)
    sid2 = mgr.create_session()             # recycles the arena slot
    assert mgr.arena.io_stats["slot_reuses"] == 1
    drive(sid2)
    assert mgr.io_stats["stack_rebuilds"] == 0


def test_churn_disk_usage_returns_to_baseline(tmp_path):
    """create -> ingest -> close churn leaks neither RSS nor disk:
    ``close_session`` drops the host FrameStore AND deletes the spill
    segments, so disk usage under ``spill_dir`` returns to baseline
    after every close."""
    cfg = VenusConfig(max_partition_len=32, spill_dir=str(tmp_path),
                      host_retain=32, spill_segment_frames=8)
    mgr = _mgr(cfg)
    w = _worlds(1)[0]
    assert _disk_usage(tmp_path) == 0
    for r in range(3):
        sid = mgr.create_session()
        twin = FrameStore()
        for t in range(5):
            c = _chunk_at(w, t)
            twin.append(c)
            mgr.ingest_tick({sid: c})
        fs = mgr[sid].frames
        assert fs.disk_bytes > 0 and _disk_usage(tmp_path) > 0
        ids = list(range(len(fs)))
        assert fs.get(ids).tobytes() == twin.get(ids).tobytes()
        mgr.close_session(sid)
        assert _disk_usage(tmp_path) == 0   # baseline restored
    assert mgr.io_stats["sessions_closed"] == 3
    # the closed sessions' spill counters survived the closes
    assert mgr.closed_frame_stats["spilled_frames"] > 0


def test_uniform_rejected_without_spill_legal_with(tmp_path):
    w = _worlds(1)[0]
    # window eviction + no spill: rejected at PLAN time, naming the
    # session and its policy
    mgr = _mgr(VenusConfig(max_partition_len=32, memory_capacity=16,
                           eviction="sliding_window"))
    sid = mgr.create_session()
    mgr.ingest_tick({sid: _chunk_at(w, 0)})
    with pytest.raises(ValueError) as ei:
        mgr.plan([QuerySpec(sid=sid, text="x", strategy="uniform")])
    assert f"session {sid}" in str(ei.value)
    assert "sliding_window" in str(ei.value)
    # eviction="none": legal (nothing is ever trimmed)
    mgr2 = _mgr(VenusConfig(max_partition_len=32))
    sid2 = mgr2.create_session()
    mgr2.ingest_tick({sid2: _chunk_at(w, 0)})
    mgr2.plan([QuerySpec(sid=sid2, text="x", strategy="uniform")])
    # window eviction + spill: legal again — and the reads SUCCEED
    # from disk end-to-end
    mgr3 = _mgr(VenusConfig(max_partition_len=32, memory_capacity=16,
                            eviction="sliding_window",
                            spill_dir=str(tmp_path), host_retain=40))
    sid3 = mgr3.create_session()
    twin = FrameStore()
    for t in range(6):
        c = _chunk_at(w, t)
        twin.append(c)
        mgr3.ingest_tick({sid3: c})
    res = mgr3.query_specs([QuerySpec(
        sid=sid3, strategy="uniform", budget=8,
        embedding=np.full(64, 0.125, np.float32))])[0]
    fs = mgr3[sid3].frames
    assert fs.base > 0                      # history left the host tier
    got = fs.get(res.frame_ids)             # ...yet every draw reads
    assert got.tobytes() == twin.get(res.frame_ids).tobytes()
    # sessions= is optional: a bare build_plan still works (no gate)
    build_plan([QuerySpec(sid=sid, text="x", strategy="uniform")],
               mgr.cfg)


def test_service_io_stats_accounts_spill(tmp_path):
    cfg = VenusConfig(max_partition_len=32, spill_dir=str(tmp_path),
                      host_retain=32, spill_segment_frames=8)
    mgr = _mgr(cfg)
    svc = VenusService(mgr, engine=None)
    w = _worlds(1)[0]
    sid = mgr.create_session()
    for t in range(5):
        mgr.ingest_tick({sid: _chunk_at(w, t)})
    fs = mgr[sid].frames
    fs.get(list(range(len(fs))))
    stats = svc.io_stats()
    assert stats["spilled_frames"] == fs.trimmed > 0
    assert stats["spilled_bytes"] == fs.io_stats["spilled_bytes"] > 0
    assert stats["spill_faults"] == fs.io_stats["spill_faults"] >= 1
    assert stats["spill_cache_hits"] == fs.io_stats["spill_cache_hits"]
    assert stats["spill_disk_bytes"] == fs.disk_bytes > 0
    spilled_before_close = stats["spilled_frames"]
    mgr.close_session(sid)
    stats = svc.io_stats()
    # counters stay monotonic across the close; the disk gauge drops
    assert stats["spilled_frames"] == spilled_before_close
    assert stats["spill_disk_bytes"] == 0
