"""Shared fixtures: launch/transfer-counter isolation.

The kernel-dispatch scan counters (``kops.scan_counts()``) are module
globals and ``SessionManager``/``VenusMemory`` io_stats live as long as
their managers (including module-scoped fixture managers), so a test
asserting launch counts could historically be perturbed by whichever
tests ran before it. The autouse fixture below resets every counter
before each test, making launch-count assertions order-independent.
"""

import pytest

from repro.core import session as session_mod
from repro.kernels import ops as kops


@pytest.fixture(autouse=True)
def _isolate_launch_counters():
    """Fresh scan/transfer counters for every test: kernel-dispatch
    counts plus every live manager's (and its memories'/arena's)
    io_stats."""
    kops.reset_scan_counts()
    session_mod.reset_all_io_stats()
    yield
