"""Distributed (shard_map) memory must agree with the dense path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_memory import DistributedVenusMemory
from repro.kernels import ref
from repro.launch.mesh import make_host_mesh


def _mesh():
    return make_host_mesh(model=len(jax.devices()))


def test_distributed_search_matches_dense():
    mesh = _mesh()
    dim, n = 16, 48
    rng = np.random.default_rng(0)
    embs = rng.normal(0, 1, (n, dim)).astype(np.float32)
    mem = DistributedVenusMemory(64, dim, mesh, top_m=64)
    mem.insert(embs)
    q = rng.normal(0, 1, (dim,)).astype(np.float32)

    ids, probs = mem.search(q, tau=0.1)
    ids, probs = np.asarray(ids), np.asarray(probs)

    # dense reference over the same vectors
    sims, dense_probs = ref.similarity_ref(
        jnp.asarray(q)[None], jnp.asarray(embs), tau=0.1,
        valid=jnp.ones((n,), bool))
    dense_probs = np.asarray(dense_probs[0])

    got = {int(i): float(p) for i, p in zip(ids, probs)
           if np.isfinite(p) and int(i) < n and p > 0}
    for i, p in got.items():
        np.testing.assert_allclose(p, dense_probs[i], rtol=1e-4,
                                   atol=1e-5, err_msg=str(i))
    # the global argmax must be among the candidates
    assert int(np.argmax(dense_probs)) in got


def test_distributed_insert_capacity_and_ids():
    mesh = _mesh()
    mem = DistributedVenusMemory(8, 4, mesh, top_m=8)
    mem.insert(np.eye(4, dtype=np.float32))
    assert mem.size == 4
    # id round-trip
    for gid in range(8):
        io = mem.global_id_to_insert_order(gid)
        assert 0 <= io < 8
    with pytest.raises(RuntimeError):
        mem.insert(np.zeros((5, 4), np.float32))


def test_empty_index_returns_zero_mass():
    """REGRESSION: searching an empty (or all-invalid) index must return
    all-zero probabilities — a plain softmax over the all-(-1e30) masked
    logits would hand back a UNIFORM distribution over garbage candidate
    ids, and any sampler downstream would happily draw them."""
    mesh = _mesh()
    rng = np.random.default_rng(1)
    q = rng.normal(0, 1, (16,)).astype(np.float32)
    mem = DistributedVenusMemory(64, 16, mesh, top_m=8)
    ids, probs = mem.search(q, tau=0.1)
    probs = np.asarray(probs)
    assert probs.shape == np.asarray(ids).shape
    np.testing.assert_array_equal(probs, 0.0)      # nothing drawable
    # and the fix must not perturb the non-empty case: mass sums to 1
    mem.insert(rng.normal(0, 1, (5, 16)).astype(np.float32))
    _, probs = mem.search(q, tau=0.1)
    np.testing.assert_allclose(float(np.asarray(probs).sum()), 1.0,
                               rtol=1e-5)


def test_insert_scatter_is_capacity_independent():
    """REGRESSION: the insert scatter DONATES both sharded operands, so
    an insert moves O(rows·dim) bytes — never a copy of the whole
    (capacity, d) buffer. ``scatter_bytes`` counts exactly what crosses;
    identical inserts into a 16× larger memory must count identical
    bytes. (On CPU, XLA donation is a no-op copy under the hood, so the
    counter — not buffer identity — is the portable assertion.)"""
    mesh = _mesh()
    rng = np.random.default_rng(2)
    dim, n = 16, 8
    rows = rng.normal(0, 1, (n, dim)).astype(np.float32)
    small = DistributedVenusMemory(64, dim, mesh, top_m=8)
    large = DistributedVenusMemory(1024, dim, mesh, top_m=8)
    small.insert(rows)
    large.insert(rows)
    expect = n * (dim * 4 + 1 + 4)     # rows f32 + valid bool + pos i32
    assert small.io_stats["scatter_bytes"] == expect
    assert large.io_stats["scatter_bytes"] == expect
    assert small.io_stats["scatter_rows"] == n
    assert large.io_stats["inserts"] == 1
    # donation took effect on backends that support it: the pre-insert
    # buffers were consumed by the in-place update
    if jax.default_backend() != "cpu":
        emb0 = large._emb
        large.insert(rows)
        assert emb0.is_deleted()
