"""Distributed (shard_map) memory must agree with the dense path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_memory import DistributedVenusMemory
from repro.kernels import ref
from repro.launch.mesh import make_host_mesh


def _mesh():
    return make_host_mesh(model=len(jax.devices()))


def test_distributed_search_matches_dense():
    mesh = _mesh()
    dim, n = 16, 48
    rng = np.random.default_rng(0)
    embs = rng.normal(0, 1, (n, dim)).astype(np.float32)
    mem = DistributedVenusMemory(64, dim, mesh, top_m=64)
    mem.insert(embs)
    q = rng.normal(0, 1, (dim,)).astype(np.float32)

    ids, probs = mem.search(q, tau=0.1)
    ids, probs = np.asarray(ids), np.asarray(probs)

    # dense reference over the same vectors
    sims, dense_probs = ref.similarity_ref(
        jnp.asarray(q)[None], jnp.asarray(embs), tau=0.1,
        valid=jnp.ones((n,), bool))
    dense_probs = np.asarray(dense_probs[0])

    got = {int(i): float(p) for i, p in zip(ids, probs)
           if np.isfinite(p) and int(i) < n and p > 0}
    for i, p in got.items():
        np.testing.assert_allclose(p, dense_probs[i], rtol=1e-4,
                                   atol=1e-5, err_msg=str(i))
    # the global argmax must be among the candidates
    assert int(np.argmax(dense_probs)) in got


def test_distributed_insert_capacity_and_ids():
    mesh = _mesh()
    mem = DistributedVenusMemory(8, 4, mesh, top_m=8)
    mem.insert(np.eye(4, dtype=np.float32))
    assert mem.size == 4
    # id round-trip
    for gid in range(8):
        io = mem.global_id_to_insert_order(gid)
        assert 0 <= io < 8
    with pytest.raises(RuntimeError):
        mem.insert(np.zeros((5, 4), np.float32))
