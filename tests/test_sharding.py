"""Sharding rule tests on an abstract 16×16 production mesh (no devices
needed) + a real 1-device lowering of the serve path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch import sharding as shd
from repro.launch.mesh import make_abstract_mesh
from repro.launch.specs import adapt_config, input_specs, params_shape
from repro.configs.base import get_shape


def _mesh(multi=False):
    if multi:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


def _spec_of(specs, *path_parts):
    node = specs
    for p in path_parts:
        node = node[p]
    return node.spec


def test_attention_tp_fsdp_layout():
    cfg = registry.get_config("glm4-9b")
    ps = params_shape(cfg)
    specs = shd.param_specs(ps, _mesh(), mode="train")
    s = _spec_of(specs, "dense_blocks", "attn", "wq")
    assert s == P(None, ("data",), "model")      # (L, d, H*hd)
    s = _spec_of(specs, "dense_blocks", "attn", "wo")
    assert s == P(None, "model", ("data",))
    s = _spec_of(specs, "embed")
    assert s == P("model", None)     # vocab-parallel, d replicated (iter E)


def test_serve_mode_drops_fsdp():
    cfg = registry.get_config("glm4-9b")
    ps = params_shape(cfg)
    specs = shd.param_specs(ps, _mesh(), mode="serve")
    assert _spec_of(specs, "dense_blocks", "attn", "wq") == P(None, None,
                                                              "model")


def test_moe_expert_parallel():
    cfg = registry.get_config("olmoe-1b-7b")
    ps = params_shape(cfg)
    specs = shd.param_specs(ps, _mesh(), mode="train")
    s = _spec_of(specs, "moe_blocks", "moe", "w_gate")   # (L, E, d, ff)
    assert s == P(None, "model", ("data",), None)


def test_nondivisible_vocab_falls_back():
    cfg = registry.get_config("whisper-base")            # vocab 51865
    ps = params_shape(cfg)
    specs = shd.param_specs(ps, _mesh(), mode="train")
    assert _spec_of(specs, "embed") == P(None, None)


def test_multipod_fsdp_spans_pod_and_data():
    cfg = registry.get_config("deepseek-7b")
    ps = params_shape(cfg)
    specs = shd.param_specs(ps, _mesh(multi=True), mode="train")
    assert _spec_of(specs, "dense_blocks", "attn", "wq") == \
        P(None, ("pod", "data"), "model")


def test_kv_cache_head_vs_sequence_sharding():
    shape = get_shape("decode_32k")
    # glm4: kv=2 < 16 ⇒ sequence sharding
    cfg = adapt_config(registry.get_config("glm4-9b"), shape)
    cache = input_specs(cfg, shape)["cache"]
    specs = shd.cache_specs(cache, _mesh())
    assert specs["dense"]["k"].spec == P(None, ("data",), "model", None,
                                         None)
    # deepseek-7b: kv=32 ⇒ head sharding
    cfg = adapt_config(registry.get_config("deepseek-7b"), shape)
    cache = input_specs(cfg, shape)["cache"]
    specs = shd.cache_specs(cache, _mesh())
    assert specs["dense"]["k"].spec == P(None, ("data",), None, "model",
                                         None)


def test_long500k_policy():
    shape = get_shape("long_500k")
    # dense GQA gets the sliding-window variant
    cfg = adapt_config(registry.get_config("deepseek-7b"), shape)
    assert cfg.sliding_window == 8192
    # MLA keeps the full latent cache
    cfg = adapt_config(registry.get_config("deepseek-v2-lite-16b"), shape)
    assert cfg.sliding_window == 0
    cache = input_specs(cfg, shape)["cache"]
    assert cache["moe"]["ckv"].shape[2] == shape.seq_len
    # SSM native
    cfg = adapt_config(registry.get_config("rwkv6-1.6b"), shape)
    assert cfg.sliding_window == 0


def test_batch_specs_long500k_batch1_replicated():
    shape = get_shape("long_500k")
    tok = jax.ShapeDtypeStruct((1, 1), jnp.int32)
    spec = shd.batch_specs(tok, _mesh())
    assert spec.spec == P(None, None)    # batch 1 cannot shard over 16


def test_serve_step_lowers_on_host_mesh():
    """End-to-end plumbing: serve_step lowers + compiles on the real
    (1-device) host mesh with the same sharding code path."""
    from repro.launch.mesh import make_host_mesh
    from repro.serving.engine import make_serve_step
    cfg = registry.get_smoke_config("qwen2-vl-7b")
    mesh = make_host_mesh()
    from repro.models.transformer import Transformer
    m = Transformer(cfg)
    pshape = jax.eval_shape(m.init, jax.random.key(0))
    cache = jax.eval_shape(lambda: m.init_cache(4, 64, jnp.bfloat16))
    pspec = shd.param_specs(pshape, mesh, mode="serve")
    cspec = shd.cache_specs(cache, mesh)
    tspec = shd.batch_specs(jax.ShapeDtypeStruct((4, 1), jnp.int32), mesh)
    with mesh:
        step = make_serve_step(cfg)
        compiled = jax.jit(step, in_shardings=(pspec, tspec, cspec)).lower(
            pshape, jax.ShapeDtypeStruct((4, 1), jnp.int32), cache
        ).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # jax ≤0.4.x: one dict per device
        ca = ca[0]
    assert ca.get("flops", 0) > 0
