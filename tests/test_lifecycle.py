"""Session lifecycle for 24/7 streams: slot recycling + eviction.

Acceptance suite for the PR-5 tentpole — session memory is a full
lifecycle (create → ingest ⇄ query → evict → close → slot reuse):

* ``SessionManager.close_session`` frees the arena slot into a
  free-list; the next ``create_session`` recycles it after ONE donated
  device-side row reset — no arena reallocation, no restack, and the
  slot count holds at its steady-state maximum under churn.
* Sessions that hit ``memory_capacity`` with a window ``EvictionPolicy``
  become device-side rings: eviction is O(1) head motion plus in-place
  overwrite of the oldest rows, and every scan consumes a per-session
  ``(start, size)`` window.

Equivalence discipline: every close/reuse/evict interleaving must stay
draw-for-draw identical to a fresh manager replaying only the surviving
rows (for rings: the same rows at the same physical positions), on both
the arena and the detached path.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.memory import (ArenaStackView, VenusMemory,
                               get_eviction_policy)
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)

# max_partition_len < chunk forces ≥ 1 partition close per 64-frame
# tick, so every ingest tick grows (and, at capacity, evicts)
CFG = VenusConfig(max_partition_len=48)
# small capacity so a handful of ticks overflows it (~5 indexed rows
# close per 64-frame tick at max_partition_len=32)
EVICT_CFG = VenusConfig(max_partition_len=32, memory_capacity=16,
                        eviction="sliding_window")


def _worlds(n):
    return [VideoWorld(WorldConfig(n_scenes=4 + s, seed=20 + s))
            for s in range(n)]


def _manager(cfg, *, use_arena=True):
    return SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64,
                          use_arena=use_arena)


def _chunk(w, t, chunk=64):
    lo = (t * chunk) % max(w.total_frames - chunk, 1)
    return w.frames[lo:lo + chunk]


def _tick(mgr, stream_map, t):
    mgr.ingest_tick({sid: _chunk(w, t) for sid, w in stream_map.items()})


def _queries(worlds, qsids, seed0):
    return np.stack([
        OracleEmbedder(worlds[s], dim=64).embed_queries(
            worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)])


def _assert_same_results(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        assert a.n_drawn == b.n_drawn


# ---------------------------------------------------------------------------
# slot recycling
# ---------------------------------------------------------------------------


def test_close_session_recycles_slot():
    """close → create reuses the freed slot: free-list mechanics, zero
    growth, and a zero-reset device row block for the newcomer."""
    worlds = _worlds(3)
    mgr = _manager(CFG)
    sids = [mgr.create_session() for _ in range(3)]
    _tick(mgr, dict(zip(sids, worlds)), 0)
    arena = mgr.arena
    assert arena.n_sessions == 3 and arena.io_stats["grows"] == 3

    freed = mgr[sids[1]].memory.slot
    stats = mgr.close_session(sids[1])
    assert stats["frames_seen"] > 0
    assert arena.free_slots == [freed]
    assert arena.sizes[freed] == 0 and arena.heads[freed] == 0
    assert mgr.io_stats["sessions_closed"] == 1
    assert arena.io_stats["slot_releases"] == 1

    new_sid = mgr.create_session()
    assert new_sid not in sids
    assert mgr[new_sid].memory.slot == freed     # recycled, not grown
    assert arena.free_slots == []
    assert arena.n_sessions == 3                 # steady-state slots
    assert arena.io_stats["grows"] == 3          # NO new growth
    assert arena.io_stats["slot_reuses"] == 1
    # the donated reset zeroed the recycled rows
    np.testing.assert_array_equal(np.asarray(arena.emb[freed]), 0.0)
    np.testing.assert_array_equal(np.asarray(arena.member_count[freed]), 0)


def test_closed_memory_detaches_and_stays_readable():
    """A handle to a closed session's memory must not read recycled
    arena rows: the memory detaches to its own host mirrors and keeps
    answering identically."""
    worlds = _worlds(2)
    mgr = _manager(CFG)
    sids = [mgr.create_session() for _ in range(2)]
    _tick(mgr, dict(zip(sids, worlds)), 0)
    mem = mgr[sids[0]].memory
    emb_before = mem._emb.copy()
    size_before = mem.size
    q = _queries(worlds, [0], seed0=40)
    want_s, want_p = mem.search(jnp.asarray(q), tau=0.1)
    want_s, want_p = np.asarray(want_s), np.asarray(want_p)

    mgr.close_session(sids[0])
    assert mem.arena is None and mem.slot is None
    # new tenant overwrites the old slot's device rows...
    new_sid = mgr.create_session()
    _tick(mgr, {new_sid: worlds[1], sids[1]: worlds[1]}, 1)
    # ...but the detached handle still answers from its own mirrors
    got_s, got_p = mem.search(jnp.asarray(q), tau=0.1)
    assert mem.size == size_before
    np.testing.assert_array_equal(mem._emb, emb_before)
    np.testing.assert_allclose(np.asarray(got_s), want_s, rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_p), want_p, rtol=1e-6,
                               atol=1e-6)


def test_queries_with_free_slot_match_fresh_manager():
    """While a freed slot waits for reuse, the scan runs over the arena
    with a masked-out hole lane (``ArenaStackView``) — zero restacks,
    and results draw-for-draw equal a fresh manager that only ever had
    the surviving sessions."""
    worlds = _worlds(3)
    mgr = _manager(CFG)
    sids = [mgr.create_session() for _ in range(3)]
    for t in range(2):
        _tick(mgr, dict(zip(sids, worlds)), t)

    fresh = _manager(CFG)
    for sid in (sids[0], sids[2]):
        fresh.create_session(sid)
    for t in range(2):
        _tick(fresh, {sids[0]: worlds[0], sids[2]: worlds[2]}, t)

    mgr.close_session(sids[1])
    lanes = mgr.scan_lanes((sids[0], sids[2]))
    assert None in lanes                       # the hole is a real lane
    assert isinstance(mgr.memory_stack(lanes), ArenaStackView)

    qsids = [0, 2, 2]
    qes = _queries(worlds, qsids, seed0=60)
    mgr.reset_io_stats()
    got = mgr.query_batch_cross([sids[s] for s in qsids], query_embs=qes)
    want = fresh.query_batch_cross([sids[s] for s in qsids],
                                   query_embs=qes)
    _assert_same_results(got, want)
    assert mgr.io_stats["stack_rebuilds"] == 0


def test_close_reuse_matches_fresh_manager():
    """Full churn equivalence: close + recreate (slot recycled), then
    ingest + query — the churned manager must answer draw-for-draw like
    a fresh manager that replays only the surviving sessions' streams."""
    worlds = _worlds(4)
    mgr = _manager(CFG)
    sids = [mgr.create_session() for _ in range(3)]
    for t in range(2):
        _tick(mgr, dict(zip(sids, worlds[:3])), t)
    mgr.close_session(sids[1])
    new_sid = mgr.create_session()             # recycles slot 1
    streams = {sids[0]: worlds[0], sids[2]: worlds[2],
               new_sid: worlds[3]}
    _tick(mgr, streams, 2)

    fresh = _manager(CFG)
    for sid in (sids[0], sids[2], new_sid):
        fresh.create_session(sid)
    for t in range(2):
        _tick(fresh, {sids[0]: worlds[0], sids[2]: worlds[2]}, t)
    _tick(fresh, streams, 2)

    qsids = [0, 2, 3, 3]
    qes = _queries(worlds, qsids, seed0=70)
    tick_sids = [{0: sids[0], 2: sids[2], 3: new_sid}[s] for s in qsids]
    _assert_same_results(
        mgr.query_batch_cross(tick_sids, query_embs=qes),
        fresh.query_batch_cross(tick_sids, query_embs=qes))


# ---------------------------------------------------------------------------
# sliding-window eviction
# ---------------------------------------------------------------------------


def test_eviction_none_still_raises():
    mem = VenusMemory(capacity=8, dim=4, member_cap=2)
    rows = np.ones((8, 4), np.float32)
    mem.insert_batch(rows, scene_ids=[0] * 8, index_frames=list(range(8)),
                     member_lists=[[i] for i in range(8)])
    with pytest.raises(RuntimeError):
        mem.insert_batch(rows[:1], scene_ids=[0], index_frames=[8],
                         member_lists=[[8]])
    with pytest.raises(KeyError):
        get_eviction_policy("nonsense")


def test_oversized_batch_evicts_on_arrival():
    """A single batch larger than ``capacity`` must not crash an
    evicting session (24/7 streams never stop ingesting): only its
    newest ``capacity`` rows survive; the older ones count as evicted
    on arrival. The ``none`` policy keeps the historical raise."""
    rng = np.random.default_rng(7)
    cap, dim = 8, 4
    mem = VenusMemory(cap, dim, member_cap=2, eviction="sliding_window")
    n = cap + 5
    rows = rng.normal(0, 1, (n, dim)).astype(np.float32)
    mem.insert_batch(rows, scene_ids=[0] * n,
                     index_frames=list(range(n)),
                     member_lists=[[i] for i in range(n)])
    assert mem.size == cap
    assert mem.io_stats["evicted_rows"] == 5
    logical = (mem.head + np.arange(cap)) % cap
    np.testing.assert_array_equal(mem._index_frame[logical],
                                  np.arange(5, n))
    np.testing.assert_array_equal(
        mem._emb[logical], rows[5:])

    mem_none = VenusMemory(cap, dim, member_cap=2)
    with pytest.raises(RuntimeError):
        mem_none.insert_batch(rows, scene_ids=[0] * n,
                              index_frames=list(range(n)),
                              member_lists=[[i] for i in range(n)])


def test_ring_matches_fresh_physical_replay():
    """A ring past capacity == a fresh memory holding the same surviving
    rows at the same physical positions: scans, probs, and device
    expansion are draw-for-draw identical, and exactly the newest
    ``capacity`` rows survive."""
    rng = np.random.default_rng(0)
    cap, dim = 16, 8
    mem = VenusMemory(cap, dim, member_cap=4, eviction="sliding_window")
    fid = 0
    for n in (10, 7, 9, 5):                    # wraps twice
        rows = rng.normal(0, 1, (n, dim)).astype(np.float32)
        mem.insert_batch(rows, scene_ids=[0] * n,
                         index_frames=list(range(fid, fid + n)),
                         member_lists=[[i] for i in range(fid, fid + n)])
        fid += n
    assert mem.size == cap and mem.head != 0
    assert mem.io_stats["evicted_rows"] == fid - cap
    # survivors are exactly the newest `capacity` index frames, in
    # logical (window) order
    logical = (mem.head + np.arange(cap)) % cap
    np.testing.assert_array_equal(mem._index_frame[logical],
                                  np.arange(fid - cap, fid))

    twin = VenusMemory(cap, dim, member_cap=4)
    twin.insert_batch(
        mem._emb.copy(), scene_ids=mem._scene_id.tolist(),
        index_frames=mem._index_frame.tolist(),
        member_lists=[mem._members[i, :mem._member_count[i]].tolist()
                      for i in range(cap)])
    q = rng.normal(0, 1, (3, dim)).astype(np.float32)
    got = mem.search(jnp.asarray(q), tau=0.1)
    want = twin.search(jnp.asarray(q), tau=0.1)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    draws = np.asarray([0, 3, 15, 7])
    valid = np.ones(4, bool)
    np.testing.assert_array_equal(
        mem.expand_draws_device(draws, valid, seed=5),
        twin.expand_draws_device(draws, valid, seed=5))


def test_sliding_window_answers_from_last_capacity_frames():
    """ACCEPTANCE: a sliding-window session that outlives
    ``memory_capacity`` keeps ingesting forever and answers queries
    using only its last ``memory_capacity`` index frames."""
    worlds = _worlds(2)
    mgr = _manager(EVICT_CFG)
    sids = [mgr.create_session() for _ in range(2)]
    for t in range(8):                         # far past capacity
        _tick(mgr, dict(zip(sids, worlds)), t)
    for sid in sids:
        mem = mgr[sid].memory
        assert mem.size == EVICT_CFG.memory_capacity
        assert mem.io_stats["evicted_rows"] > 0
    surviving = {sid: set(
        int(f) for f in mgr[sid].memory._index_frame[
            (mgr[sid].memory.head
             + np.arange(mgr[sid].memory.size))
            % mgr[sid].memory.capacity])
        for sid in sids}
    qes = _queries(worlds, [0, 1], seed0=90)
    for j, sid in enumerate(sids):
        got = mgr.query_topk(sid, "", k=8, query_emb=qes[j])
        centroids = set(int(f) for f in got)
        assert centroids <= surviving[sid], \
            "top-k returned an evicted index frame"


def test_evicting_arena_matches_detached():
    """The detached path gets the same window semantics: an arena
    manager and a ``use_arena=False`` twin evict identically and stay
    draw-for-draw equal across post-eviction ingest/query rounds."""
    worlds = _worlds(3)
    mgr_a = _manager(EVICT_CFG, use_arena=True)
    mgr_d = _manager(EVICT_CFG, use_arena=False)
    sids = [mgr_a.create_session() for _ in range(3)]
    for _ in range(3):
        mgr_d.create_session()
    for t in range(8):
        _tick(mgr_a, dict(zip(sids, worlds)), t)
        _tick(mgr_d, dict(zip(sids, worlds)), t)
        qsids = [0, 1, 2, 1]
        qes = _queries(worlds, qsids, seed0=100 + 11 * t)
        _assert_same_results(
            mgr_a.query_batch_cross(qsids, query_embs=qes),
            mgr_d.query_batch_cross(qsids, query_embs=qes))
    for sid in sids:
        assert mgr_a[sid].memory.io_stats["evicted_rows"] > 0
        assert (mgr_a[sid].memory.window
                == mgr_d[sid].memory.window)
    assert mgr_a.io_stats["stack_rebuilds"] == 0


def test_cluster_merge_folds_reservoirs():
    """cluster_merge eviction: an evictee similar to a survivor donates
    its member reservoir before leaving the window; a dissimilar one is
    dropped like plain sliding-window."""
    rng = np.random.default_rng(3)
    cap, dim = 4, 8
    mem = VenusMemory(cap, dim, member_cap=8, eviction="cluster_merge")
    base = rng.normal(0, 1, (dim,)).astype(np.float32)
    other = rng.normal(0, 1, (dim,)).astype(np.float32)
    # row 0: evictee; row 2: near-duplicate survivor; rows 1/3: far away
    rows = np.stack([base, other, base + 1e-3, -other]).astype(np.float32)
    mem.insert_batch(rows, scene_ids=[0] * 4,
                     index_frames=[10, 11, 12, 13],
                     member_lists=[[10, 100], [11], [12], [13]])
    mem.insert_batch(rng.normal(0, 1, (1, dim)).astype(np.float32),
                     scene_ids=[1], index_frames=[14],
                     member_lists=[[14]])
    assert mem.io_stats["evicted_rows"] == 1
    assert mem.io_stats["reservoir_merges"] == 1
    # survivor at physical position 2 inherited the evictee's members
    assert int(mem._member_count[2]) == 3
    assert set(mem._members[2, :3].tolist()) == {12, 10, 100}
    # expansion through the merged cluster reaches the evicted frames
    fids = mem.expand_draws_device(np.asarray([2] * 8),
                                   np.ones(8, bool), seed=1)
    assert {10, 100} <= set(int(f) for f in fids) | {12}

    # dissimilar evictee (row at physical 1, "other"): no merge
    mem2 = VenusMemory(cap, dim, member_cap=8,
                       eviction=get_eviction_policy("cluster_merge"))
    mem2.insert_batch(rows, scene_ids=[0] * 4,
                      index_frames=[10, 11, 12, 13],
                      member_lists=[[10], [11], [12], [13]])
    merges0 = mem2.io_stats["reservoir_merges"]
    mem2.insert_batch(rows[:1] * 0.5, scene_ids=[1], index_frames=[14],
                      member_lists=[[14]])   # evicts row 0 (merges)
    mem2.insert_batch(rng.normal(0, 1, (1, dim)).astype(np.float32),
                      scene_ids=[1], index_frames=[15],
                      member_lists=[[15]])   # evicts "other": no match
    assert mem2.io_stats["evicted_rows"] == 2
    assert mem2.io_stats["reservoir_merges"] <= merges0 + 1


def test_cluster_merge_no_survivor_above_threshold():
    """Evictees with NO survivor clearing the threshold fall back to
    plain sliding-window: no merge, reservoirs dropped with the row."""
    rng = np.random.default_rng(9)
    cap, dim = 4, 8
    mem = VenusMemory(cap, dim, member_cap=8,
                      eviction=get_eviction_policy("cluster_merge",
                                                   threshold=0.999))
    rows = np.eye(dim, dtype=np.float32)[:4]     # mutually orthogonal
    mem.insert_batch(rows, scene_ids=[0] * 4,
                     index_frames=[10, 11, 12, 13],
                     member_lists=[[10, 100], [11], [12], [13]])
    mem.insert_batch(rng.normal(0, 1, (2, dim)).astype(np.float32),
                     scene_ids=[1] * 2, index_frames=[14, 15],
                     member_lists=[[14], [15]])
    assert mem.io_stats["evicted_rows"] == 2
    assert mem.io_stats["reservoir_merges"] == 0
    # no surviving reservoir inherited the evicted frames
    live = (mem.head + np.arange(mem.size)) % cap
    for p in live:
        got = set(mem._members[p, :mem._member_count[p]].tolist())
        assert not ({10, 100, 11} & got)


def test_cluster_merge_need_exceeds_live_window():
    """``need`` ≥ the live window (one batch overruns everything the
    memory holds): merging is skipped — there is no survivor to fold
    into — and the window semantics match plain sliding-window."""
    rng = np.random.default_rng(10)
    cap, dim = 8, 8
    mem = VenusMemory(cap, dim, member_cap=4, eviction="cluster_merge")
    first = rng.normal(0, 1, (3, dim)).astype(np.float32)
    mem.insert_batch(first, scene_ids=[0] * 3, index_frames=[0, 1, 2],
                     member_lists=[[0], [1], [2]])
    n = cap + 5                                  # > capacity AND > size
    rows = rng.normal(0, 1, (n, dim)).astype(np.float32)
    mem.insert_batch(rows, scene_ids=[1] * n,
                     index_frames=list(range(3, 3 + n)),
                     member_lists=[[i] for i in range(3, 3 + n)])
    twin = VenusMemory(cap, dim, member_cap=4, eviction="sliding_window")
    twin.insert_batch(first, scene_ids=[0] * 3, index_frames=[0, 1, 2],
                      member_lists=[[0], [1], [2]])
    twin.insert_batch(rows, scene_ids=[1] * n,
                      index_frames=list(range(3, 3 + n)),
                      member_lists=[[i] for i in range(3, 3 + n)])
    assert mem.size == twin.size == cap
    assert mem.window == twin.window
    np.testing.assert_array_equal(mem._index_frame, twin._index_frame)
    np.testing.assert_array_equal(mem._emb, twin._emb)


def test_cluster_merge_folds_on_recycled_slot():
    """A recycled arena slot must fold into the NEW tenant's survivors
    only: the old tenant's rows are gone from the device rows the slot
    reuses, and post-recycle merge behaviour matches a fresh manager."""
    worlds = _worlds(3)
    cfg = VenusConfig(max_partition_len=32, memory_capacity=16,
                      eviction="cluster_merge")
    mgr = _manager(cfg)
    sids = [mgr.create_session() for _ in range(2)]
    for t in range(6):                           # both fill past capacity
        _tick(mgr, dict(zip(sids, worlds[:2])), t)
    assert mgr[sids[0]].memory.io_stats["evicted_rows"] > 0
    slot = mgr[sids[1]].memory.slot
    mgr.close_session(sids[1])
    new_sid = mgr.create_session()               # recycles the slot
    assert mgr[new_sid].memory.slot == slot
    fresh = _manager(cfg)
    fsid_keep = fresh.create_session()
    fsid_new = fresh.create_session()
    for t in range(6):
        _tick(fresh, {fsid_keep: worlds[0]}, t)
    for t in range(6, 12):                       # recycled tenant fills
        _tick(mgr, {sids[0]: worlds[0], new_sid: worlds[2]}, t)
        _tick(fresh, {fsid_keep: worlds[0], fsid_new: worlds[2]}, t)
    mem_r = mgr[new_sid].memory
    mem_f = fresh[fsid_new].memory
    assert mem_r.io_stats["evicted_rows"] > 0
    assert mem_r.window == mem_f.window
    np.testing.assert_array_equal(mem_r._index_frame, mem_f._index_frame)
    np.testing.assert_array_equal(mem_r._member_count,
                                  mem_f._member_count)
    qes = _queries(worlds, [0, 2], seed0=500)
    _assert_same_results(
        mgr.query_batch_cross([sids[0], new_sid], query_embs=qes),
        fresh.query_batch_cross([fsid_keep, fsid_new], query_embs=qes))


def test_commit_jobs_raises_clear_memory_full_error():
    """Satellite: an ``eviction='none'`` session at capacity fails the
    TICK with a named, actionable error — before any embedding work —
    instead of a deep-in-scatter failure."""
    worlds = _worlds(1)
    cfg = VenusConfig(max_partition_len=32, memory_capacity=8,
                      eviction="none")
    mgr = _manager(cfg)
    sid = mgr.create_session()
    with pytest.raises(RuntimeError,
                       match=rf"session {sid}: memory full"):
        for t in range(12):
            _tick(mgr, {sid: worlds[0]}, t)
    # the error names the fix
    try:
        for t in range(12, 24):
            _tick(mgr, {sid: worlds[0]}, t)
    except RuntimeError as e:
        assert "enable eviction or consolidation" in str(e)
    # the session itself is intact (the tick failed cleanly)
    assert mgr[sid].memory.size <= cfg.memory_capacity


# ---------------------------------------------------------------------------
# ACCEPTANCE: churn workload — steady-state slots, zero restacks
# ---------------------------------------------------------------------------


def test_churn_steady_state_slots_zero_restacks():
    """≥ 3 rounds of create → fill past capacity → close → recreate:
    ``stack_rebuilds`` stays 0, the arena slot count holds at its
    steady-state maximum (no monotonic growth), every round past the
    first recycles a slot, and live sessions keep answering."""
    worlds = _worlds(4)
    mgr = _manager(EVICT_CFG)
    stable = [mgr.create_session() for _ in range(2)]   # long-lived
    churn_sid = mgr.create_session()                    # round 0 tenant
    steady = mgr.arena.n_sessions
    assert steady == 3
    grows0 = mgr.arena.io_stats["grows"]
    # warm-up round so jit compiles don't sit inside the assertions
    for t in range(2):
        _tick(mgr, {stable[0]: worlds[0], stable[1]: worlds[1],
                    churn_sid: worlds[2]}, t)
    mgr.query_batch_cross([stable[0], churn_sid],
                          query_embs=_queries(worlds, [0, 2], seed0=7))
    mgr.reset_io_stats()

    rounds = 3
    for r in range(1, rounds + 1):
        mgr.close_session(churn_sid)
        churn_sid = mgr.create_session()               # reuses the slot
        streams = {stable[0]: worlds[0], stable[1]: worlds[1],
                   churn_sid: worlds[2 + r % 2]}
        for t in range(6):                 # fill the churn session past
            _tick(mgr, streams, 2 + 6 * r + t)         # capacity
        qsids = [stable[0], stable[1], churn_sid, churn_sid]
        qes = _queries(worlds, [0, 1, 2 + r % 2, 2 + r % 2],
                       seed0=200 + 17 * r)
        results = mgr.query_batch_cross(qsids, query_embs=qes)
        assert all(r_ is not None for r_ in results)
        # the churned session filled past capacity and evicted
        assert mgr[churn_sid].memory.io_stats["evicted_rows"] > 0
        # slot count NEVER grows past the steady-state maximum
        assert mgr.arena.n_sessions == steady
        assert mgr.arena.io_stats["grows"] == 0        # (reset) no grow

    assert mgr.io_stats["stack_rebuilds"] == 0
    assert mgr.arena.io_stats["slot_reuses"] == rounds
    assert mgr.io_stats["sessions_closed"] == rounds
    assert mgr.arena.io_stats["grows"] == 0
    assert grows0 == 3
    # monitoring stays monotonic across closes: the churned tenants'
    # eviction history is folded into closed_mem_stats, not dropped
    assert mgr.closed_mem_stats["evicted_rows"] > 0


def test_create_session_eviction_override():
    """Per-session eviction override: one 24/7 stream among bounded
    ones."""
    mgr = _manager(CFG)
    s_default = mgr.create_session()
    s_window = mgr.create_session(eviction="sliding_window")
    assert mgr[s_default].memory.eviction.name == "none"
    assert mgr[s_window].memory.eviction.name == "sliding_window"


# ---------------------------------------------------------------------------
# bounded raw-frame archive (FrameStore trim below the eviction window)
# ---------------------------------------------------------------------------


def test_framestore_trim_keeps_absolute_ids():
    from repro.core.memory import FrameStore
    fs = FrameStore()
    fs.append(np.arange(10, dtype=np.float32).reshape(10, 1, 1, 1))
    assert len(fs) == 10 and fs.retained == 10 and fs.base == 0
    assert fs.trim(4) == 4
    assert len(fs) == 10          # absolute id space never shrinks
    assert fs.retained == 6 and fs.base == 4 and fs.trimmed == 4
    assert float(fs.get([4])[0].ravel()[0]) == 4.0    # ids stay stable
    with pytest.raises(IndexError):
        fs.get([3])               # trimmed ids fail fast, never alias
    assert fs.trim(2) == 0        # backwards trim is a no-op
    assert fs.trim(10 ** 9) == 6  # clamped to what exists
    fs.append(np.arange(2, dtype=np.float32).reshape(2, 1, 1, 1))
    assert len(fs) == 12 and fs.retained == 2


def test_min_live_frame_consults_reservoirs():
    """The trim horizon is the min over index_frame ids AND count-masked
    member reservoirs — and cluster_merge's folded members keep an
    EVICTED row's frames live through the surviving cluster."""
    rng = np.random.default_rng(3)
    cap, dim = 4, 8
    mem = VenusMemory(cap, dim, member_cap=8, eviction="cluster_merge")
    assert mem.min_live_frame() == np.iinfo(np.int64).max   # empty
    base = rng.normal(0, 1, (dim,)).astype(np.float32)
    rows = np.stack([base, -base, base + 1e-3,
                     rng.normal(0, 1, (dim,))]).astype(np.float32)
    mem.insert_batch(rows, scene_ids=[0] * 4,
                     index_frames=[10, 11, 12, 13],
                     member_lists=[[10, 7], [11], [12], [13]])
    assert mem.min_live_frame() == 7          # reservoir beats index id
    # evict row 0 (frame 10): its reservoir folds into the near-dup at
    # physical 2, so frames 7 and 10 stay reachable — and LIVE
    mem.insert_batch(rng.normal(0, 1, (1, dim)).astype(np.float32),
                     scene_ids=[1], index_frames=[14],
                     member_lists=[[14]])
    assert mem.io_stats["reservoir_merges"] == 1
    assert mem.min_live_frame() == 7
    # a plain sliding window would have released them
    mem2 = VenusMemory(cap, dim, member_cap=8, eviction="sliding_window")
    mem2.insert_batch(rows, scene_ids=[0] * 4,
                      index_frames=[10, 11, 12, 13],
                      member_lists=[[10, 7], [11], [12], [13]])
    mem2.insert_batch(rng.normal(0, 1, (1, dim)).astype(np.float32),
                      scene_ids=[1], index_frames=[14],
                      member_lists=[[14]])
    assert mem2.min_live_frame() == 11


def test_archive_bounded_under_sliding_window():
    """ACCEPTANCE: a sliding-window session's raw-frame archive stays
    bounded — the manager trims host frames below every live reference
    after each tick — while every frame a query can return remains
    readable. ``eviction='none'`` sessions keep the historical
    keep-everything contract."""
    worlds = _worlds(2)
    mgr = _manager(EVICT_CFG)
    s_win = mgr.create_session()
    s_none = mgr.create_session(eviction="none")
    for t in range(8):                         # far past capacity
        chunks = {s_win: worlds[0]}
        if t < 2:            # keep the "none" session under capacity
            chunks[s_none] = worlds[1]
        _tick(mgr, chunks, t)
    st = mgr[s_win]
    assert st.memory.io_stats["evicted_rows"] > 0
    assert st.stats["frames_trimmed"] > 0
    assert st.frames.retained < st.stats["frames_seen"]
    assert len(st.frames) == st.stats["frames_seen"]    # ids absolute
    # never trimmed past a live reference or the un-clustered pending
    assert st.frames.base <= min(st.memory.min_live_frame(),
                                 st.pending_base)
    assert mgr.io_stats["archive_trimmed_frames"] >= \
        st.stats["frames_trimmed"]
    # the un-evicting session keeps everything
    st_n = mgr[s_none]
    assert st_n.frames.retained == st_n.stats["frames_seen"]
    assert st_n.stats["frames_trimmed"] == 0
    # every frame a query returns is still readable from the archive
    qes = _queries(worlds, [0, 0], seed0=400)
    for res in mgr.query_batch_cross([s_win, s_win], query_embs=qes):
        if len(res.frame_ids):
            assert st.frames.get(res.frame_ids).shape[0] == \
                len(res.frame_ids)
