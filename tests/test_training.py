"""Training substrate: optimizer math, schedules, losses, checkpointing,
MEM contrastive training."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.venus_mem import smoke_config as mem_smoke
from repro.data.text import lm_batches, tokenize, tokenize_batch
from repro.models.mem import MEM
from repro.models.transformer import Transformer
from repro.training import (TrainHParams, adamw_init, adamw_update,
                            cosine_schedule, make_mem_train_step,
                            make_train_step)
from repro.training import checkpoint as ckpt
from repro.training.losses import lm_cross_entropy, siglip_loss


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt = adamw_update(grads, opt, params, lr=0.05,
                                   weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(jnp.asarray(0), base_lr=1.0, warmup=10,
                          total=100)
    lr_w = cosine_schedule(jnp.asarray(10), base_lr=1.0, warmup=10,
                           total=100)
    lr_end = cosine_schedule(jnp.asarray(100), base_lr=1.0, warmup=10,
                             total=100)
    assert float(lr0) == 0.0
    assert abs(float(lr_w) - 1.0) < 1e-5
    assert float(lr_end) <= 0.11


def test_lm_cross_entropy_gold():
    logits = jnp.asarray([[[10.0, 0.0, 0.0], [0.0, 10.0, 0.0]]])
    labels = jnp.asarray([[0, 1]])
    loss, metrics = lm_cross_entropy(logits, labels, z_loss=0.0)
    assert float(loss) < 1e-3
    assert float(metrics["accuracy"]) == 1.0


def test_lm_loss_decreases_end_to_end():
    cfg = registry.get_smoke_config("deepseek-7b")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, TrainHParams(
        base_lr=1e-3, warmup=2, total_steps=50, remat=False)))
    it = lm_batches(cfg.vocab_size, 4, 64, seed=0)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, metrics = step(params, opt, b, jnp.asarray(i))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_siglip_loss_prefers_diagonal():
    d = 8
    img = jnp.eye(4, d)
    txt_match = jnp.eye(4, d)
    perm = jnp.asarray([1, 0, 3, 2])
    loss_m, _ = siglip_loss(img, txt_match, jnp.asarray(2.0),
                            jnp.asarray(-1.0))
    loss_x, _ = siglip_loss(img, txt_match[perm], jnp.asarray(2.0),
                            jnp.asarray(-1.0))
    assert float(loss_m) < float(loss_x)


def test_mem_contrastive_training_improves(tmp_path):
    cfg = mem_smoke()
    mem = MEM(cfg)
    params = mem.init(jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_mem_train_step(mem, TrainHParams(
        base_lr=3e-4, warmup=2, total_steps=60, remat=False)))
    rng = np.random.default_rng(0)
    # synthetic paired data: 4 "classes"; patches + texts per class
    protos = rng.normal(0, 1, (4, cfg.vision.d_model)).astype(np.float32)
    texts = [f"class{i} object{i}" for i in range(4)]
    accs = []
    for i in range(30):
        cls = rng.integers(0, 4, size=4)
        while len(set(cls.tolist())) < 4:       # distinct rows for siglip
            cls = rng.integers(0, 4, size=4)
        patches = protos[cls][:, None, :].repeat(4, 1) \
            + rng.normal(0, 0.1, (4, 4, cfg.vision.d_model))
        toks, mask = tokenize_batch([texts[c] for c in cls],
                                    cfg.text.vocab_size, 16)
        batch = {"tokens": jnp.asarray(toks), "mask": jnp.asarray(mask),
                 "patches": jnp.asarray(patches, jnp.float32)}
        params, opt, metrics = step(params, opt, batch, jnp.asarray(i))
        accs.append(float(metrics["contrastive_acc"]))
    assert np.mean(accs[-5:]) > np.mean(accs[:5])


def test_checkpoint_roundtrip(tmp_path):
    cfg = registry.get_smoke_config("olmoe-1b-7b")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, {"params": params, "opt": opt._asdict()},
              metadata={"step": 3})
    target = jax.tree.map(lambda a: np.zeros_like(a),
                          {"params": params, "opt": opt._asdict()})
    restored = ckpt.restore(path, target)
    flat_a = jax.tree.leaves(restored["params"])
    flat_b = jax.tree.leaves(params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tokenizer_deterministic_and_bounded():
    a = tokenize("hello world", 512, 8)
    b = tokenize("hello world", 512, 8)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (8,)
    assert (a < 512).all() and (a >= 0).all()
    c = tokenize("hello mars", 512, 8)
    assert (a != c).any()
