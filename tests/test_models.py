"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward and one train step on CPU with
shape and finiteness checks, plus prefill→decode parity in f32."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, registry
from repro.models.transformer import Transformer
from repro.training import TrainHParams, adamw_init, make_train_step


def _batch_kwargs(cfg, b, key):
    kw = {}
    if cfg.family == "vlm":
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        kw["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model)) * 0.02
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = registry.get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    b, s = 2, 32
    tok = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    kw = _batch_kwargs(cfg, b, jax.random.key(2))
    logits, _, aux = m.apply(params, tok, mode="train", **kw)
    s_total = s + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, TrainHParams(warmup=1,
                                                     total_steps=10,
                                                     remat=False)))
    b, s = 2, 32
    tok = jax.random.randint(jax.random.key(1), (b, s + 1), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
    batch.update(_batch_kwargs(cfg, b, jax.random.key(2)))
    new_params, _, metrics = step(params, opt, batch, jnp.asarray(1))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    diffs = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    cfg = registry.get_smoke_config(arch).replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    b, s, extra = 2, 20, 6
    tok = jax.random.randint(jax.random.key(1), (b, s + extra), 0,
                             cfg.vocab_size)
    kw = _batch_kwargs(cfg, b, jax.random.key(2))
    nv = cfg.vision_tokens if cfg.family == "vlm" else 0
    ref_logits, _, _ = m.apply(params, tok, mode="train", **kw)
    cache = m.init_cache(b, s + extra + nv, dtype=jnp.float32)
    pl, cache, _ = m.apply(params, tok[:, :s], mode="prefill", cache=cache,
                           **kw)
    np.testing.assert_allclose(np.asarray(pl[:, 0]),
                               np.asarray(ref_logits[:, nv + s - 1]),
                               rtol=1e-4, atol=1e-4)
    for t in range(extra):
        dl, cache, _ = m.apply(params, tok[:, s + t:s + t + 1],
                               mode="decode", cache=cache)
        np.testing.assert_allclose(
            np.asarray(dl[:, 0]), np.asarray(ref_logits[:, nv + s + t]),
            rtol=1e-3, atol=1e-3, err_msg=f"{arch} step {t}")


def test_sliding_window_cache_bounded():
    """Ring-buffer decode == full-cache decode restricted to the window."""
    cfg = registry.get_smoke_config("glm4-9b").replace(
        dtype="float32", sliding_window=8)
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    b, s = 1, 24
    tok = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    ref_logits, _, _ = m.apply(params, tok, mode="train")
    cache = m.init_cache(b, 64, dtype=jnp.float32)
    assert cache["dense"]["k"].shape[2] == 8        # bounded by window
    _, cache, _ = m.apply(params, tok[:, :4], mode="prefill", cache=cache)
    for t in range(4, s - 1):
        dl, cache, _ = m.apply(params, tok[:, t:t + 1], mode="decode",
                               cache=cache)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(ref_logits[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_prompt_lengths_padding_equivalence():
    """Right-padded prefill with prompt_lengths == exact-length prefill."""
    cfg = registry.get_smoke_config("deepseek-7b").replace(dtype="float32")
    m = Transformer(cfg)
    params = m.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (1, 13), 0, cfg.vocab_size)
    cache1 = m.init_cache(1, 64, dtype=jnp.float32)
    exact, cache1, _ = m.apply(params, tok, mode="prefill", cache=cache1)
    padded_tok = jnp.pad(tok, ((0, 0), (0, 19)))
    cache2 = m.init_cache(1, 64, dtype=jnp.float32)
    padded, cache2, _ = m.apply(params, padded_tok, mode="prefill",
                                cache=cache2,
                                prompt_lengths=jnp.asarray([13]))
    np.testing.assert_allclose(np.asarray(exact), np.asarray(padded),
                               rtol=1e-5, atol=1e-5)
    assert int(cache2["pos"][0]) == 13
    # decode continues identically
    nxt = jnp.asarray([[5]])
    d1, _, _ = m.apply(params, nxt, mode="decode", cache=cache1)
    d2, _, _ = m.apply(params, nxt, mode="decode", cache=cache2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5,
                               atol=1e-5)


def test_param_counts_positive():
    from repro.models.params import (count_active_params_analytic,
                                     count_params_analytic)
    for arch in ARCH_IDS:
        cfg = registry.get_smoke_config(arch)
        n = count_params_analytic(cfg)
        na = count_active_params_analytic(cfg)
        assert 0 < na <= n, arch
        if cfg.moe is not None:
            assert na < n, arch
