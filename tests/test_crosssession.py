"""Cross-session fused query path: ONE scan over all sessions.

Equivalence suite for the tentpole invariant — the fused path
(``query_batch_cross``: padded-stack similarity scan + one jit'd
sampling→AKR→reservoir-expansion program) must match the per-session
``query_batch`` path and the sequential ``query`` path draw-for-draw:
same subkey chain, same draws, same AKR ``n_drawn``/``mass``, same frame
ids, for unequal session sizes and unequal per-session query counts
(padding lanes must not leak into results). It must also do its work in
exactly ONE similarity scan with ZERO host-side reservoir gathers.
"""

import numpy as np
import pytest

from repro.core.memory import MemoryStack, VenusMemory
from repro.core.session import SessionManager, VenusConfig
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)


def _worlds(n_sessions):
    # n_scenes varies per stream ⇒ genuinely unequal memory sizes
    return [VideoWorld(WorldConfig(n_scenes=4 + s, seed=20 + s))
            for s in range(n_sessions)]


def _ingested_manager(worlds, chunk=64):
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sids = [mgr.create_session() for _ in worlds]
    for sid, w in zip(sids, worlds):
        for i in range(0, w.total_frames, chunk):
            mgr.ingest_tick({sid: w.frames[i:i + chunk]})
    mgr.flush()
    return mgr, sids


def _queries(worlds, qsids, seed0=40):
    return np.stack([
        OracleEmbedder(worlds[s], dim=64).embed_queries(
            worlds[s].make_queries(1, seed=seed0 + j))[0]
        for j, s in enumerate(qsids)])


def _per_session_baseline(mgr, qsids, qes, **kw):
    """Per-session query_batch in canonical (sorted-sid) session order —
    the same per-session subkey consumption the fused path performs."""
    order = {}
    for j, s in enumerate(qsids):
        order.setdefault(s, []).append(j)
    out = [None] * len(qsids)
    for s in sorted(order):
        idxs = order[s]
        for j, r in zip(idxs, mgr.query_batch(s, query_embs=qes[idxs],
                                              **kw)):
            out[j] = r
    return out


def _sequential_baseline(mgr, qsids, qes, **kw):
    order = {}
    for j, s in enumerate(qsids):
        order.setdefault(s, []).append(j)
    out = [None] * len(qsids)
    for s in sorted(order):
        for j in order[s]:
            out[j] = mgr.query(s, "", query_emb=qes[j], **kw)
    return out


def _assert_equal_results(got, want, check_akr=True):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.draws, b.draws)
        np.testing.assert_array_equal(a.frame_ids, b.frame_ids)
        if check_akr:
            assert a.n_drawn == b.n_drawn
            np.testing.assert_allclose(a.mass, b.mass, rtol=1e-6)


# ---------------------------------------------------------------------------
# fused == per-session query_batch == sequential query
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_sessions,qsids", [
    (1, [0, 0, 0]),                       # S=1: degenerate stack
    (3, [0, 1, 1, 2, 0, 2, 2]),           # S=3: unequal query counts
])
def test_fused_matches_per_session_and_sequential(n_sessions, qsids):
    worlds = _worlds(n_sessions)
    qes = _queries(worlds, qsids)

    mgr_f, sids = _ingested_manager(worlds)
    mgr_b, _ = _ingested_manager(worlds)
    mgr_s, _ = _ingested_manager(worlds)
    sizes = {mgr_f[s].memory.size for s in sids}
    if n_sessions > 1:
        assert len(sizes) > 1, "want genuinely unequal session sizes"

    fused = mgr_f.query_batch_cross(qsids, query_embs=qes)
    per_session = _per_session_baseline(mgr_b, qsids, qes)
    sequential = _sequential_baseline(mgr_s, qsids, qes)
    _assert_equal_results(fused, per_session)
    _assert_equal_results(fused, sequential)


@pytest.mark.parametrize("n_sessions,qsids", [
    (1, [0, 0]),
    (3, [0, 1, 1, 2, 2, 2]),
])
def test_fused_fixed_budget_matches(n_sessions, qsids):
    worlds = _worlds(n_sessions)
    qes = _queries(worlds, qsids, seed0=70)
    mgr_f, _ = _ingested_manager(worlds)
    mgr_b, _ = _ingested_manager(worlds)
    mgr_s, _ = _ingested_manager(worlds)

    fused = mgr_f.query_batch_cross(qsids, query_embs=qes, budget=6,
                                    use_akr=False)
    per_session = _per_session_baseline(mgr_b, qsids, qes, budget=6,
                                        use_akr=False)
    sequential = _sequential_baseline(mgr_s, qsids, qes, budget=6,
                                      use_akr=False)
    _assert_equal_results(fused, per_session, check_akr=False)
    _assert_equal_results(fused, sequential, check_akr=False)


def test_fused_then_per_session_same_manager():
    """The fused path consumes each session's subkey chain exactly like
    the per-session path, so the NEXT query on the same manager still
    matches a twin manager that only ever used per-session calls."""
    worlds = _worlds(3)
    qsids = [0, 1, 2, 1]
    qes = _queries(worlds, qsids)
    mgr_f, _ = _ingested_manager(worlds)
    mgr_b, _ = _ingested_manager(worlds)

    _assert_equal_results(mgr_f.query_batch_cross(qsids, query_embs=qes),
                          _per_session_baseline(mgr_b, qsids, qes))
    # chain positions now identical ⇒ follow-up queries agree too
    follow = _queries(worlds, [1], seed0=90)
    a = mgr_f.query(1, "", query_emb=follow[0])
    b = mgr_b.query(1, "", query_emb=follow[0])
    np.testing.assert_array_equal(a.draws, b.draws)
    np.testing.assert_array_equal(a.frame_ids, b.frame_ids)


# ---------------------------------------------------------------------------
# acceptance: ONE scan, ZERO host-side reservoir gathers
# ---------------------------------------------------------------------------


def test_fused_one_scan_zero_host_gathers():
    worlds = _worlds(3)
    qsids = [0, 1, 1, 2, 2]
    qes = _queries(worlds, qsids)
    mgr, sids = _ingested_manager(worlds)

    before_scans = dict(mgr.io_stats)
    before_mem = {s: dict(mgr[s].memory.io_stats) for s in sids}
    results = mgr.query_batch_cross(qsids, query_embs=qes)
    assert all(r is not None for r in results)

    # exactly ONE similarity scan for the whole group: one fused scan,
    # zero per-session scans
    assert mgr.io_stats["fused_scans"] == before_scans["fused_scans"] + 1
    assert mgr.io_stats["scans"] == before_scans["scans"]
    for s in sids:
        io = mgr[s].memory.io_stats
        assert io["scans"] == before_mem[s]["scans"]
        # zero host-side reservoir gathers: expansion ran on device
        assert (io["host_expand_gathers"]
                == before_mem[s]["host_expand_gathers"])
    assert mgr.io_stats["device_expands"] == \
        before_scans["device_expands"] + 1


def test_stack_cached_between_queries():
    """Repeated fused queries with no intervening inserts must reuse the
    device stack — no rebuilds, no new uploads."""
    worlds = _worlds(3)
    qsids = [0, 1, 2]
    mgr, sids = _ingested_manager(worlds)
    mgr.query_batch_cross(qsids, query_embs=_queries(worlds, qsids))
    stack = mgr.memory_stack(tuple(sorted(set(qsids))))
    builds = dict(stack.io_stats)
    uploads = {s: mgr[s].memory.io_stats["full_uploads"] for s in sids}
    for k in range(3):
        mgr.query_batch_cross(qsids,
                              query_embs=_queries(worlds, qsids,
                                                  seed0=50 + 7 * k))
    assert stack.io_stats == builds
    for s in sids:
        assert mgr[s].memory.io_stats["full_uploads"] == uploads[s]


# ---------------------------------------------------------------------------
# MemoryStack view invariants
# ---------------------------------------------------------------------------


def test_memory_stack_matches_per_memory_index():
    rng = np.random.default_rng(0)
    mems = []
    for k, n in enumerate((5, 12, 1)):
        m = VenusMemory(capacity=32, dim=8, member_cap=4)
        rows = rng.normal(0, 1, (n, 8)).astype(np.float32)
        m.insert_batch(rows, scene_ids=[0] * n,
                       index_frames=list(range(n)),
                       member_lists=[[i] for i in range(n)])
        mems.append(m)
    stack = MemoryStack(mems)
    emb, valid = stack.device_stack()
    assert emb.shape == (3, 32, 8) and valid.shape == (3, 32)
    for k, m in enumerate(mems):
        e, v = m.device_index()
        np.testing.assert_array_equal(np.asarray(emb[k]), np.asarray(e))
        np.testing.assert_array_equal(np.asarray(valid[k]), np.asarray(v))
        assert np.asarray(valid[k]).sum() == m.size

    q = rng.normal(0, 1, (3, 2, 8)).astype(np.float32)
    import jax.numpy as jnp
    sims, probs = stack.search(jnp.asarray(q), tau=0.1)
    for k, m in enumerate(mems):
        s1, p1 = m.search(jnp.asarray(q[k]), tau=0.1)
        np.testing.assert_allclose(np.asarray(sims[k]), np.asarray(s1),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(probs[k]), np.asarray(p1),
                                   rtol=1e-6, atol=1e-6)


def test_memory_stack_rejects_mismatched_shapes():
    a = VenusMemory(capacity=16, dim=8)
    b = VenusMemory(capacity=32, dim=8)
    with pytest.raises(AssertionError):
        MemoryStack([a, b])


def test_memory_stack_tracks_inserts():
    rng = np.random.default_rng(1)
    m = VenusMemory(capacity=16, dim=4, member_cap=4)
    stack = MemoryStack([m])
    m.insert_batch(rng.normal(0, 1, (3, 4)).astype(np.float32),
                   scene_ids=[0] * 3, index_frames=[0, 1, 2],
                   member_lists=[[0], [1], [2]])
    emb, valid = stack.device_stack()
    assert np.asarray(valid).sum() == 3
    m.insert_batch(rng.normal(0, 1, (2, 4)).astype(np.float32),
                   scene_ids=[1] * 2, index_frames=[3, 4],
                   member_lists=[[3], [4]])
    emb, valid = stack.device_stack()          # version bump ⇒ restack
    assert np.asarray(valid).sum() == 5
    np.testing.assert_array_equal(np.asarray(emb[0, :5]), m._emb[:5])
    assert m.io_stats["full_uploads"] == 1     # append path, not re-upload


# ---------------------------------------------------------------------------
# service-level: budget-only grouping spans sessions in one scan
# ---------------------------------------------------------------------------


def test_service_groups_by_budget_across_sessions():
    from repro.configs import registry
    from repro.models.transformer import Transformer
    from repro.serving.engine import ServingEngine
    from repro.serving.venus_service import StreamQuery, VenusService
    import jax

    worlds = _worlds(3)
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    cfg = registry.get_smoke_config("qwen2-vl-7b")
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=128)
    svc = VenusService(mgr, eng, max_frames=2)
    sids = [svc.create_stream() for _ in worlds]
    for sid, w in zip(sids, worlds):
        for i in range(0, w.total_frames, 64):
            svc.ingest_tick({sid: w.frames[i:i + 64]})
    svc.flush()

    rng = np.random.default_rng(0)
    queries = [StreamQuery(rid=r, sid=sids[r % 3], text=f"q{r}",
                           prompt_tokens=rng.integers(
                               3, cfg.vocab_size, size=8),
                           max_new_tokens=2)
               for r in range(5)]
    before = dict(mgr.io_stats)
    done = svc.answer(queries)
    # 5 queries over 3 sessions, one budget group ⇒ ONE fused scan
    assert mgr.io_stats["fused_scans"] == before["fused_scans"] + 1
    assert mgr.io_stats["scans"] == before["scans"]
    assert len(done) == 5
    assert all(q.frame_ids is not None for q in queries)
