"""Property tests for standing queries (``repro.core.standing``).

Two invariants under random interleavings:

* REPLAY EQUIVALENCE — any sequence of register / unregister / tick
  operations fires the identical alert stream (sid, spec id, score
  bitwise, frame ids, tick) when replayed op-for-op on a fresh
  manager: standing evaluation keeps no hidden state beyond the
  registry's own trigger fields, consumes no PRNG chain, and its
  scores don't depend on what other specs exist or when they were
  (un)registered.
* READABILITY AT FIRE TIME — every alert's frame ids are readable
  from the session's ``FrameStore`` the moment the alert is polled:
  alerts only ever reference the tick's newly committed rows, which
  the archive trim horizon keeps live — and with the spill tier
  enabled they stay readable forever (faulted back from disk).
"""

import os
import shutil
import tempfile

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.queryplan import QuerySpec  # noqa: E402
from repro.core.session import SessionManager, VenusConfig  # noqa: E402
from repro.data.video import PixelEmbedder  # noqa: E402

DIM = 32


def _unit(rows):
    rows = np.asarray(rows, np.float32)
    return rows / (np.linalg.norm(rows, axis=-1, keepdims=True) + 1e-12)


class ArrayEmbedder:
    def embed_queries(self, texts):
        raise AssertionError("tests pass explicit embeddings")

    def embed_frames(self, frames, aux=None, frame_ids=None):
        raise AssertionError("tests insert rows directly")


def _draw_ops(data):
    """A concrete op list — every array materialised up front, so the
    replay applies EXACTLY the same inputs."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    ops = []
    for _ in range(data.draw(st.integers(3, 10))):
        kind = data.draw(st.sampled_from(["register", "unregister",
                                          "tick", "tick"]))
        if kind == "register":
            ops.append(("register", {
                "s": data.draw(st.integers(0, 1)),
                "emb": _unit(rng.normal(size=(1, DIM)))[0],
                "budget": data.draw(st.integers(1, 4)),
                "threshold": data.draw(st.sampled_from(
                    [-1.0, 0.2, 0.6, 0.9])),
                "hysteresis": data.draw(st.sampled_from([0.0, 0.1])),
                "cooldown": data.draw(st.integers(0, 2)),
            }))
        elif kind == "unregister":
            ops.append(("unregister", None))
        else:
            counts = [data.draw(st.integers(0, 5)) for _ in range(2)]
            ops.append(("tick", [_unit(rng.normal(size=(n, DIM)))
                                 if n else None for n in counts]))
    return ops


def _apply(ops):
    """Run the op list on a fresh manager; return the alert stream."""
    mgr = SessionManager(VenusConfig(memory_capacity=128, member_cap=8),
                         ArrayEmbedder(), embed_dim=DIM)
    sids = [mgr.create_session(), mgr.create_session()]
    fid = [0, 0]
    stream = []
    for kind, arg in ops:
        if kind == "register":
            mgr.register_standing(
                sids[arg["s"]],
                QuerySpec(sid=sids[arg["s"]], embedding=arg["emb"],
                          strategy="topk", budget=arg["budget"]),
                threshold=arg["threshold"],
                hysteresis=arg["hysteresis"],
                cooldown_ticks=arg["cooldown"])
        elif kind == "unregister":
            if mgr.standing.entries:       # lowest live id — replay
                mgr.unregister_standing(   # makes the same choice
                    min(mgr.standing.entries))
        else:
            phys = {}
            for s, rows in enumerate(arg):
                if rows is None:
                    continue
                mem = mgr.sessions[sids[s]].memory
                fids = np.arange(fid[s], fid[s] + len(rows))
                fid[s] += len(rows)
                with mgr.arena.deferred_appends():
                    p = mem.insert_batch(
                        rows, scene_ids=[0] * len(rows),
                        index_frames=fids,
                        member_lists=[[int(f)] for f in fids])
                phys[sids[s]] = [p]
            if phys:
                for a in mgr.standing.evaluate(mgr.sessions, phys,
                                               mgr.io_stats):
                    stream.append((a.sid, a.spec_id, a.score,
                                   tuple(int(f) for f in a.frame_ids),
                                   a.tick))
    stats = (mgr.io_stats["alerts_fired"],
             mgr.io_stats["alerts_suppressed"])
    return stream, stats


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_replay_fires_identical_alert_stream(data):
    ops = _draw_ops(data)
    first, first_stats = _apply(ops)
    replay, replay_stats = _apply(ops)
    assert replay == first
    assert replay_stats == first_stats


def _scene_chunk(rng, n=16, hw=16, pool=8):
    blocks = rng.uniform(-1, 1, (hw // pool, hw // pool, 3)
                         ).astype(np.float32)
    frame = np.kron(blocks, np.ones((pool, pool, 1), np.float32))
    return np.broadcast_to(frame, (n,) + frame.shape).copy()


@settings(max_examples=8, deadline=None)
@given(data=st.data(), spill=st.booleans())
def test_alert_frame_ids_readable_at_fire_time(data, spill):
    """Random target/noise scene sequences through the REAL ingest
    path, under a window-evicting session that trims its archive:
    every polled alert's frame ids must resolve through
    ``FrameStore.get`` — bit-readable host frames, or spill faults
    when the tier is on; never a trimmed-id IndexError."""
    tmp = tempfile.mkdtemp() if spill else None
    try:
        cfg = VenusConfig(max_partition_len=32, memory_capacity=64,
                          member_cap=8, eviction="sliding_window",
                          spill_dir=(os.path.join(tmp, "s") if spill
                                     else None),
                          spill_segment_frames=8,
                          host_retain=16 if spill else None)
        embedder = PixelEmbedder(dim=64)
        mgr = SessionManager(cfg, embedder, embed_dim=64)
        sid = mgr.create_session()
        target_rng_seed = data.draw(st.integers(0, 2**31 - 1))
        target = _scene_chunk(np.random.default_rng(target_rng_seed))
        mgr.register_standing(
            sid, QuerySpec(
                sid=sid, strategy="topk", budget=4,
                embedding=np.asarray(
                    embedder.embed_frames(target)[0], np.float32)),
            threshold=0.9, hysteresis=0.1)
        noise_rng = np.random.default_rng(
            data.draw(st.integers(0, 2**31 - 1)))
        n_alerts = 0
        for _ in range(data.draw(st.integers(4, 8))):
            match = data.draw(st.booleans())
            chunk = (target.copy() if match
                     else _scene_chunk(noise_rng))
            mgr.ingest_tick({sid: chunk})
            for a in mgr.poll_alerts():
                n_alerts += 1
                got = mgr[sid].frames.get(
                    [int(f) for f in a.frame_ids])
                assert got.shape[0] == len(a.frame_ids)
        mgr.flush()
        for a in mgr.poll_alerts():
            n_alerts += 1
            ids = [int(f) for f in a.frame_ids]
            if spill:
                assert mgr[sid].frames.get(ids).shape[0] == len(ids)
        assert mgr.io_stats["alerts_fired"] == n_alerts
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)
