"""Whisper-base — encoder-decoder audio transformer backbone.

6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865 — enc-dec, conv
frontend (stub) [arXiv:2212.04356]

Per the assignment carve-out the mel-spectrogram + conv feature extractor
is a STUB: ``input_specs`` provides precomputed frame embeddings of shape
(batch, encoder_seq_len, d_model); we implement the transformer encoder
over those embeddings and the decoder with self+cross attention.

long_500k is SKIPPED for this arch (see DESIGN.md §Arch-applicability):
an enc-dec audio model has no 524k-token decoder stream analogue.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        source="arXiv:2212.04356",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51865,
        activation="gelu",
        gated_mlp=False,
        pos_type="learned",
        is_encoder_decoder=True,
        num_encoder_layers=6,
        encoder_seq_len=1500,     # 30 s of audio at 50 frames/s
        audio_frontend=True,
        tie_embeddings=True,
        # whisper's native decoder context is 448; the assigned decode_32k
        # shape requires positions up to 32k, so the learned table is sized
        # for the dry-run (deviation noted in DESIGN.md).
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="whisper-base-smoke",
        num_layers=2,
        num_encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        encoder_seq_len=64,
        max_seq_len=64,
    )
