"""DeepSeek-LLM-7B — llama-architecture dense decoder.

30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400 [arXiv:2401.02954]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        source="arXiv:2401.02954",
        num_layers=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102400,
        activation="silu",
        rope_theta=10000.0,
        max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-7b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        max_seq_len=512,
    )
