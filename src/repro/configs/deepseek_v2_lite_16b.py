"""DeepSeek-V2-Lite (16B) — MLA + fine-grained MoE.

27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6 —
MLA kv_lora=512, 2 shared + routed top-6 [arXiv:2405.04434]

Note on the assignment line: it reads "2 shared+160 routed top-6", but 160
routed experts is the *full* DeepSeek-V2; the Lite model (and the same
assignment line's own "MoE 64e top-6") has 64 routed experts. We follow
64 routed + 2 shared, matching hf:deepseek-ai/DeepSeek-V2-Lite.
"""

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

_MLA = MLAConfig(
    q_lora_rank=0,                # Lite has no query compression
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        source="arXiv:2405.04434",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        attn_type="mla",
        mla=_MLA,
        moe=MoEConfig(num_experts=64, experts_per_token=6, d_ff=1408,
                      num_shared_experts=2, shared_d_ff=2816,
                      first_dense_layers=1, dense_d_ff=10944),
        rope_theta=10000.0,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-v2-lite-16b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        mla=MLAConfig(q_lora_rank=0, kv_lora_rank=64, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32),
        # capacity_factor = E/k ⇒ zero token drops ⇒ routing is exact and
        # chunking-invariant, which the prefill/decode parity tests rely on
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=128,
                      num_shared_experts=1, shared_d_ff=128,
                      first_dense_layers=1, dense_d_ff=256,
                      capacity_factor=2.0),
    )
