"""RWKV6-1.6B ("Finch") — attention-free RNN with data-dependent decay.

24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 [arXiv:2404.05892]
Sub-quadratic by construction: O(1) recurrent state per layer, so the
long_500k decode shape runs natively.
"""

from repro.configs.base import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892",
        num_layers=24,
        d_model=2048,
        num_heads=32,             # d_model / rwkv.head_dim
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        attn_type="none",
        pos_type="none",
        activation="relu2",       # RWKV channel-mix uses squared ReLU
        gated_mlp=False,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=32),
        max_seq_len=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="rwkv6-1.6b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        rwkv=RWKVConfig(head_dim=32, decay_lora=16, gate_lora=8),
    )
