"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a single frozen ``ModelConfig``.
The model builder (``repro.models.transformer``) consumes only this config,
so architectures are selectable by name (``--arch <id>``) everywhere:
smoke tests, the serving engine, the trainer, and the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 0          # 0 => no query compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Sparse mixture-of-experts feed-forward."""

    num_experts: int = 64
    experts_per_token: int = 8
    d_ff: int = 1024              # per-expert hidden size
    num_shared_experts: int = 0   # DeepSeek-style always-on experts
    shared_d_ff: int = 0          # hidden size of the shared expert block
    first_dense_layers: int = 0   # leading layers that stay dense
    dense_d_ff: int = 0           # d_ff for those dense layers
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    capacity_factor: float = 1.25  # dispatch capacity (dense dispatch)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    state_dim: int = 64
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64               # chunked-scan block length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix parameters."""

    head_dim: int = 64
    decay_lora: int = 64          # rank of the data-dependent decay LoRA
    gate_lora: int = 32           # rank of token-shift mix LoRAs


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str = "tiny"
    family: str = "dense"         # dense | ssm | hybrid | moe | audio | vlm
    source: str = ""              # citation for the exact numbers

    # trunk --------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096

    # flavour ------------------------------------------------------------
    activation: str = "silu"      # silu | gelu | relu2  (relu2 => non-gated)
    gated_mlp: bool = True
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_type: str = "gqa"        # gqa | mla | none
    pos_type: str = "rope"        # rope | mrope | learned | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # partial-rotary fraction (GLM uses 0.5)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0       # 0 => full attention

    # sub-family configs ---------------------------------------------------
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # layer pattern for hybrids; "M"=mamba2, "A"=attention, "R"=rwkv6,
    # "D"=dense attn+mlp. Empty => homogeneous from family/attn_type.
    layer_pattern: str = ""
    shared_attn_period: int = 0   # zamba2: weight-tied attn block every k layers

    # encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0      # fixed encoder frames (whisper: 1500)

    # multimodal stub -----------------------------------------------------
    vision_tokens: int = 0        # VLM: patch-embedding tokens per request
    audio_frontend: bool = False  # whisper: precomputed frame embeddings

    # numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ----------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolve the per-layer block kinds for this architecture."""
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.num_layers, (
                f"{self.name}: layer_pattern len {len(self.layer_pattern)} "
                f"!= num_layers {self.num_layers}")
            return tuple(self.layer_pattern)
        if self.family == "ssm" and self.rwkv is not None:
            return tuple("R" * self.num_layers)
        if self.family == "ssm":
            return tuple("M" * self.num_layers)
        return tuple("D" * self.num_layers)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_active_params_analytic
        return count_active_params_analytic(self)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def get_shape(name: str) -> ShapeSpec:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; choose from {sorted(INPUT_SHAPES)}")
