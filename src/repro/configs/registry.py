"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

# arch id -> module under repro.configs
_ARCH_MODULES: Dict[str, str] = {
    "minicpm3-4b": "minicpm3_4b",
    "nemotron-4-15b": "nemotron4_15b",
    "glm4-9b": "glm4_9b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "zamba2-2.7b": "zamba2_2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-7b": "deepseek_7b",
}

ARCH_IDS: List[str] = sorted(_ARCH_MODULES)

# (arch, shape) combinations that are skipped by design; see DESIGN.md
# §Arch-applicability for the rationale.
SKIPPED_COMBOS = {
    ("whisper-base", "long_500k"): (
        "enc-dec audio model: no 524k-token decoder-stream analogue"),
}


def _module(arch: str):
    try:
        mod = _ARCH_MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def combo_is_skipped(arch: str, shape: str) -> str | None:
    return SKIPPED_COMBOS.get((arch, shape))
