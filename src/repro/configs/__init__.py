from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RWKVConfig,
    SSMConfig,
    ShapeSpec,
    get_shape,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    SKIPPED_COMBOS,
    combo_is_skipped,
    get_config,
    get_smoke_config,
)
