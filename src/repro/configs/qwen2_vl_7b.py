"""Qwen2-VL-7B — the paper's own cloud VLM; M-RoPE decoder backbone.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE, dynamic
resolution [arXiv:2409.12191]

Per the assignment carve-out the ViT vision encoder + projector is a STUB:
``input_specs`` provides precomputed patch embeddings (vision_tokens,
d_model) that are scattered into the token stream at image positions; we
implement the language decoder with multimodal rotary position embedding
(M-RoPE: head_dim split into temporal/height/width sections).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        source="arXiv:2409.12191",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        activation="silu",
        pos_type="mrope",
        mrope_sections=(16, 24, 24),   # t/h/w over head_dim/2 = 64
        rope_theta=1_000_000.0,
        vision_tokens=1024,            # patch embeddings per request (stub)
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="qwen2-vl-7b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        vision_tokens=16,
        mrope_sections=(4, 6, 6),
        max_seq_len=512,
    )
