"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention.

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448 — MLA
[hf:openbmb/MiniCPM3-4B]
"""

from repro.configs.base import MLAConfig, ModelConfig

_MLA = MLAConfig(
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,              # v_head_dim; qk dims come from MLA config
        d_ff=6400,
        vocab_size=73448,
        attn_type="mla",
        mla=_MLA,
        activation="silu",
        tie_embeddings=True,
        rope_theta=10000.0,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="minicpm3-4b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        max_seq_len=512,
        mla=MLAConfig(q_lora_rank=96, kv_lora_rank=64,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=64),
    )
