"""GLM-4-9B — dense decoder, RoPE (partial rotary), aggressive GQA kv=2.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 [hf:THUDM/glm-4-9b]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        source="hf:THUDM/glm-4-9b",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=151552,
        activation="silu",
        rope_theta=10000.0,
        rope_fraction=0.5,        # GLM applies rotary to half the head dim
        max_seq_len=8192,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="glm4-9b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        max_seq_len=512,
    )
