"""Zamba2-2.7B — hybrid Mamba2 backbone with a shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242]

The backbone is Mamba2; a single weight-tied (shared) attention+MLP block
is applied every ``shared_attn_period`` layers (Zamba2 interleaves shared
blocks every ~6 layers). At long_500k the shared attention runs with a
sliding window so the KV cache stays bounded (hardware adaptation noted in
DESIGN.md).
"""

from repro.configs.base import ModelConfig, SSMConfig

_PERIOD = 6


def _pattern(n: int) -> str:
    # 'A' marks layers where the shared attention block runs before Mamba2.
    return "".join("A" if (i % _PERIOD == _PERIOD - 1) else "M"
                   for i in range(n))


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        source="arXiv:2411.15242",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, head_dim=64),
        layer_pattern=_pattern(54),
        shared_attn_period=_PERIOD,
        sliding_window=8192,      # bounds shared-attn KV at 500k decode
        max_seq_len=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="zamba2-2.7b-smoke",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2, head_dim=32,
                      chunk=16),
        layer_pattern="MAMA",
        shared_attn_period=2,
        sliding_window=128,
    )
