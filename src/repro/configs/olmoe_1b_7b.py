"""OLMoE-1B-7B — fully sparse MoE decoder, 64 experts top-8.

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8
[arXiv:2409.02060]
"""

from repro.configs.base import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        source="arXiv:2409.02060",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        qk_norm=True,             # OLMoE applies QK-norm
        moe=MoEConfig(num_experts=64, experts_per_token=8, d_ff=1024,
                      router_aux_coef=0.01),
        rope_theta=10000.0,
        max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="olmoe-1b-7b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        # capacity_factor = E/k ⇒ zero drops ⇒ chunking-invariant routing
        moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff=128,
                      capacity_factor=2.0),
    )
