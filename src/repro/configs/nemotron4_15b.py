"""Nemotron-4-15B — dense decoder, GQA kv=8, squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819]
Nemotron-4 uses a non-gated squared-ReLU MLP and RoPE.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        source="arXiv:2402.16819",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        activation="relu2",
        gated_mlp=False,
        norm_eps=1e-5,
        rope_theta=10000.0,
        max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="nemotron-4-15b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=1024,
        vocab_size=512,
        max_seq_len=512,
    )
