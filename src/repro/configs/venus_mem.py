"""Venus MEM — the multimodal embedding model the paper builds memory with.

The paper uses BGE-VL-large [arXiv:2412.14475] (CLIP-family dual encoder).
We implement the same *shape* of model as a dual-tower encoder sharing our
transformer substrate: a text tower over tokens and a vision tower over
precomputed patch embeddings (frontend stubbed per the assignment
carve-out), each mean-pooled and projected into a shared, L2-normalised
embedding space. Trained with a SigLIP-style pairwise loss
(examples/train_mem.py).
"""

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MEMConfig:
    name: str = "venus-mem-large"
    embed_dim: int = 768           # shared image-text space
    text: ModelConfig = None       # type: ignore[assignment]
    vision: ModelConfig = None     # type: ignore[assignment]


def _tower(name: str, layers: int, d: int, heads: int, d_ff: int,
           vocab: int, seq: int, learned: bool = False) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=heads,
        head_dim=d // heads,
        d_ff=d_ff,
        vocab_size=vocab,
        activation="gelu",
        gated_mlp=False,
        pos_type="learned" if learned else "rope",
        max_seq_len=seq,
    )


def config() -> MEMConfig:
    # ~300M total: BGE-VL-large class.
    return MEMConfig(
        name="venus-mem-large",
        embed_dim=768,
        text=_tower("mem-text", 12, 768, 12, 3072, 32768, 512),
        vision=_tower("mem-vision", 12, 1024, 16, 4096, 0, 1024,
                      learned=True),
    )


def small_config() -> MEMConfig:
    """~100M-class MEM used by examples/train_mem.py."""
    return MEMConfig(
        name="venus-mem-small",
        embed_dim=512,
        text=_tower("mem-text-s", 6, 512, 8, 2048, 8192, 128),
        vision=_tower("mem-vision-s", 6, 640, 10, 2560, 0, 256,
                      learned=True),
    )


def smoke_config() -> MEMConfig:
    return MEMConfig(
        name="venus-mem-smoke",
        embed_dim=64,
        text=_tower("mem-text-smoke", 2, 64, 2, 128, 512, 32),
        vision=_tower("mem-vision-smoke", 2, 64, 2, 128, 0, 64,
                      learned=True),
    )
