"""Small shared helpers."""

from __future__ import annotations


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two ≥ n, floored at ``lo`` (itself a power of
    two). Used to bucket dynamic batch/prompt sizes so jit caches see
    O(log n) shapes instead of one per size."""
    b = lo
    while b < n:
        b *= 2
    return b
