"""Flash-decode Pallas kernels: one query token vs a long KV cache.

TPU-native tiling: the KV cache is streamed HBM→VMEM in ``(BLK_S, Hkv, D)``
blocks along the sequence; online-softmax accumulators (running max,
normaliser, weighted value sum) live in VMEM scratch across grid steps.
GQA query groups are packed as an (Hkv·G, D) matrix so the score matmul
hits the MXU. Two variants:

* ``gqa_decode``: scores q·kᵀ over head_dim; accumulates over v.
* ``mla_decode``: latent (matrix-absorbed) form — scores
  q_abs·ckvᵀ + q_rope·kropeᵀ, accumulates over ckv itself, so per-token
  cache traffic is kv_lora + rope bytes (576 B/token for DeepSeek-V2).

Grid: ``(B, S/BLK_S)`` with the sequence axis sequential ("arbitrary")
so scratch carries across blocks; batch is parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30
DEFAULT_BLK_S = 512


def _gqa_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                m_ref, l_ref, acc_ref, *, scale, softcap, q_per_kv,
                blocks):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (H, D)
    k = k_ref[0].astype(jnp.float32)             # (BLK, Hkv, D)
    v = v_ref[0].astype(jnp.float32)             # (BLK, Hkv, Dv)
    valid = valid_ref[0]                         # (BLK,)

    h, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(hkv, q_per_kv, d)
    # scores: (Hkv, G, BLK)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    s = s.reshape(h, -1)                         # (H, BLK)

    m_prev = m_ref[...]                          # (H, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)               # (H, 1)
    p = jnp.exp(s - m_new)                       # (H, BLK)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    # ctx: (Hkv, G, Dv) from p (Hkv, G, BLK) x v (BLK, Hkv, Dv)
    pg = p.reshape(hkv, q_per_kv, -1)
    ctx = jax.lax.dot_general(
        pg, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)      # (Hkv, G, Dv)
    acc_ref[...] = acc_ref[...] * corr + ctx.reshape(h, -1)
    m_ref[...] = m_new

    @pl.when(i == blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "q_per_kv",
                                             "blk_s", "interpret"))
def gqa_decode(q, k, v, valid, *, scale: float, softcap: float = 0.0,
               q_per_kv: int = 1, blk_s: int = DEFAULT_BLK_S,
               interpret: bool = True):
    """q: (B,1,H,D); k/v: (B,C,Hkv,D[v]); valid: (B,C) bool -> (B,1,H,Dv)."""
    b, _, h, d = q.shape
    c = k.shape[1]
    dv = v.shape[-1]
    hkv = k.shape[2]
    blk = min(blk_s, c)
    assert c % blk == 0, (c, blk)
    blocks = c // blk

    kernel = functools.partial(_gqa_kernel, scale=scale, softcap=softcap,
                               q_per_kv=q_per_kv, blocks=blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, blocks),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda bi, i: (bi, 0, 0, 0)),
            pl.BlockSpec((1, blk, hkv, d), lambda bi, i: (bi, i, 0, 0)),
            pl.BlockSpec((1, blk, hkv, dv), lambda bi, i: (bi, i, 0, 0)),
            pl.BlockSpec((1, blk), lambda bi, i: (bi, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, dv), lambda bi, i: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, valid)


# ---------------------------------------------------------------------------
# MLA latent decode
# ---------------------------------------------------------------------------


def _mla_kernel(qa_ref, qr_ref, ckv_ref, kr_ref, valid_ref, o_ref,
                m_ref, l_ref, acc_ref, *, scale, blocks):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qa = qa_ref[0, 0].astype(jnp.float32)        # (H, R)
    qr = qr_ref[0, 0].astype(jnp.float32)        # (H, Dr)
    ckv = ckv_ref[0].astype(jnp.float32)         # (BLK, R)
    kr = kr_ref[0].astype(jnp.float32)           # (BLK, Dr)
    valid = valid_ref[0]                         # (BLK,)

    s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale
    s = jnp.where(valid[None, :], s, NEG_INF)    # (H, BLK)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    ctx = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (H, R)
    acc_ref[...] = acc_ref[...] * corr + ctx
    m_ref[...] = m_new

    @pl.when(i == blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "blk_s", "interpret"))
def mla_decode(q_abs, q_rope, ckv, krope, valid, *, scale: float,
               blk_s: int = DEFAULT_BLK_S, interpret: bool = True):
    """q_abs: (B,1,H,R); q_rope: (B,1,H,Dr); ckv: (B,C,R); krope: (B,C,Dr);
    valid: (B,C) -> latent ctx (B,1,H,R)."""
    b, _, h, r = q_abs.shape
    c = ckv.shape[1]
    dr = q_rope.shape[-1]
    blk = min(blk_s, c)
    assert c % blk == 0, (c, blk)
    blocks = c // blk

    kernel = functools.partial(_mla_kernel, scale=scale, blocks=blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, blocks),
        in_specs=[
            pl.BlockSpec((1, 1, h, r), lambda bi, i: (bi, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, dr), lambda bi, i: (bi, 0, 0, 0)),
            pl.BlockSpec((1, blk, r), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, blk, dr), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, blk), lambda bi, i: (bi, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, r), lambda bi, i: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, h, r), q_abs.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, r), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(q_abs, q_rope, ckv, krope, valid)
