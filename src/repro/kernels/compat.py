"""Version compat for Pallas TPU compiler params.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(and, earlier still, exposed a plain dict). The kernels only set
``dimension_semantics``; this helper builds whichever object the
installed JAX understands so the same kernel source compiles across
versions.
"""

from __future__ import annotations

from typing import Tuple

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(dimension_semantics: Tuple[str, ...]):
    """compiler_params= value with the given dimension semantics."""
    if _PARAMS_CLS is not None:
        return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))
    # very old JAX: pallas_call accepted a mosaic params dict
    return dict(mosaic=dict(dimension_semantics=tuple(dimension_semantics)))
