"""Kernel dispatch layer.

Every hot-spot op has two implementations: the Pallas TPU kernel and the
pure-jnp oracle (``ref.py``). The backend is selected by
``REPRO_KERNEL_BACKEND`` (default ``jnp`` — XLA fuses the references well
on CPU, and the dry-run lowers the jnp path so cost_analysis reflects
plain HLO). ``pallas`` switches to the kernels; on CPU they execute in
interpret mode, on TPU they compile natively.
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ref
from repro.launch.sharding import mesh_axis_size, shard_map

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "jnp")


def backend() -> str:
    return _BACKEND


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("jnp", "pallas"), name
    _BACKEND = name


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# Dispatch-level launch accounting: every similarity scan entering the
# kernel layer is counted here, independent of backend, so the query-plan
# executor's "ONE similarity_scan_stack launch per execution group"
# invariant is assertable at the layer that actually launches the scan
# (manager/memory io_stats only see their own call sites).
#
# The fusion/quantisation savings are measurable, not anecdotal:
# ``scan_bytes`` accumulates the index bytes streamed by every scan
# (int8 indices count 1 byte/element — the 4× bandwidth lever);
# ``fused_draw_launches`` counts scans whose draws/top-k were resolved
# in the fused epilogue (no (S,Q,N) score tensor materialised);
# ``dense_score_launches`` counts scans that DID materialise dense
# scores (the BOLT/MDF/AKS fallback and every legacy ``search`` call).
#
# Sharded-arena accounting: ``sharded_stack_launches`` counts stack
# scans fanned out per shard via shard_map (K > 1 — the K == 1 mesh
# short-circuits to the single-device path, bit-identically);
# ``shard_gather_bytes`` accumulates the bytes the sharded launches'
# OUTPUTS move across shard boundaries — O(S·Q·(T+K)) for the fused
# scan, O(S·Q·N) for a dense sharded scan — computed from the actual
# output arrays, so the "only the epilogue crosses" contract is a
# counter assertion, not a claim. (Counters are host-side: they bump at
# the dispatch call site, never inside a traced shard_map body.)
#
# Two-stage (hierarchical-tier) accounting: ``coarse_scan_bytes`` is the
# subset of ``scan_bytes`` streamed by stage-1 scans over the coarse
# summary tier; ``fine_gather_rows`` counts the candidate fine rows
# stage 2 gathers into its per-query scan operand (winner blocks ×
# block rows, padding slots included — the honest operand size);
# ``two_stage_scans`` counts completed coarse→fine retrievals. Together
# they pin the tier's bandwidth claim: coarse_scan_bytes + the gathered
# candidate bytes must undercut the flat 1×-capacity scan.
_scan_counts = {"similarity": 0, "similarity_stack": 0,
                "scan_bytes": 0, "fused_draw_launches": 0,
                "dense_score_launches": 0,
                "sharded_stack_launches": 0, "shard_gather_bytes": 0,
                "coarse_scan_bytes": 0, "fine_gather_rows": 0,
                "two_stage_scans": 0, "standing_scan_bytes": 0}


def _count_scan_bytes(index) -> None:
    _scan_counts["scan_bytes"] += index.size * index.dtype.itemsize


def count_fine_gather(n_rows: int) -> None:
    """Host-side stage-2 accounting hook for the tiering layer: the
    candidate rows gathered out of the fine arena for one two-stage
    retrieval (counted at dispatch, never inside a traced body)."""
    _scan_counts["fine_gather_rows"] += int(n_rows)
    _scan_counts["two_stage_scans"] += 1


def scan_counts() -> dict:
    return dict(_scan_counts)


def reset_scan_counts() -> None:
    for k in _scan_counts:
        _scan_counts[k] = 0


# ---------------------------------------------------------------------------


def decode_attention(q, k, v, valid, *, scale: float, softcap: float = 0.0,
                     q_per_kv: int = 1) -> jnp.ndarray:
    """q: (B,1,H,D); k/v: (B,C,Hkv,D); valid: (B or 1, C) -> (B,1,H,D)."""
    if _BACKEND == "pallas":
        from repro.kernels import decode_attention as dk
        b, c = q.shape[0], k.shape[1]
        vmask = jnp.broadcast_to(valid, (b, c))
        blk = c if c <= dk.DEFAULT_BLK_S else _largest_divisor_blk(
            c, dk.DEFAULT_BLK_S)
        return dk.gqa_decode(q, k, v, vmask, scale=scale, softcap=softcap,
                             q_per_kv=q_per_kv, blk_s=blk,
                             interpret=_interpret())
    return ref.decode_attention_ref(q, k, v, valid, scale=scale,
                                    softcap=softcap, q_per_kv=q_per_kv)


def mla_decode_attention(q_abs, q_rope, ckv, krope, valid, *,
                         scale: float) -> jnp.ndarray:
    if _BACKEND == "pallas":
        from repro.kernels import decode_attention as dk
        b, c = q_abs.shape[0], ckv.shape[1]
        vmask = jnp.broadcast_to(valid, (b, c))
        blk = c if c <= dk.DEFAULT_BLK_S else _largest_divisor_blk(
            c, dk.DEFAULT_BLK_S)
        return dk.mla_decode(q_abs, q_rope, ckv, krope, vmask, scale=scale,
                             blk_s=blk, interpret=_interpret())
    return ref.mla_decode_attention_ref(q_abs, q_rope, ckv, krope, valid,
                                        scale=scale)


def similarity(query, index, *, tau: float, valid
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """query (Q,d) × index (N,d) -> (sims (Q,N), probs (Q,N))."""
    _scan_counts["similarity"] += 1
    _scan_counts["dense_score_launches"] += 1
    _count_scan_bytes(index)
    if _BACKEND == "pallas":
        from repro.kernels import similarity as sk
        n = index.shape[0]
        blk = n if n <= sk.DEFAULT_BLK_N else _largest_divisor_blk(
            n, sk.DEFAULT_BLK_N)
        sims, m, l = sk.similarity_scan(query, index, valid, tau=tau,
                                        blk_n=blk, interpret=_interpret())
        logits = jnp.where(valid[None, :], sims / tau, ref.NEG_INF)
        probs = jnp.exp(logits - m) / jnp.maximum(l, 1e-30)
        return sims.astype(query.dtype), probs
    return ref.similarity_ref(query, index, tau=tau, valid=valid)


def _similarity_stack_local(query, index, valid, *, tau: float,
                            backend: str):
    """The per-(shard-local) stack-scan body — every lane's math is
    per-session, so running it on an (S/K, …) slab inside shard_map is
    exactly the single-device computation restricted to that slab."""
    if backend == "pallas":
        from repro.kernels import similarity as sk
        sims, m, l = sk.similarity_scan_stack(query, index, valid, tau=tau,
                                              interpret=_interpret())
        vmask = ref.as_valid_mask(valid, index.shape[1])
        logits = jnp.where(vmask[:, None, :], sims / tau, ref.NEG_INF)
        probs = jnp.exp(logits - m) / jnp.maximum(l, 1e-30)
        return sims.astype(query.dtype), probs
    return ref.similarity_stack_ref(query, index, tau=tau, valid=valid)


def _valid_spec(valid, mesh_axis: str) -> P:
    """Partition spec of the canonical ``valid`` operand: the leading
    axis is always the session/slot axis, whatever the form (mask,
    sizes vector, or (S, 2) windows)."""
    return P(mesh_axis) if valid.ndim == 1 else P(mesh_axis, None)


@functools.partial(jax.jit,
                   static_argnames=("tau", "backend", "mesh", "mesh_axis"))
def _similarity_stack_sharded(query, index, valid, *, tau: float,
                              backend: str, mesh, mesh_axis: str):
    """Fan the stack scan out per shard: each device scans its
    contiguous slot slab with the identical kernel/oracle body; the
    out_specs stitch the per-shard (S/K, Q, N) outputs back together."""
    local = functools.partial(_similarity_stack_local, tau=tau,
                              backend=backend)
    sp = P(mesh_axis, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(sp, sp, _valid_spec(valid, mesh_axis)),
        out_specs=(sp, sp))(query, index, valid)


def similarity_stack(query, index, *, tau: float, valid, mesh=None,
                     mesh_axis: str = "model"
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-session scan: query (S,Q,d) × index (S,N,d) + valid —
    a (S,N) bool mask, a (S,) int sizes vector, or a (S,2) int
    ``[start,size)`` ring-window array (arena/eviction paths: the
    per-session valid masks derive on device — ``ref.as_valid_mask``)
    -> (sims (S,Q,N), probs (S,Q,N)) in ONE kernel launch.

    With ``mesh`` carrying K > 1 shards on ``mesh_axis`` the launch runs
    as a shard_map over contiguous slot slabs (the sharded arena's
    placement); per-lane math makes the result bit-identical to the
    single-device scan. K == 1 (or mesh None) short-circuits to the
    plain path."""
    _scan_counts["similarity_stack"] += 1
    _scan_counts["dense_score_launches"] += 1
    _count_scan_bytes(index)
    if mesh is not None and mesh_axis_size(mesh, mesh_axis) > 1:
        assert query.shape[0] % mesh_axis_size(mesh, mesh_axis) == 0, \
            (query.shape, dict(mesh.shape))
        sims, probs = _similarity_stack_sharded(
            query, index, valid, tau=tau, backend=_BACKEND, mesh=mesh,
            mesh_axis=mesh_axis)
        _scan_counts["sharded_stack_launches"] += 1
        _scan_counts["shard_gather_bytes"] += int(
            sims.size * sims.dtype.itemsize
            + probs.size * probs.dtype.itemsize)
        return sims, probs
    return _similarity_stack_local(query, index, valid, tau=tau,
                                   backend=_BACKEND)


class FusedRetrieval(NamedTuple):
    """Finalised fused-retrieval result — what the query-plan executor
    consumes. No (S, Q, N) tensor anywhere in the contract."""
    draws: jnp.ndarray          # (S, Q, T) int32 lane draws (clipped)
    drawn_p: jnp.ndarray        # (S, Q, T) f32 probability of each draw
    topk_v: jnp.ndarray         # (S, Q, K) f32 top-k scores (desc)
    topk_i: jnp.ndarray         # (S, Q, K) int32 top-k lane indices
    m: jnp.ndarray              # (S, Q, 1) f32 online-softmax max
    l: jnp.ndarray              # (S, Q, 1) f32 online-softmax sum-exp
    p_max: jnp.ndarray          # (S, Q, 1) f32 max probability


def _fused_retrieve_local(query, index, valid, targets, *, tau: float,
                          n_topk: int, backend: str):
    """Per-(shard-local) fused-retrieval body: the raw 8-tuple
    ``(cnt, dp, p_last, tv, ti, m, l, p_max)``, every output with a
    leading session axis. All draw counts and top-k indices are
    SESSION-LOCAL lane indices, so a shard computes them for its slab
    without any global-id offset — the gather is a pure concatenation."""
    if backend == "pallas":
        from repro.kernels import similarity as sk
        cnt, dp, p_last, tv, ti, m, l = sk.fused_retrieve_scan_stack(
            query, index, valid, targets, tau=tau, n_topk=n_topk,
            interpret=_interpret())
        # the max-probability lane is exp(m − m)/l == 1/l, bitwise the
        # value a max over this backend's materialised probs would find
        p_max = 1.0 / jnp.maximum(l, 1e-30)
        return cnt, dp, p_last, tv, ti, m, l, p_max
    # plain tuple (not the NamedTuple): shard_map matches out_specs
    # against the pytree STRUCTURE, which must be backend-independent
    return tuple(ref.fused_retrieve_stack_ref(query, index, valid,
                                              targets, tau=tau,
                                              n_topk=n_topk))


@functools.partial(jax.jit, static_argnames=("tau", "n_topk", "backend",
                                             "mesh", "mesh_axis"))
def _fused_retrieve_sharded(query, index, valid, targets, *, tau: float,
                            n_topk: int, backend: str, mesh,
                            mesh_axis: str):
    """Per-shard fused launches: each device runs the full fused scan on
    its contiguous slot slab; only the O(S·Q·(T+K)) epilogue outputs are
    stitched across shards (the top-M candidate gather — no recall loss
    because draws/top-k are per-lane and lanes never span shards)."""
    local = functools.partial(_fused_retrieve_local, tau=tau,
                              n_topk=n_topk, backend=backend)
    sp = P(mesh_axis, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(sp, sp, _valid_spec(valid, mesh_axis), sp),
        out_specs=(sp,) * 8)(query, index, valid, targets)


def fused_retrieve_stack(query, index, *, tau: float, valid, targets,
                         n_topk: int, mesh=None,
                         mesh_axis: str = "model",
                         tier: str = "fine") -> FusedRetrieval:
    """One-launch fused retrieval: query (S,Q,d) × index (S,N,d) fp32 or
    int8 + valid (any canonical mask form) + targets (S,Q,T) inverse-CDF
    draw targets -> draws, drawn probabilities, top-k, softmax stats.

    Draws are bit-identical to running the canonical chunked inverse-CDF
    (``draws.categorical_from_targets``) over this backend's materialised
    probabilities, and topk_i to ``lax.top_k`` over its masked scores —
    without ever materialising them on the fused (pallas) backend. The
    clip-to-cap-1 / p_last substitution for targets beyond the
    accumulated total mass happens here, identically for both backends.

    With ``mesh`` carrying K > 1 shards on ``mesh_axis``, the launch
    fans out per shard over contiguous slot slabs and only the epilogue
    outputs — O(S·Q·(T+K)) bytes, counted into ``shard_gather_bytes`` —
    cross shard boundaries; K == 1 (or mesh None) short-circuits to the
    single-device launch, bit-identically.

    ``tier="coarse"`` marks the launch as a stage-1 scan over the
    hierarchical summary tier: identical math, but the streamed bytes
    are additionally counted into ``coarse_scan_bytes`` so the
    two-stage bandwidth claim stays a counter assertion.
    ``tier="standing"`` marks the launch as a standing-query evaluation
    over the tick's new-row slab: the streamed bytes additionally count
    into ``standing_scan_bytes``, pinning the "no full-capacity
    re-scan" contract (the operand is the compact slab, so the counter
    is O(new_rows · d) by construction).
    """
    assert tier in ("fine", "coarse", "standing"), tier
    _scan_counts["similarity_stack"] += 1
    _scan_counts["fused_draw_launches"] += 1
    _count_scan_bytes(index)
    if tier == "coarse":
        _scan_counts["coarse_scan_bytes"] += int(
            index.size * index.dtype.itemsize)
    elif tier == "standing":
        _scan_counts["standing_scan_bytes"] += int(
            index.size * index.dtype.itemsize)
    n = index.shape[1]
    if mesh is not None and mesh_axis_size(mesh, mesh_axis) > 1:
        assert query.shape[0] % mesh_axis_size(mesh, mesh_axis) == 0, \
            (query.shape, dict(mesh.shape))
        r = _fused_retrieve_sharded(query, index, valid, targets, tau=tau,
                                    n_topk=n_topk, backend=_BACKEND,
                                    mesh=mesh, mesh_axis=mesh_axis)
        _scan_counts["sharded_stack_launches"] += 1
        _scan_counts["shard_gather_bytes"] += int(
            sum(a.size * a.dtype.itemsize for a in r))
    else:
        r = _fused_retrieve_local(query, index, valid, targets, tau=tau,
                                  n_topk=n_topk, backend=_BACKEND)
    cnt, dp, p_last, tv, ti, m, l, p_max = r
    draws = jnp.clip(cnt, 0, n - 1).astype(jnp.int32)
    drawn_p = jnp.where(cnt >= n, p_last, dp)
    return FusedRetrieval(draws, drawn_p, tv, ti, m, l, p_max)


def scene_score(frames, weights) -> jnp.ndarray:
    """frames (T,H,W,3) in [0,1] -> φ (T,)."""
    if _BACKEND == "pallas":
        from repro.kernels import scene_score as sk
        return sk.scene_score(frames, tuple(weights),
                              interpret=_interpret())
    return ref.scene_score_ref(frames, tuple(weights))


def _largest_divisor_blk(n: int, target: int) -> int:
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return n
