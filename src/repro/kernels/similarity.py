"""Fused cosine-similarity scan over the Venus memory index (Eq. 4–5).

The memory index is an (N, d) matrix of MEM embeddings; each query scans
all of it (exact search — see DESIGN.md on why brute-force MXU matmul
replaces FAISS ANN on TPU). The kernel streams the index HBM→VMEM in
(BLK_N, d) blocks, L2-normalises rows in-register, computes the (Q, BLK_N)
cosine block on the MXU, and maintains online max / sum-exp accumulators
so the temperature-softmax denominator (Eq. 5) comes out of the same pass.
The wrapper finishes probs = exp(s/τ − m)/l — an O(N) vector epilogue XLA
fuses with the consumer.

Grid: ``(N/BLK_N,)`` sequential, queries resident in VMEM.

``similarity_scan_stack`` is the cross-session form: a padded stack of S
session indices ``(S, capacity, d)`` with per-session valid masks and a
per-session query block ``(S, Q, d)`` scanned by ONE program over grid
``(S, capacity/BLK_N)`` — the multi-tenant edge box's whole query tick
in a single kernel launch. Capacities that do not divide the block size
are zero-padded by the wrapper (pad lanes are masked invalid, so they
contribute nothing to the softmax statistics).

Tier-agnostic by design (the hierarchical consolidation tier rides on
this): the same stack kernels scan the FINE arena ``(S, capacity, d)``
and the COARSE summary tier ``(S, n_coarse, d)`` — a coarse stage-1
scan is just a stack launch with a smaller N and the coarse validity
mask, and stage 2 re-enters as a ``(S·Q, B·block, d)`` scan over
gathered candidates. Nothing in this module knows which tier it is
scanning; the stage-1/stage-2 bookkeeping (``coarse_scan_bytes``,
``fine_gather_rows``) lives at the ``kernels.ops`` dispatch layer, and
the orchestration in ``core.tiering``. Since summary centroids are
means of unit rows, the in-register L2 row normalisation below is also
what makes block/consolidated centroids comparable to fine rows under
one cosine — keep it.

Shard-local entry contract (the sharded arena rides on this): every
stack kernel in this module is a pure per-lane program — softmax
statistics, inverse-CDF draw counts, and top-k selections are all
computed within one session lane, and the lane indices they emit are
SESSION-LOCAL. ``kernels.ops`` therefore fans a stack launch out over
mesh shards by calling these very kernels on each shard's contiguous
``(S/K, capacity, ·)`` slot slab inside shard_map, with NO kernel
changes and no global-id rebasing: the sharded result is the
single-device result restricted to the slab, concatenated. Anything
added here must preserve that property (no cross-lane reductions, no
absolute-S-dependent constants) or the arena's shard fan-out breaks.

Layer invariant — what ``valid`` means here: the kernels never trust
row CONTENT, only the mask. Callers may pass the mask in any of the
three canonical forms (explicit ``(S, N)`` bool; ``(S,)`` prefix sizes;
``(S, 2)`` ``[start, size)`` ring windows for sessions under
sliding-window eviction) and it is normalised on device by ONE shared
helper, ``ref.as_valid_mask`` — so stale rows (evicted, recycled-slot,
or block padding) can never leak into the softmax statistics no matter
which path produced the operand. The index/query buffers are borrowed
for the duration of the call: the kernel neither owns nor caches them,
so donation-invalidated handles are the CALLER's problem (re-read views
from the arena after any ingest tick — see ``core.memory``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.draws import DRAW_BLK, chunk_cdf
from repro.kernels.ref import as_valid_mask

NEG_INF = -1e30
DEFAULT_BLK_N = 1024


def _aligned_blk(n: int, blk_n: int) -> int:
    """Scan block size for an index of n rows. When blk_n is a DRAW_BLK
    multiple (the default path), the block is kept a DRAW_BLK multiple
    too, so the fused epilogue's draw-CDF chunks tile every scan block
    exactly — a requirement for the chunked CDF fold (and therefore the
    draws) to be bit-identical between the fused kernel and the
    materialised path, whatever the capacity. Other block sizes (test
    sweeps) fall back to the legacy min(blk_n, n)."""
    if blk_n % DRAW_BLK == 0:
        return min(blk_n, DRAW_BLK * (-(-n // DRAW_BLK)))
    return min(blk_n, n)


def _sim_kernel(q_ref, x_ref, valid_ref, sims_ref, m_ref, l_ref,
                m_acc, l_acc, *, tau, blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[...].astype(jnp.float32)            # (Q, d) pre-normalised
    x = x_ref[...].astype(jnp.float32)            # (BLK, d)
    valid = valid_ref[0]                          # (BLK,)

    xn = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)
    s = jax.lax.dot_general(q, xn, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, BLK)
    sims_ref[...] = s.astype(sims_ref.dtype)

    logit = jnp.where(valid[None, :], s / tau, NEG_INF)
    m_prev = m_acc[...]                           # (Q, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(logit, -1))[:, None]
    corr = jnp.exp(m_prev - m_new)
    l_acc[...] = l_acc[...] * corr + jnp.sum(
        jnp.exp(logit - m_new), -1, keepdims=True)
    m_acc[...] = m_new

    @pl.when(i == blocks - 1)
    def _final():
        m_ref[...] = m_acc[...]
        l_ref[...] = l_acc[...]


@functools.partial(jax.jit, static_argnames=("tau", "blk_n", "interpret"))
def similarity_scan(query, index, valid, *, tau: float,
                    blk_n: int = DEFAULT_BLK_N, interpret: bool = True):
    """query: (Q,d); index: (N,d); valid: (N,) bool.

    Returns (sims (Q,N), m (Q,1), l (Q,1)) — cosine scores plus the online
    softmax statistics. probs = exp(sims/τ − m) / l on valid entries.
    N is zero-padded (invalid lanes) up to a block multiple, the same
    treatment as the stacked wrapper — any index length works with any
    block size.
    """
    qn, d = query.shape
    n = index.shape[0]
    blk = _aligned_blk(n, blk_n)
    pad = (-n) % blk
    if pad:
        index = jnp.pad(index, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    npad = n + pad
    blocks = npad // blk

    q32 = query.astype(jnp.float32)
    qnorm = q32 * jax.lax.rsqrt(
        jnp.sum(q32 * q32, -1, keepdims=True) + 1e-12)

    kernel = functools.partial(_sim_kernel, tau=tau, blocks=blocks)
    sims, m, l = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((qn, d), lambda i: (0, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((qn, blk), lambda i: (0, i)),
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, npad), jnp.float32),
            jax.ShapeDtypeStruct((qn, 1), jnp.float32),
            jax.ShapeDtypeStruct((qn, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, 1), jnp.float32),
            pltpu.VMEM((qn, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(qnorm, index, valid[None, :])
    return sims[:, :n], m, l


# ---------------------------------------------------------------------------
# Cross-session padded-stack scan
# ---------------------------------------------------------------------------


def _sim_stack_kernel(q_ref, x_ref, valid_ref, sims_ref, m_ref, l_ref,
                      m_acc, l_acc, *, tau, blocks):
    i = pl.program_id(1)                          # block within session s

    @pl.when(i == 0)
    def _init():                                  # fresh stats per session
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0].astype(jnp.float32)              # (Q, d) pre-normalised
    x = x_ref[0].astype(jnp.float32)              # (BLK, d)
    valid = valid_ref[0]                          # (BLK,)

    xn = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)
    s = jax.lax.dot_general(q, xn, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, BLK)
    sims_ref[0] = s.astype(sims_ref.dtype)

    logit = jnp.where(valid[None, :], s / tau, NEG_INF)
    m_prev = m_acc[...]                           # (Q, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(logit, -1))[:, None]
    corr = jnp.exp(m_prev - m_new)
    l_acc[...] = l_acc[...] * corr + jnp.sum(
        jnp.exp(logit - m_new), -1, keepdims=True)
    m_acc[...] = m_new

    @pl.when(i == blocks - 1)
    def _final():
        m_ref[0] = m_acc[...]
        l_ref[0] = l_acc[...]


@functools.partial(jax.jit, static_argnames=("tau", "blk_n", "interpret"))
def similarity_scan_stack(query, index, valid, *, tau: float,
                          blk_n: int = DEFAULT_BLK_N,
                          interpret: bool = True):
    """query: (S,Q,d); index: (S,N,d); valid: (S,N) bool, (S,) int
    per-session sizes, or (S,2) int ``[start,size)`` ring windows (the
    arena passes windows — a sliding-window session's valid region
    wraps around capacity — and the mask materialises here, inside the
    jit: no host-side mask build, see ``ref.as_valid_mask``).

    One program over all S session indices: grid (S, N/BLK). Returns
    (sims (S,Q,N), m (S,Q,1), l (S,Q,1)); probs = exp(sims/τ − m)/l on
    valid entries, per session. N is zero-padded (invalid lanes) up to a
    block multiple, so any capacity works with any block size.
    """
    sn, qn, d = query.shape
    n = index.shape[1]
    valid = as_valid_mask(valid, n)
    blk = _aligned_blk(n, blk_n)
    pad = (-n) % blk
    if pad:
        index = jnp.pad(index, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    npad = n + pad
    blocks = npad // blk

    q32 = query.astype(jnp.float32)
    qnorm = q32 * jax.lax.rsqrt(
        jnp.sum(q32 * q32, -1, keepdims=True) + 1e-12)

    kernel = functools.partial(_sim_stack_kernel, tau=tau, blocks=blocks)
    sims, m, l = pl.pallas_call(
        kernel,
        grid=(sn, blocks),
        in_specs=[
            pl.BlockSpec((1, qn, d), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, blk, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, blk), lambda s, i: (s, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, qn, blk), lambda s, i: (s, 0, i)),
            pl.BlockSpec((1, qn, 1), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, 1), lambda s, i: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sn, qn, npad), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, 1), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, 1), jnp.float32),
            pltpu.VMEM((qn, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(qnorm, index, valid)
    return sims[:, :, :n], m, l


# ---------------------------------------------------------------------------
# Fused retrieval scan: draws + top-k inside the launch, no (S,Q,N) output
# ---------------------------------------------------------------------------


def _fused_stack_kernel(q_ref, x_ref, valid_ref, t_ref,
                        cnt_ref, dp_ref, plast_ref, tv_ref, ti_ref,
                        m_ref, l_ref,
                        m_acc, l_acc, carry_acc, cnt_acc, dp_acc,
                        tv_acc, ti_acc,
                        *, tau, blocks, blk, last_blk, last_lane):
    """Two passes over a session's blocks in ONE grid walk (2·blocks
    steps; the index map re-fetches block ``i % blocks``).

    Pass 1 (i < blocks) is the standard online max/sum-exp scan. Pass 2
    revisits the same normalised blocks with the finalised (m, l): each
    block's probabilities ``exp(s/τ − m)/l`` are folded into the
    canonical chunked draw-CDF (``draws.chunk_cdf``, carry in scratch),
    every target accumulates its ``#{cdf ≤ t}`` lane count and its
    crossing-lane probability, and a running top-k merges the block's
    masked scores. Only O(Q·(T+K)) state ever leaves the kernel — the
    (Q, BLK) score tile dies in VMEM.
    """
    i = pl.program_id(1)                          # 0 .. 2*blocks-1
    qn = q_ref.shape[1]

    q = q_ref[0].astype(jnp.float32)              # (Q, d) pre-normalised
    x = x_ref[0].astype(jnp.float32)              # (BLK, d) int8 rows
    valid = valid_ref[0]                          # (BLK,)  dequantise here

    xn = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)
    s = jax.lax.dot_general(q, xn, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, BLK)
    logit = jnp.where(valid[None, :], s / tau, NEG_INF)

    @pl.when(i == 0)
    def _init():                                  # fresh stats per session
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    @pl.when(i < blocks)
    def _pass1():
        m_prev = m_acc[...]                       # (Q, 1)
        m_new = jnp.maximum(m_prev[:, 0], jnp.max(logit, -1))[:, None]
        corr = jnp.exp(m_prev - m_new)
        l_acc[...] = l_acc[...] * corr + jnp.sum(
            jnp.exp(logit - m_new), -1, keepdims=True)
        m_acc[...] = m_new

    @pl.when(i == blocks - 1)
    def _stats_out():
        m_ref[0] = m_acc[...]
        l_ref[0] = l_acc[...]

    @pl.when(i == blocks)
    def _init_epilogue():
        carry_acc[...] = jnp.zeros_like(carry_acc)
        cnt_acc[...] = jnp.zeros_like(cnt_acc)
        dp_acc[...] = jnp.zeros_like(dp_acc)
        tv_acc[...] = jnp.full_like(tv_acc, NEG_INF)
        # NEG_INF ties resolve to the lowest lane index, as in a global
        # top_k — seed the accumulator with indices 0..K-1
        ti_acc[...] = jax.lax.broadcasted_iota(jnp.int32, ti_acc.shape, 1)

    @pl.when(i >= blocks)
    def _pass2():
        m = m_acc[...]                            # finalised stats
        l = jnp.maximum(l_acc[...], 1e-30)
        p = jnp.exp(logit - m) / l                # (Q, BLK) — bit-equal
                                                  # to the materialised
                                                  # probs epilogue
        carry = carry_acc[...]                    # (Q, 1)
        cdf = chunk_cdf(p.reshape(qn, blk // DRAW_BLK, DRAW_BLK),
                        carry).reshape(qn, blk)
        carry_acc[...] = cdf[:, -1:]
        t = t_ref[0]                              # (Q, T)
        le = cdf[:, None, :] <= t[:, :, None]     # (Q, T, BLK)
        cnt_acc[...] += jnp.sum(le.astype(jnp.int32), -1)
        # drawn probability: p at the unique crossing lane
        # (cdf > t and the previous lane's cdf ≤ t)
        prev = jnp.concatenate([carry, cdf[:, :-1]], -1)
        cross = (~le) & (prev[:, None, :] <= t[:, :, None])
        dp_acc[...] += jnp.sum(jnp.where(cross, p[:, None, :], 0.0), -1)

        j = i - blocks
        sv = jnp.where(valid[None, :], s, NEG_INF)
        gi = j * blk + jax.lax.broadcasted_iota(jnp.int32, sv.shape, 1)
        cand_v = jnp.concatenate([tv_acc[...], sv], -1)
        cand_i = jnp.concatenate([ti_acc[...], gi], -1)
        nv, sel = jax.lax.top_k(cand_v, tv_acc.shape[-1])
        tv_acc[...] = nv
        ti_acc[...] = jnp.take_along_axis(cand_i, sel, -1)

    @pl.when(i == blocks + last_blk)
    def _plast():
        m = m_acc[...]
        l = jnp.maximum(l_acc[...], 1e-30)
        p = jnp.exp(logit - m) / l
        plast_ref[0] = p[:, last_lane:last_lane + 1]

    @pl.when(i == 2 * blocks - 1)
    def _final():
        cnt_ref[0] = cnt_acc[...]
        dp_ref[0] = dp_acc[...]
        tv_ref[0] = tv_acc[...]
        ti_ref[0] = ti_acc[...]


@functools.partial(jax.jit,
                   static_argnames=("tau", "n_topk", "blk_n", "interpret"))
def fused_retrieve_scan_stack(query, index, valid, targets, *, tau: float,
                              n_topk: int, blk_n: int = DEFAULT_BLK_N,
                              interpret: bool = True):
    """One-launch fused retrieval over the session stack.

    query: (S,Q,d); index: (S,N,d) fp32 or int8 rows; valid in any
    canonical ``as_valid_mask`` form; targets: (S,Q,T) inverse-CDF draw
    targets in (0,1) (``draws.draw_targets``).

    Returns raw kernel outputs, the fused contract of
    ``ref.fused_retrieve_stack_ref`` — counts (S,Q,T) i32 UNCLIPPED
    ``#{cdf ≤ t}`` lane counts, drawn_p (S,Q,T) f32 crossing-lane
    probabilities (0 where the target overshot the total mass — the
    dispatch substitutes p_last there), p_last (S,Q,1), topk values and
    lane indices (S,Q,K), and the online-softmax stats m, l (S,Q,1).
    No (S,Q,N) tensor exists in HBM at any point.
    """
    sn, qn, d = query.shape
    n = index.shape[1]
    tn = targets.shape[2]
    assert blk_n % DRAW_BLK == 0, (blk_n, DRAW_BLK)
    assert 1 <= n_topk <= n, (n_topk, n)
    valid = as_valid_mask(valid, n)
    blk = _aligned_blk(n, blk_n)
    pad = (-n) % blk
    if pad:
        index = jnp.pad(index, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    npad = n + pad
    blocks = npad // blk

    q32 = query.astype(jnp.float32)
    qnorm = q32 * jax.lax.rsqrt(
        jnp.sum(q32 * q32, -1, keepdims=True) + 1e-12)

    kernel = functools.partial(
        _fused_stack_kernel, tau=tau, blocks=blocks, blk=blk,
        last_blk=(n - 1) // blk, last_lane=(n - 1) % blk)
    xmap = lambda s, i: (s, i % blocks, 0)
    vmap_ = lambda s, i: (s, i % blocks)
    out = pl.pallas_call(
        kernel,
        grid=(sn, 2 * blocks),
        in_specs=[
            pl.BlockSpec((1, qn, d), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, blk, d), xmap),
            pl.BlockSpec((1, blk), vmap_),
            pl.BlockSpec((1, qn, tn), lambda s, i: (s, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qn, tn), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, tn), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, 1), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, n_topk), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, n_topk), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, 1), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, qn, 1), lambda s, i: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sn, qn, tn), jnp.int32),
            jax.ShapeDtypeStruct((sn, qn, tn), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, 1), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, n_topk), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, n_topk), jnp.int32),
            jax.ShapeDtypeStruct((sn, qn, 1), jnp.float32),
            jax.ShapeDtypeStruct((sn, qn, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, 1), jnp.float32),
            pltpu.VMEM((qn, 1), jnp.float32),
            pltpu.VMEM((qn, 1), jnp.float32),
            pltpu.VMEM((qn, tn), jnp.int32),
            pltpu.VMEM((qn, tn), jnp.float32),
            pltpu.VMEM((qn, n_topk), jnp.float32),
            pltpu.VMEM((qn, n_topk), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(qnorm, index, valid, targets)
    return out
