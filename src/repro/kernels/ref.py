"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references: each kernel's test sweeps shapes and
dtypes and asserts allclose against the function here. They are also the
default execution backend on CPU (``REPRO_KERNEL_BACKEND=jnp``), so the
whole system runs without Pallas in the loop.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.draws import blockwise_cdf

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# decode attention (GQA)
# ---------------------------------------------------------------------------


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray, *, scale: float,
                         softcap: float = 0.0,
                         q_per_kv: int = 1) -> jnp.ndarray:
    """q: (B,1,H,D); k/v: (B,C,Hkv,D); valid: (B or 1, C) -> (B,1,H,D)."""
    b, _, h, d = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, 1, hkv, q_per_kv, d).astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgs", qg,
                        k.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = jnp.broadcast_to(valid, (b, valid.shape[-1]))
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return ctx.reshape(b, 1, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (MLA, matrix-absorbed latent form)
# ---------------------------------------------------------------------------


def mla_decode_attention_ref(q_abs: jnp.ndarray, q_rope: jnp.ndarray,
                             ckv: jnp.ndarray, krope: jnp.ndarray,
                             valid: jnp.ndarray, *, scale: float
                             ) -> jnp.ndarray:
    """q_abs: (B,1,H,R); q_rope: (B,1,H,Dr); ckv: (B,C,R);
    krope: (B,C,Dr); valid: (B or 1, C) -> latent context (B,1,H,R)."""
    b, _, h, r = q_abs.shape
    f32 = jnp.float32
    logits = (jnp.einsum("bqhr,bsr->bhs", q_abs.astype(f32),
                         ckv.astype(f32))
              + jnp.einsum("bqhd,bsd->bhs", q_rope.astype(f32),
                           krope.astype(f32))) * scale
    mask = jnp.broadcast_to(valid, (b, valid.shape[-1]))
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(f32))
    return ctx[:, None].astype(q_abs.dtype)


# ---------------------------------------------------------------------------
# fused cosine-similarity + temperature softmax over the memory index
# ---------------------------------------------------------------------------


def as_valid_mask(valid: jnp.ndarray, n: int) -> jnp.ndarray:
    """Canonical form of a stacked scan's ``valid`` argument. Three
    accepted forms, ONE definition shared by the Pallas wrapper, the
    oracle, and the ops dispatch layer, so the derived-mask semantics
    cannot diverge between them:

    * (S, N) bool mask — explicit per-row validity, passes through;
    * (S,) int sizes — per-session valid prefix ``[0, size)`` (the
      pre-eviction arena form; a window with ``start == 0``);
    * (S, 2) int ``[start, size]`` ring windows — valid rows are
      ``[start, start+size) mod N`` (the eviction path: a session's
      ``head`` advances on device-side sliding-window eviction, so the
      valid region wraps). Masks materialise here, on device — only
      the tiny sizes/window arrays ever cross the host boundary.
    """
    if valid.ndim == 1:
        return jnp.arange(n)[None, :] < valid[:, None]
    if (valid.ndim == 2 and valid.shape[-1] == 2
            and jnp.issubdtype(valid.dtype, jnp.integer)):
        j = jnp.arange(n)[None, :]
        return (j - valid[:, :1]) % n < valid[:, 1:2]
    return valid


def similarity_ref(query: jnp.ndarray, index: jnp.ndarray, *, tau: float,
                   valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """query: (Q,d); index: (N,d); valid: (N,) bool.

    Returns (sims (Q,N) cosine, probs (Q,N) temperature softmax over valid
    entries) — Eq. 4 + Eq. 5 of the paper in one op.
    """
    f32 = jnp.float32
    q = query.astype(f32)
    x = index.astype(f32)
    qn = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
    xn = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)
    sims = qn @ xn.T                                        # (Q,N)
    logits = jnp.where(valid[None, :], sims / tau, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return sims.astype(query.dtype), probs.astype(f32)


def similarity_stack_ref(query: jnp.ndarray, index: jnp.ndarray, *,
                         tau: float, valid: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-session form: query (S,Q,d); index (S,N,d); valid (S,N)
    bool mask, (S,) int per-session sizes, OR (S,2) int ``[start,size)``
    ring windows (the arena/eviction paths — the mask is derived on
    device here, see ``as_valid_mask``).

    Returns (sims (S,Q,N), probs (S,Q,N)) — per-session Eq. 4 + Eq. 5,
    vmapped so every lane matches ``similarity_ref`` on that session.
    """
    valid = as_valid_mask(valid, index.shape[1])
    fn = lambda q, x, v: similarity_ref(q, x, tau=tau, valid=v)
    return jax.vmap(fn)(query, index, valid)


# ---------------------------------------------------------------------------
# fused retrieval: scan + inverse-CDF draws + running top-k, one pass
# ---------------------------------------------------------------------------


class FusedRetrieveResult(NamedTuple):
    """Everything the retrieval strategies need, with NO (S, Q, N) score
    tensor in the contract: per-target inverse-CDF draw counts and drawn
    probabilities, the running top-k, and the online-softmax stats.

    ``counts`` are RAW lane counts (#{cdf ≤ t}, possibly == the padded
    lane total when t falls beyond the accumulated mass) — the dispatch
    layer clips them to cap-1 and substitutes ``p_last`` (the cap-1
    lane's probability) for the drawn probability in that edge, exactly
    what the materialised path's clipped gather produces."""
    counts: jnp.ndarray         # (S, Q, T) int32 raw cdf≤t lane counts
    drawn_p: jnp.ndarray        # (S, Q, T) f32 prob at the crossing lane
    p_last: jnp.ndarray         # (S, Q, 1) f32 prob of lane cap-1
    topk_v: jnp.ndarray         # (S, Q, K) f32 top-k sims (desc)
    topk_i: jnp.ndarray         # (S, Q, K) int32 top-k lane indices
    m: jnp.ndarray              # (S, Q, 1) f32 online-softmax max
    l: jnp.ndarray              # (S, Q, 1) f32 online-softmax sum-exp
    p_max: jnp.ndarray          # (S, Q, 1) f32 max probability


def fused_retrieve_stack_ref(query: jnp.ndarray, index: jnp.ndarray,
                             valid: jnp.ndarray, targets: jnp.ndarray, *,
                             tau: float, n_topk: int
                             ) -> FusedRetrieveResult:
    """Oracle for the fused retrieval scan: query (S,Q,d), index
    (S,N,d) fp32 or int8, valid in any canonical form, targets (S,Q,T)
    inverse-CDF draw targets.

    The oracle MAY materialise the (S,Q,N) scores internally (it is the
    correctness reference, not the bandwidth path); what it returns is
    exactly the fused kernel's contract. Draws use the canonical chunked
    CDF from ``kernels.draws`` — the same fold the kernel epilogue
    computes blockwise — and top-k matches ``lax.top_k`` over the masked
    scores (value-descending, ties to the lowest lane index).
    """
    n = index.shape[1]
    valid = as_valid_mask(valid, n)
    sims, probs = similarity_stack_ref(query, index, tau=tau, valid=valid)
    counts = jax.vmap(jax.vmap(
        lambda p, t: _raw_counts(p, t)))(probs, targets)
    clipped = jnp.clip(counts, 0, n - 1)
    drawn_p = jnp.take_along_axis(probs, clipped, axis=-1)
    p_last = probs[:, :, n - 1:n]
    masked = jnp.where(valid[:, None, :], sims.astype(jnp.float32),
                       NEG_INF)
    topk_v, topk_sel = jax.lax.top_k(masked, n_topk)
    logits = jnp.where(valid[:, None, :], sims.astype(jnp.float32) / tau,
                       NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    l = jnp.sum(jnp.exp(logits - m), axis=-1, keepdims=True)
    return FusedRetrieveResult(counts, drawn_p, p_last, topk_v,
                               topk_sel.astype(jnp.int32), m, l,
                               jnp.max(probs, axis=-1, keepdims=True))


def _raw_counts(probs: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Raw (unclipped) inverse-CDF lane counts — ``#{cdf ≤ t}`` over the
    canonical chunked CDF, the quantity the kernel accumulates."""
    cdf = blockwise_cdf(probs)
    return jnp.sum((cdf[None, :] <= t[:, None]).astype(jnp.int32),
                   axis=-1)


# ---------------------------------------------------------------------------
# scene score (Eq. 1): fused HSL+edge frame-difference metric
# ---------------------------------------------------------------------------


def _hsle(frame: jnp.ndarray) -> jnp.ndarray:
    """frame: (H,W,3) float in [0,1] -> (H,W,4) hue/sat/light/edge maps."""
    f32 = jnp.float32
    rgb = frame.astype(f32)
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    c = mx - mn
    light = 0.5 * (mx + mn)
    sat = c / (1.0 - jnp.abs(2.0 * light - 1.0) + 1e-6)
    safe_c = jnp.where(c > 0, c, 1.0)
    hue = jnp.where(
        mx == r, jnp.mod((g - b) / safe_c, 6.0),
        jnp.where(mx == g, (b - r) / safe_c + 2.0,
                  (r - g) / safe_c + 4.0)) / 6.0
    hue = jnp.where(c > 0, hue, 0.0)
    # edge map: L1 gradient magnitude of lightness (zero-padded)
    dx = jnp.abs(jnp.diff(light, axis=1, prepend=light[:, :1]))
    dy = jnp.abs(jnp.diff(light, axis=0, prepend=light[:1, :]))
    edge = dx + dy
    return jnp.stack([hue, sat, light, edge], axis=-1)


def scene_score_ref(frames: jnp.ndarray,
                    weights: Tuple[float, float, float, float]
                    ) -> jnp.ndarray:
    """frames: (T,H,W,3) in [0,1] -> phi (T,) per Eq. 1; phi[0] = 0."""
    w = jnp.asarray(weights, jnp.float32)
    feats = jax.vmap(_hsle)(frames)                       # (T,H,W,4)
    diffs = jnp.abs(feats[1:] - feats[:-1])               # (T-1,H,W,4)
    num = jnp.einsum("thwc,c->t", diffs, w)
    hw = frames.shape[1] * frames.shape[2]
    phi = num / (jnp.sum(w) * hw)
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), phi])
