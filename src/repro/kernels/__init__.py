# Pallas TPU kernels for the paper's compute hot spots:
#   scene_score       - Eq. 1 fused HSLE frame-difference (ingestion)
#   similarity        - Eq. 4/5 fused cosine + temperature softmax (query)
#   decode_attention  - flash-decode GQA/MLA (cloud VLM serving)
# Each has a pure-jnp oracle in ref.py and a dispatch wrapper in ops.py.
