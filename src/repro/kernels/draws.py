"""Canonical inverse-CDF draw primitives — the ONE definition of a
stochastic retrieval draw, shared verbatim by the materialised reference
path (``core.retrieval``), the jnp fused oracle (``kernels.ref``) and
the fused Pallas epilogue (``kernels.similarity``).

Why chunked: the fused kernel only ever holds one scan block of
probabilities in VMEM, so the draw must be defined over a *chunked*
left-fold CDF (DRAW_BLK lanes per chunk, sequential fp32 carry between
chunks). A flat ``jnp.cumsum`` over the whole probability vector would
not decompose into per-block work bit-for-bit (float associativity), so
it is NOT the definition — the chunked fold is. Both the materialised
and fused paths compute this exact fold, which is what makes fused
draws draw-for-draw bit-identical to the materialised path.

Variates: one ``jax.random.randint`` in [0, 2^DRAW_U_BITS) per draw —
the same 20-bit integer-variate contract as the member-pick variates in
``core.memory`` (``(u * cnt) >> U_BITS``). The target of draw i is
t_i = (u_i + 0.5) / 2^DRAW_U_BITS ∈ (0, 1); the draw is the first lane
whose CDF exceeds t_i (== the count of lanes with cdf ≤ t_i), clipped
to cap-1 when t_i falls beyond the accumulated total mass (fp32
summation of a softmax can land marginally below 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DRAW_U_BITS = 20
DRAW_U_CARD = 1 << DRAW_U_BITS
DRAW_BLK = 256


def draw_targets(key, n: int) -> jnp.ndarray:
    """n inverse-CDF targets in (0, 1). One key consumption."""
    u = jax.random.randint(key, (n,), 0, DRAW_U_CARD)
    return (u.astype(jnp.float32) + 0.5) * jnp.float32(1.0 / DRAW_U_CARD)


def chunk_cdf(chunks: jnp.ndarray, carry: jnp.ndarray) -> jnp.ndarray:
    """The canonical fold step over (..., K, DRAW_BLK) chunk-major
    probabilities with an incoming (..., 1) carry: per-chunk cumsum plus
    the left-fold chain of chunk totals. Returns the (..., K, DRAW_BLK)
    CDF; the outgoing carry is its last element. The fused kernel calls
    this per scan block (carry in scratch); ``blockwise_cdf`` calls it
    once over the whole vector (carry 0) — identical folds, so the
    per-lane CDF bits agree no matter how the lanes are blocked.
    """
    cc = jnp.cumsum(chunks, axis=-1)
    totals = cc[..., -1]                               # (..., K)
    ext = jnp.concatenate([carry, totals[..., :-1]], axis=-1)
    off = jnp.cumsum(ext, axis=-1)                     # left fold of totals
    return cc + off[..., None]


def blockwise_cdf(probs: jnp.ndarray) -> jnp.ndarray:
    """The canonical chunked CDF of a (cap,) probability vector.
    Zero-pads to a DRAW_BLK multiple (flat CDF over pad lanes — exactly
    how the fused kernel's padded scan lanes behave)."""
    cap = probs.shape[0]
    pad = (-cap) % DRAW_BLK
    p = jnp.pad(probs.astype(jnp.float32), (0, pad))
    cdf = chunk_cdf(p.reshape(-1, DRAW_BLK), jnp.zeros((1,), jnp.float32))
    return cdf.reshape(-1)[:cap]


def categorical_from_targets(probs: jnp.ndarray, t: jnp.ndarray
                             ) -> jnp.ndarray:
    """Inverse-CDF categorical draws over a (cap,) probability vector
    for (n,) targets: count of lanes with cdf ≤ t, clipped to cap-1."""
    cap = probs.shape[0]
    cdf = blockwise_cdf(probs)
    cnt = jnp.sum((cdf[None, :] <= t[:, None]).astype(jnp.int32), axis=-1)
    return jnp.clip(cnt, 0, cap - 1).astype(jnp.int32)
