"""Scene-score Pallas kernel — Eq. 1 fused per-frame pipeline.

φ(fᵢ) = ‖w ⊙ (vᵢ − vᵢ₋₁)‖₁ / (‖w‖₁ · H·W),  v = [hue, sat, light, edge]

This runs on *every captured frame* (25–60 FPS × pixels), making it the
ingestion hot spot. TPU-native design: a **sequential grid over frames**
with the previous frame's feature maps carried in VMEM scratch — each
frame is read from HBM exactly once, features are computed and diffed
against the carried maps in a single fused VPU pass, and only the scalar
φ goes back to HBM. (The GPU/OpenCV original recomputes features per
frame on the CPU; see DESIGN.md §3.)

VMEM budget: 2 × H·W·4 f32 maps ≈ 1.6 MB at 224², 12.8 MB at 448². Larger
frames would take a row-blocked variant; ingestion-side Venus frames are
embedding-model resolution (≤448²).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _features(rgb: jnp.ndarray) -> jnp.ndarray:
    """(H,W,3) f32 in [0,1] -> (H,W,4) hue/sat/light/edge."""
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    c = mx - mn
    light = 0.5 * (mx + mn)
    sat = c / (1.0 - jnp.abs(2.0 * light - 1.0) + 1e-6)
    safe_c = jnp.where(c > 0, c, 1.0)
    hue = jnp.where(
        mx == r, jnp.mod((g - b) / safe_c, 6.0),
        jnp.where(mx == g, (b - r) / safe_c + 2.0,
                  (r - g) / safe_c + 4.0)) / 6.0
    hue = jnp.where(c > 0, hue, 0.0)
    dx = jnp.abs(jnp.diff(light, axis=1, prepend=light[:, :1]))
    dy = jnp.abs(jnp.diff(light, axis=0, prepend=light[:1, :]))
    return jnp.stack([hue, sat, light, dx + dy], axis=-1)


def _scene_kernel(f_ref, phi_ref, prev_ref, *, weights, hw):
    t = pl.program_id(0)
    rgb = f_ref[0].astype(jnp.float32)            # (H, W, 3)
    feat = _features(rgb)                          # (H, W, 4)
    wh, ws, wl, we = (float(x) for x in weights)  # static scalars

    @pl.when(t == 0)
    def _seed():                 # first frame diffs against itself -> φ=0
        prev_ref[...] = feat

    diff = jnp.abs(feat - prev_ref[...])
    num = (wh * jnp.sum(diff[..., 0]) + ws * jnp.sum(diff[..., 1])
           + wl * jnp.sum(diff[..., 2]) + we * jnp.sum(diff[..., 3]))
    phi_ref[0, 0] = num / ((wh + ws + wl + we) * hw)
    prev_ref[...] = feat


@functools.partial(jax.jit, static_argnames=("weights", "interpret"))
def scene_score(frames: jnp.ndarray,
                weights: Tuple[float, float, float, float],
                *, interpret: bool = True) -> jnp.ndarray:
    """frames: (T,H,W,3) float in [0,1] -> φ (T,) f32; φ[0] = 0."""
    t, h, w, _ = frames.shape
    kernel = functools.partial(_scene_kernel, weights=tuple(weights),
                               hw=float(h * w))
    phi = pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, h, w, 3), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((h, w, 4), jnp.float32)],
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(frames)
    return phi[:, 0]
