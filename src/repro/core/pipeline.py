"""The Venus system: online ingestion + querying (paper Fig. 6).

Ingestion (steps ①–④): stream chunks → scene segmentation → incremental
clustering per partition → centroid index frames → aux prompts → MEM
embedding → hierarchical memory insert. Querying (steps ⑤–⑦): embed the
query, similarity over the index (Eq. 4), temperature-softmax sampling or
AKR (Eq. 5–7), expand draws into raw frames from the cluster reservoirs,
hand the frame set to the (cloud) VLM.

The embedder is pluggable:
* ``MEMEmbedder`` — the real dual-tower MEM (frontend-stub patchifier).
* ``OracleEmbedder`` (repro.data.video) — a perfect MEM for isolating
  retrieval-algorithm quality in benchmarks.

Every stage records wall-clock time into a ``LatencyBreakdown`` so the
benchmarks reproduce the paper's Fig. 12 decomposition.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import retrieval as rt
from repro.core.aux_models import AuxModel, build_aux_prompt
from repro.core.clustering import cluster_partition, frame_vectors
from repro.core.memory import FrameStore, VenusMemory
from repro.core.scene import StreamSegmenter
from repro.data.text import tokenize_batch


# ---------------------------------------------------------------------------
# Embedders
# ---------------------------------------------------------------------------


def patchify(frames: np.ndarray, patch: int, d_vision: int,
             seed: int = 11) -> jnp.ndarray:
    """Frontend stub: frames (B,H,W,3) -> patch embeddings (B,P,d_vision)
    via fixed seeded random projection of raw patches."""
    b, h, w, c = frames.shape
    ph, pw = h // patch, w // patch
    x = frames[:, : ph * patch, : pw * patch].reshape(
        b, ph, patch, pw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, ph * pw, patch * patch * c)
    rng = np.random.default_rng(seed)
    proj = rng.normal(0, 1.0 / np.sqrt(x.shape[-1]),
                      (x.shape[-1], d_vision)).astype(np.float32)
    return jnp.asarray(x @ proj)


class MEMEmbedder:
    """Adapter: Venus pipeline ↔ the dual-tower MEM model."""

    def __init__(self, mem, params, *, patch: int = 8,
                 text_max_len: int = 32):
        self.mem = mem
        self.params = params
        self.patch = patch
        self.text_max_len = text_max_len
        self._img_fn = jax.jit(mem.encode_image)
        self._txt_fn = jax.jit(mem.encode_text)

    def embed_frames(self, frames: np.ndarray,
                     aux_texts: Optional[Sequence[str]] = None,
                     frame_ids=None) -> np.ndarray:
        patches = patchify(np.asarray(frames), self.patch,
                           self.mem.cfg.vision.d_model)
        img = self._img_fn(self.params, patches)
        if aux_texts and any(aux_texts):
            toks, mask = tokenize_batch(list(aux_texts),
                                        self.mem.cfg.text.vocab_size,
                                        self.text_max_len)
            txt = self._txt_fn(self.params, jnp.asarray(toks),
                               jnp.asarray(mask))
            img = (img + 0.3 * txt) / np.linalg.norm(
                np.asarray(img + 0.3 * txt), axis=-1, keepdims=True)
        return np.asarray(img, np.float32)

    def embed_query(self, text: str) -> np.ndarray:
        toks, mask = tokenize_batch([text], self.mem.cfg.text.vocab_size,
                                    self.text_max_len)
        return np.asarray(self._txt_fn(self.params, jnp.asarray(toks),
                                       jnp.asarray(mask))[0], np.float32)


# ---------------------------------------------------------------------------
# Venus system
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class VenusConfig:
    # ingestion
    scene_threshold: float = 0.075
    max_partition_len: int = 256
    cluster_threshold: float = 0.35
    max_clusters_per_partition: int = 16
    cluster_pool: int = 8
    # memory
    memory_capacity: int = 8192
    member_cap: int = 128
    # querying (Eq. 5-7)
    tau: float = 0.1
    theta: float = 0.9
    beta: float = 1.0
    n_max: int = 32
    seed: int = 0


@dataclass
class QueryResult:
    frame_ids: np.ndarray          # selected raw-frame ids (deduped)
    draws: np.ndarray              # index draws
    n_drawn: int
    mass: float
    timings: Dict[str, float]


class VenusSystem:
    def __init__(self, cfg: VenusConfig, embedder, embed_dim: int,
                 aux_models: Sequence[AuxModel] = (),
                 annotation_fn=None):
        self.cfg = cfg
        self.embedder = embedder
        self.aux_models = list(aux_models)
        self.annotation_fn = annotation_fn
        self.segmenter = StreamSegmenter(
            threshold=cfg.scene_threshold,
            max_partition_len=cfg.max_partition_len)
        self.memory = VenusMemory(cfg.memory_capacity, embed_dim,
                                  cfg.member_cap, seed=cfg.seed)
        self.frames = FrameStore()
        self._pending: List[np.ndarray] = []   # frames not yet clustered
        self._pending_base = 0                 # abs index of pending[0]
        self._key = jax.random.key(cfg.seed)
        self.stats = {"frames_seen": 0, "frames_embedded": 0,
                      "partitions": 0, "clusters": 0}

    # ------------------------------------------------------------ ingestion
    def ingest(self, chunk: np.ndarray) -> Dict[str, float]:
        """Consume a chunk of streaming frames (T,H,W,3). Returns stage
        timings for this chunk."""
        t0 = time.perf_counter()
        chunk = np.asarray(chunk, np.float32)
        self.frames.append(chunk)
        self.stats["frames_seen"] += len(chunk)
        closed = self.segmenter.ingest(jnp.asarray(chunk))
        t_seg = time.perf_counter()

        self._pending.extend(chunk)
        t_clu = t_emb = 0.0
        for part in closed:
            tc0 = time.perf_counter()
            lo = part.start - self._pending_base
            hi = part.end - self._pending_base
            pf = np.stack(self._pending[lo:hi])
            self._ingest_partition(pf, part.start)
            t_clu += time.perf_counter() - tc0
        if closed:
            consumed = closed[-1].end - self._pending_base
            self._pending = self._pending[consumed:]
            self._pending_base = closed[-1].end
        return {"segment": t_seg - t0, "cluster_embed": t_clu}

    def flush(self) -> None:
        for part in self.segmenter.flush():
            lo = part.start - self._pending_base
            pf = np.stack(self._pending[lo:])
            self._ingest_partition(pf, part.start)
        self._pending = []
        self._pending_base = self.stats["frames_seen"]

    def _ingest_partition(self, pframes: np.ndarray, abs_start: int) -> None:
        cfg = self.cfg
        vecs = frame_vectors(jnp.asarray(pframes), cfg.cluster_pool)
        res = cluster_partition(vecs, threshold=cfg.cluster_threshold,
                                max_clusters=cfg.max_clusters_per_partition)
        n = int(res.n_clusters)
        assign = np.asarray(res.assignments)
        idxf = np.asarray(res.index_frames)
        scene_id = self.stats["partitions"]

        # embed all index frames of this partition in one batch
        index_local = idxf[:n]
        batch = pframes[index_local]
        aux_texts = None
        if self.aux_models and self.annotation_fn is not None:
            aux_texts = [build_aux_prompt(
                self.aux_models, batch[j],
                self.annotation_fn(abs_start + int(index_local[j])))
                for j in range(n)]
        embs = self.embedder.embed_frames(
            batch, aux_texts, frame_ids=abs_start + index_local)
        self.stats["frames_embedded"] += n

        for c in range(n):
            members = abs_start + np.nonzero(assign == c)[0]
            self.memory.insert_cluster(
                embs[c], scene_id=scene_id,
                index_frame=abs_start + int(index_local[c]),
                member_frames=members)
        self.stats["partitions"] += 1
        self.stats["clusters"] += n

    # -------------------------------------------------------------- querying
    def query(self, text: str, *, budget: Optional[int] = None,
              use_akr: bool = True, query_emb: Optional[np.ndarray] = None
              ) -> QueryResult:
        """budget set ⇒ fixed-N sampling (paper §IV-D1); otherwise AKR."""
        cfg = self.cfg
        timings: Dict[str, float] = {}
        t0 = time.perf_counter()
        if query_emb is None:
            query_emb = self.embedder.embed_query(text)
        timings["embed_query"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sims, probs = self.memory.search(jnp.asarray(query_emb)[None],
                                         tau=cfg.tau)
        probs0 = probs[0]
        timings["similarity"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        self._key, sub = jax.random.split(self._key)
        if budget is not None and not use_akr:
            draws, _ = rt.sampling_retrieve(probs0, sub, budget)
            valid = np.ones((budget,), bool)
            n_drawn, mass = budget, float("nan")
        else:
            n_max = budget if budget is not None else cfg.n_max
            res = rt.akr_progressive(probs0, sub, theta=cfg.theta,
                                     beta=cfg.beta, n_max=n_max)
            draws, valid = np.asarray(res.draws), np.asarray(res.valid)
            n_drawn, mass = int(res.n_drawn), float(res.mass)
        timings["sampling"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        frame_ids = self.memory.expand_draws(np.asarray(draws), valid,
                                             seed=cfg.seed)
        timings["expand"] = time.perf_counter() - t0
        return QueryResult(frame_ids=frame_ids, draws=np.asarray(draws),
                           n_drawn=n_drawn, mass=mass, timings=timings)

    # baselines share the same memory/index ---------------------------------
    def query_topk(self, text: str, k: int,
                   query_emb: Optional[np.ndarray] = None) -> np.ndarray:
        if query_emb is None:
            query_emb = self.embedder.embed_query(text)
        sims, _ = self.memory.search(jnp.asarray(query_emb)[None],
                                     tau=self.cfg.tau)
        valid = jnp.arange(self.memory.capacity) < self.memory.size
        idx = rt.topk_retrieve(sims[0], valid, k)
        return self.memory.index_frames(np.asarray(idx))
