"""The Venus system: online ingestion + querying (paper Fig. 6).

Ingestion (steps ①–④): stream chunks → scene segmentation → incremental
clustering per partition → centroid index frames → aux prompts → MEM
embedding → hierarchical memory insert. Querying (steps ⑤–⑦): embed the
query, similarity over the index (Eq. 4), temperature-softmax sampling or
AKR (Eq. 5–7), expand draws into raw frames from the cluster reservoirs,
hand the frame set to the (cloud) VLM.

The stage logic lives in ``repro.core.session`` as composable per-stream
stages driven by a ``SessionManager`` (multi-stream, batch-first).
``VenusSystem`` is the single-stream façade over one managed session —
the public API the examples/benchmarks were written against — and also
exposes the batched ``query_batch``.

The embedder is pluggable:
* ``MEMEmbedder`` — the real dual-tower MEM (frontend-stub patchifier).
* ``OracleEmbedder`` (repro.data.video) — a perfect MEM for isolating
  retrieval-algorithm quality in benchmarks.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aux_models import AuxModel
from repro.core.queryplan import QueryPlan, QuerySpec
from repro.core.session import (QueryResult, SessionManager, SessionState,
                                VenusConfig)
from repro.data.text import tokenize_batch
from repro.util import pow2_bucket

__all__ = ["patchify", "MEMEmbedder", "VenusConfig", "QueryResult",
           "QuerySpec", "QueryPlan", "VenusSystem", "SessionManager",
           "SessionState"]


# ---------------------------------------------------------------------------
# Embedders
# ---------------------------------------------------------------------------


def patchify(frames: np.ndarray, patch: int, d_vision: int,
             seed: int = 11) -> jnp.ndarray:
    """Frontend stub: frames (B,H,W,3) -> patch embeddings (B,P,d_vision)
    via fixed seeded random projection of raw patches."""
    b, h, w, c = frames.shape
    ph, pw = h // patch, w // patch
    x = frames[:, : ph * patch, : pw * patch].reshape(
        b, ph, patch, pw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, ph * pw, patch * patch * c)
    rng = np.random.default_rng(seed)
    proj = rng.normal(0, 1.0 / np.sqrt(x.shape[-1]),
                      (x.shape[-1], d_vision)).astype(np.float32)
    return jnp.asarray(x @ proj)


class MEMEmbedder:
    """Adapter: Venus pipeline ↔ the dual-tower MEM model."""

    def __init__(self, mem, params, *, patch: int = 8,
                 text_max_len: int = 32):
        self.mem = mem
        self.params = params
        self.patch = patch
        self.text_max_len = text_max_len
        self._img_fn = jax.jit(mem.encode_image)
        self._txt_fn = jax.jit(mem.encode_text)

    def embed_frames(self, frames: np.ndarray,
                     aux_texts: Optional[Sequence[str]] = None,
                     frame_ids=None) -> np.ndarray:
        frames = np.asarray(frames)
        n = frames.shape[0]
        # pad the batch to a power-of-two bucket: multi-stream ticks close
        # arbitrary numbers of clusters, and unbucketed shapes would jit-
        # specialise the vision tower per batch size
        bucket = pow2_bucket(n, lo=4)
        if bucket != n:
            frames = np.concatenate(
                [frames, np.zeros((bucket - n,) + frames.shape[1:],
                                  frames.dtype)])
        patches = patchify(frames, self.patch,
                           self.mem.cfg.vision.d_model)
        img = self._img_fn(self.params, patches)[:n]
        if aux_texts and any(aux_texts):
            toks, mask = tokenize_batch(list(aux_texts),
                                        self.mem.cfg.text.vocab_size,
                                        self.text_max_len)
            txt = self._txt_fn(self.params, jnp.asarray(toks),
                               jnp.asarray(mask))
            img = (img + 0.3 * txt) / np.linalg.norm(
                np.asarray(img + 0.3 * txt), axis=-1, keepdims=True)
        return np.asarray(img, np.float32)

    def embed_queries(self, texts: Sequence[str]) -> np.ndarray:
        """Batch-encode Q query texts in one text-tower call."""
        toks, mask = tokenize_batch(list(texts),
                                    self.mem.cfg.text.vocab_size,
                                    self.text_max_len)
        return np.asarray(self._txt_fn(self.params, jnp.asarray(toks),
                                       jnp.asarray(mask)), np.float32)

    def embed_query(self, text: str) -> np.ndarray:
        return self.embed_queries([text])[0]


# ---------------------------------------------------------------------------
# Venus system — single-stream façade over one managed session
# ---------------------------------------------------------------------------


class VenusSystem:
    def __init__(self, cfg: VenusConfig, embedder, embed_dim: int,
                 aux_models: Sequence[AuxModel] = (),
                 annotation_fn=None):
        self.cfg = cfg
        self.embedder = embedder
        self.manager = SessionManager(cfg, embedder, embed_dim,
                                      aux_models=aux_models,
                                      annotation_fn=annotation_fn)
        self.sid = self.manager.create_session()

    # ----------------------------------------------------- state delegation
    @property
    def _session(self) -> SessionState:
        return self.manager[self.sid]

    @property
    def memory(self):
        return self._session.memory

    @property
    def frames(self):
        return self._session.frames

    @property
    def stats(self) -> Dict[str, int]:
        return self._session.stats

    @property
    def segmenter(self):
        return self._session.segmenter

    # ------------------------------------------------------------ ingestion
    def ingest(self, chunk: np.ndarray) -> Dict[str, float]:
        """Consume a chunk of streaming frames (T,H,W,3). Returns stage
        timings for this chunk."""
        t = self.manager.ingest_tick({self.sid: chunk})
        return {"segment": t["segment"],
                "cluster_embed": t["cluster"] + t["embed_insert"]}

    def flush(self) -> None:
        self.manager.flush([self.sid])

    # -------------------------------------------------------------- querying
    def plan(self, specs: Sequence[QuerySpec]) -> QueryPlan:
        """Declarative path: group specs into execution groups. Specs
        are pinned to this system's single session."""
        return self.manager.plan(
            [replace(s, sid=self.sid) for s in specs])

    def execute(self, plan: QueryPlan) -> List[QueryResult]:
        return self.manager.execute(plan)

    def query_specs(self, specs: Sequence[QuerySpec]) -> List[QueryResult]:
        """``execute(plan(specs))`` — any registered retrieval strategy
        through the fused one-scan-per-group path."""
        return self.execute(self.plan(specs))

    def query(self, text: str, *, budget: Optional[int] = None,
              use_akr: bool = True, query_emb: Optional[np.ndarray] = None
              ) -> QueryResult:
        """budget set ⇒ fixed-N sampling (paper §IV-D1); otherwise AKR."""
        return self.manager.query(self.sid, text, budget=budget,
                                  use_akr=use_akr, query_emb=query_emb)

    def query_batch(self, texts: Optional[Sequence[str]] = None, *,
                    query_embs: Optional[np.ndarray] = None,
                    budget: Optional[int] = None, use_akr: bool = True
                    ) -> List[QueryResult]:
        """Q queries through one similarity scan + vmapped sampling."""
        return self.manager.query_batch(self.sid, texts,
                                        query_embs=query_embs,
                                        budget=budget, use_akr=use_akr)

    # baselines share the same memory/index ---------------------------------
    def query_topk(self, text: str, k: int,
                   query_emb: Optional[np.ndarray] = None) -> np.ndarray:
        return self.manager.query_topk(self.sid, text, k,
                                       query_emb=query_emb)
