"""Hierarchical two-level memory: the coarse summary tier + the
two-stage (coarse → fine) retrieval executor (paper §IV-C; Video-XL's
visual-context-compression argument — capacity outruns scan bandwidth
by scanning summaries and gathering only winning detail).

Storage layout (owned by ``MemoryArena`` — see ``coarse_rows_for``):
each slot's coarse tier is ``n_coarse = n_blocks + coarse_capacity``
summary rows inside ``(S, n_coarse, ·)`` super-buffers:

* rows ``[0, n_blocks)`` — **block summaries**: one centroid per
  ``coarse_block`` physical fine rows, recomputed for dirty blocks at
  tick flush from the host mirrors. They carry no reservoir: a stage-1
  win on block ``b`` gathers block ``b``'s actual fine rows, which
  carry their own members/index_frame metadata.
* rows ``[n_blocks, n_coarse)`` — **consolidated summaries**: evicted
  fine rows folded by ``ConsolidationEviction`` into running
  count-weighted centroids with merged member reservoirs and
  ``[fid_lo, fid_hi]`` frame windows. These rows ARE their own stage-2
  candidates (one row each), expanded through the merged reservoir.

Two-stage retrieval contract (``two_stage_retrieve``):

1. **Stage 1 — coarse scan.** The existing fused stack scan runs over
   the ``(S, n_coarse, d)`` coarse tier (``tier="coarse"`` so the bytes
   count into ``kops.coarse_scan_bytes``), selecting the per-query
   top-B summary winners on device. Sharded arenas fan this launch out
   per slot slab exactly like the fine scan.
2. **Stage 2 — winner-block gather + fine scan.** A jit'd gather builds
   each (session, query)'s candidate table: ``coarse_block`` fine arena
   rows per block-summary winner, the summary row itself per
   consolidated winner (padded to the block width, masked). The same
   fused scan then runs over the ``(S·Q, B·block, d)`` candidate
   operand with the group's ORIGINAL inverse-CDF targets, so draws /
   top-k / AKR stop-rule state resolve over candidates only. Gathered
   candidate rows count into ``kops.fine_gather_rows``.

Per query the streamed rows are ``n_coarse + B·coarse_block`` — sized
far below ``capacity`` — while consolidation keeps ≫ capacity of
ingested history reachable: effective capacity ≫ scanned bytes.

Equivalence: the executor only enters this path when the tier holds at
least one consolidated row (``MemoryArena.has_consolidated``); before
the first consolidation — and always under the ``coarse=False`` escape
hatch — queries take the flat scan UNCHANGED, so flat-path results are
bit-identical to a coarse-less build. The PRNG contract is also
preserved: session chains advance identically in both modes (the same
keys produce the same targets; only the operand they resolve over
differs).

The stage-2 candidate scan runs unsharded even on a sharded arena: the
winner gather crosses slab boundaries anyway and the candidate operand
is epilogue-sized (O(S·Q·B·block·d)), not capacity-sized — it is the
two-stage analogue of the sharded fused scan's candidate gather.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import retrieval as rt
from repro.core.memory import MemoryArena, expand_gather
from repro.kernels import ops as kops


class TwoStageResult(NamedTuple):
    """What the plan executor consumes after a coarse→fine retrieval.
    ``fr`` is candidate-LOCAL (draw/top-k indices address the gathered
    candidate tables, not arena rows); the ``cand_*`` tables map those
    back to member reservoirs and frame ids."""
    fr: kops.FusedRetrieval      # (S, Q, ·) candidate-local outputs
    cand_members: jnp.ndarray    # (S, Q, C, K) per-candidate reservoirs
    cand_counts: jnp.ndarray     # (S, Q, C) reservoir counts
    cand_ifr: jnp.ndarray        # (S, Q, C) candidate frame ids
    cand_valid: jnp.ndarray      # (S, Q, C) candidate validity
    winners: jnp.ndarray         # (S, Q, B) stage-1 coarse row winners


@functools.partial(jax.jit, static_argnames=("block", "n_blocks"))
def _gather_candidates(winners, f_emb, f_mem, f_cnt, f_ifr, f_valid,
                       c_emb, c_mem, c_cnt, c_ifr, c_valid, *,
                       block: int, n_blocks: int):
    """Winner-block gather, vmapped over sessions. Per session:
    winners (Q, B) coarse rows; ``f_*`` (cap, ·) fine tables;
    ``c_*`` (n_coarse, ·) coarse tables. Block winners (< n_blocks)
    contribute their block's ``block`` fine rows; consolidated winners
    contribute themselves in candidate slot 0, the rest masked.
    Returns (emb, members, counts, ifr, valid) with a (Q, B·block)
    candidate axis."""

    def per_session(w, fe, fm, fc, ff, fv, ce, cm, cc, cf, cv):
        cap = fe.shape[0]
        is_blk = w < n_blocks                            # (Q, B)
        offs = jnp.arange(block)
        first = offs == 0
        rows = jnp.clip(w[..., None] * block + offs, 0, cap - 1)
        k0_emb = fe[rows].astype(jnp.float32)            # (Q,B,blk,d)
        k0_valid = fv[rows] & is_blk[..., None]
        cw = jnp.clip(w, 0, ce.shape[0] - 1)
        k1_valid = cv[cw] & ~is_blk                      # (Q, B)
        k1_emb = (ce[cw][:, :, None, :]
                  * first[None, None, :, None])          # (Q,B,blk,d)
        emb = jnp.where(is_blk[..., None, None], k0_emb, k1_emb)
        valid = jnp.where(is_blk[..., None], k0_valid,
                          k1_valid[..., None] & first)
        mem = jnp.where(is_blk[..., None, None], fm[rows],
                        cm[cw][:, :, None, :])
        cnt = jnp.where(is_blk[..., None], fc[rows],
                        cc[cw][..., None] * first)
        ifr = jnp.where(is_blk[..., None], ff[rows],
                        cf[cw][..., None] * first)
        q, b = w.shape
        c = b * block
        return (emb.reshape(q, c, -1), mem.reshape(q, c, -1),
                cnt.reshape(q, c), ifr.reshape(q, c),
                valid.reshape(q, c))

    return jax.vmap(per_session)(winners, f_emb, f_mem, f_cnt, f_ifr,
                                 f_valid, c_emb, c_mem, c_cnt, c_ifr,
                                 c_valid)


def two_stage_retrieve(arena: MemoryArena, q_stack: jnp.ndarray,
                       targets: jnp.ndarray, *, tau: float, n_topk: int,
                       topb: int) -> TwoStageResult:
    """Run one group's coarse→fine retrieval over the arena tiers.
    ``q_stack`` (S, Q, d), ``targets`` (S, Q, T) — the group's ORIGINAL
    inverse-CDF targets (PRNG chains advance identically to the flat
    path). ``topb`` is B, the stage-1 winner budget per query."""
    assert arena.n_coarse, "arena has no coarse tier"
    s, q, d = q_stack.shape
    topb = max(1, min(int(topb), arena.n_coarse))
    # ---- stage 1: fused scan over the coarse summary tier --------------
    fr1 = kops.fused_retrieve_stack(
        q_stack, arena.coarse_emb, tau=tau,
        valid=arena.device_coarse_valid(),
        targets=jnp.zeros((s, q, 1), jnp.float32), n_topk=topb,
        mesh=arena.mesh, mesh_axis=arena.mesh_axis, tier="coarse")
    winners = fr1.topk_i                                  # (S, Q, B)
    # ---- stage 2: gather winner blocks, rescan candidates --------------
    cand_emb, cand_mem, cand_cnt, cand_ifr, cand_valid = \
        _gather_candidates(
            winners, arena.emb, arena.members, arena.member_count,
            arena.index_frame, arena.device_valid(),
            arena.coarse_emb, arena.coarse_members,
            arena.coarse_member_count, arena.coarse_index_frame,
            arena.device_coarse_valid(),
            block=arena.coarse_block, n_blocks=arena.n_blocks)
    c = topb * arena.coarse_block
    kops.count_fine_gather(s * q * c)
    n_topk = max(1, min(int(n_topk), c))
    fr2 = kops.fused_retrieve_stack(
        q_stack.reshape(s * q, 1, d), cand_emb.reshape(s * q, c, d),
        tau=tau, valid=cand_valid.reshape(s * q, c),
        targets=targets.reshape(s * q, 1, -1), n_topk=n_topk)
    fr = kops.FusedRetrieval(
        draws=fr2.draws.reshape(s, q, -1),
        drawn_p=fr2.drawn_p.reshape(s, q, -1),
        topk_v=fr2.topk_v.reshape(s, q, -1),
        topk_i=fr2.topk_i.reshape(s, q, -1),
        m=fr2.m.reshape(s, q, 1), l=fr2.l.reshape(s, q, 1),
        p_max=fr2.p_max.reshape(s, q, 1))
    return TwoStageResult(fr, cand_mem, cand_cnt, cand_ifr, cand_valid,
                          winners)


# --- candidate-local post-processing (the per-(s,q)-table twins of the
# --- executor's stacked expansion jits) ------------------------------------


@jax.jit
def gather_candidate_ifr(cand_ifr: jnp.ndarray, draws: jnp.ndarray
                         ) -> jnp.ndarray:
    """cand_ifr (S, Q, C) × candidate-local draws (S, Q, n) → frame ids
    (S, Q, n): the two-stage twin of ``_gather_index_frames``, except
    each (s, q) lane gathers from its own candidate table."""
    c = cand_ifr.shape[-1]
    return jnp.take_along_axis(cand_ifr, jnp.clip(draws, 0, c - 1),
                               axis=-1)


@jax.jit
def expand_candidates(cand_mem, cand_cnt, draws, valid, u):
    """Reservoir expansion over per-(s,q) candidate tables: the
    two-stage twin of the executor's ``_expand_stack`` (same
    ``expand_gather`` core, same u variates — one vmap deeper)."""
    fids, ok = jax.vmap(jax.vmap(
        lambda m, c, d, v: expand_gather(m, c, d, v, u)))(
            cand_mem, cand_cnt, draws, valid)
    return fids, ok


@functools.partial(jax.jit, static_argnames=("theta", "beta", "n_max"))
def akr_post_candidates(draws, drawn_p, p_max, cand_mem, cand_cnt, u, *,
                        theta, beta, n_max):
    """AKR stop rule + reservoir expansion over candidate-local draw
    state: per-lane it is exactly ``akr_from_draws`` (the fused flat
    path's epilogue) applied to the stage-2 scan's outputs."""
    akr = jax.vmap(jax.vmap(lambda dd, p, pm: rt.akr_from_draws(
        dd, p, pm, theta=theta, beta=beta, n_max=n_max)))(
            draws, drawn_p, p_max)
    fids, ok = jax.vmap(jax.vmap(
        lambda m, c, d, v: expand_gather(m, c, d, v, u)))(
            cand_mem, cand_cnt, akr.draws, akr.valid)
    return akr, fids, ok
