"""Hierarchical memory (paper §IV-C): index layer over a raw data layer.

* **Raw data layer** — every captured frame, archived as-is. Here it is a
  ``FrameStore`` holding frames by absolute index (the paper's NVMe
  archive); reasoning-time expansion pulls raw frames from it.
* **Index data layer** — one vector per *indexed frame* (cluster
  centroid), stored in a fixed-capacity packed array that is directly
  shardable over the ``model`` mesh axis (DESIGN.md: brute-force MXU
  similarity replaces FAISS ANN on TPU). Each indexed vector is linked to
  its scene cluster via a bounded **member reservoir** — up to
  ``member_cap`` member frame ids kept uniformly at random, so
  "uniformly sample n(oᵢ) frames from cluster c(oᵢ)" (§IV-D1) stays a
  fixed-shape gather.

Inserts are cheap O(K·d) host-side appends (as in FAISS); the query-path
similarity scan is the jit/Pallas hot path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class FrameStore:
    """Raw data layer: append-only archive of frames by absolute index."""

    def __init__(self):
        self._frames: List[np.ndarray] = []

    def append(self, frames: np.ndarray) -> None:
        for f in np.asarray(frames):
            self._frames.append(f)

    def __len__(self) -> int:
        return len(self._frames)

    def get(self, idx: Sequence[int]) -> np.ndarray:
        return np.stack([self._frames[int(i)] for i in idx])


@dataclass
class IndexEntry:
    scene_id: int
    cluster_id: int
    ts: int                      # timestamp (frame index) of indexed frame


class VenusMemory:
    """Index layer: packed vector store + cluster member reservoirs."""

    def __init__(self, capacity: int, dim: int, member_cap: int = 128,
                 seed: int = 0):
        self.capacity = capacity
        self.dim = dim
        self.member_cap = member_cap
        self._emb = np.zeros((capacity, dim), np.float32)
        self._members = np.zeros((capacity, member_cap), np.int32)
        self._member_count = np.zeros((capacity,), np.int32)
        self._index_frame = np.zeros((capacity,), np.int32)
        self._scene_id = np.zeros((capacity,), np.int32)
        self._size = 0
        self._rng = np.random.default_rng(seed)
        self._device_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None

    # ------------------------------------------------------------- ingestion
    def insert_cluster(self, embedding: np.ndarray, *, scene_id: int,
                       index_frame: int, member_frames: Sequence[int]
                       ) -> int:
        """Insert one indexed vector linked to its cluster members."""
        if self._size >= self.capacity:
            raise RuntimeError("memory capacity exhausted")
        i = self._size
        self._emb[i] = np.asarray(embedding, np.float32)
        members = np.asarray(member_frames, np.int32)
        m = len(members)
        if m > self.member_cap:            # uniform reservoir
            keep = self._rng.choice(m, self.member_cap, replace=False)
            members = members[np.sort(keep)]
            m = self.member_cap
        self._members[i, :m] = members
        self._member_count[i] = m
        self._index_frame[i] = index_frame
        self._scene_id[i] = scene_id
        self._size += 1
        self._device_cache = None
        return i

    # ----------------------------------------------------------------- query
    @property
    def size(self) -> int:
        return self._size

    def device_index(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(embeddings (cap, d), valid (cap,)) as device arrays (cached)."""
        if self._device_cache is None:
            valid = np.arange(self.capacity) < self._size
            self._device_cache = (jnp.asarray(self._emb),
                                  jnp.asarray(valid))
        return self._device_cache

    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (Q,d) -> (sims (Q,cap), probs (Q,cap)) — Eq. 4+5."""
        emb, valid = self.device_index()
        return kops.similarity(query_emb, emb, tau=tau, valid=valid)

    # ------------------------------------------------- cluster-level expand
    def members_table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self._members), jnp.asarray(self._member_count)

    def expand_draws(self, draws: np.ndarray, valid: np.ndarray,
                     seed: int = 0) -> np.ndarray:
        """Map index draws to frame ids: each draw of index i samples one
        member uniformly from cluster c(oᵢ) (paper §IV-D1). Returns the
        deduplicated, time-ordered frame ids."""
        rng = np.random.default_rng(seed)
        out = []
        for i, ok in zip(np.asarray(draws), np.asarray(valid)):
            if not ok:
                continue
            cnt = int(self._member_count[i])
            if cnt == 0:
                continue
            out.append(int(self._members[i, rng.integers(cnt)]))
        return np.unique(np.asarray(out, np.int64))

    def index_frames(self, idx: Sequence[int]) -> np.ndarray:
        return self._index_frame[np.asarray(idx, np.int64)]
