"""Hierarchical memory (paper §IV-C): index layer over a raw data layer.

* **Raw data layer** — every captured frame, archived as-is. Here it is a
  ``FrameStore`` holding frames by absolute index (the paper's NVMe
  archive); reasoning-time expansion pulls raw frames from it.
* **Index data layer** — one vector per *indexed frame* (cluster
  centroid), stored in a fixed-capacity packed array that is directly
  shardable over the ``model`` mesh axis (DESIGN.md: brute-force MXU
  similarity replaces FAISS ANN on TPU). Each indexed vector is linked to
  its scene cluster via a bounded **member reservoir** — up to
  ``member_cap`` member frame ids kept uniformly at random, so
  "uniformly sample n(oᵢ) frames from cluster c(oᵢ)" (§IV-D1) stays a
  fixed-shape gather.

The index is **device-resident and incrementally updated**: the first
query uploads the packed array once; afterwards batched inserts append
rows in place with a jit'd ``dynamic_update_slice`` (bucketed batch
sizes bound the jit cache), so a post-ingest query never re-transfers
the whole ``(capacity, dim)`` buffer. The member reservoirs get the
same treatment (``device_members``), so reasoning-time expansion is a
jit'd on-device gather (``expand_draws_device``) instead of a host
lookup. ``io_stats`` counts full uploads vs appended rows (and host vs
device expansion gathers) so tests/benches can assert the transfer
behaviour.

``MemoryArena`` is the grow-in-place form of the cross-session view:
one set of device-resident super-buffers ``(S, capacity, d)`` /
``(S, capacity, K)`` owned by the session manager, inside which every
session's index, member reservoirs, and index_frame rows live from the
start. Per-tick batched appends are donated ``dynamic_update_slice``
writes at ``(slot, pos)``, so the arena buffers ARE the fused-scan
operand — queries between (or after) ingest ticks never restack
anything. Only the per-session valid masks depend on the sizes, and
those are derived on device from the tiny ``(S,)`` sizes vector.

``MemoryStack`` remains the padded-stack view over S ``VenusMemory``
instances for the cross-session fused query path. When its members all
live in one arena and cover it exactly (the session manager's default),
every view IS the arena buffer — zero stack rebuilds ever. Detached
memories (standalone use) fall back to the PR-2 behaviour: device-side
``jnp.stack`` of the per-memory buffers, cached against the members'
insert versions and rebuilt when any version changes (each rebuild is
counted into ``rebuild_stats["stack_rebuilds"]`` when provided).

**Lifecycle for 24/7 streams** (see ARCHITECTURE.md for the full state
machine). Two mechanisms keep memory bounded under unbounded streaming:

* **Slot recycling** — a closed session's arena slot goes onto a
  free-list (``MemoryArena.release_slot``); its lane reads window
  ``(0, 0)`` and is masked out as padding until ``add_session``
  recycles it after ONE donated device-side row reset. The arena grows
  by whole slot blocks only when the free-list is empty, so a churn
  workload (create → ingest → close → recreate) holds the slot count at
  its steady-state maximum with zero restacks and zero reallocation.
* **Eviction** — a session that outlives ``capacity`` consults its
  ``EvictionPolicy``. ``none`` keeps the historical overflow-raises
  contract. The window policies turn the memory into a device-side
  ring: a ``head`` offset marks the oldest valid row, eviction is O(1)
  pointer motion (``head`` advances, ``size`` shrinks) and the incoming
  rows overwrite the evicted physical positions in place. Validity is
  therefore a ``(head, size)`` WINDOW, not a prefix: every scan path
  accepts ``(S, 2)`` ``[start, size)`` windows as its ``valid`` operand
  (masks derive on device — ``kernels.ref.as_valid_mask``), and the
  detached per-memory path derives the same ring mask, so arena and
  detached semantics cannot diverge.

What ``valid`` means, in one place: a **bool mask** is explicit
per-row validity; a **(S,) sizes vector** means prefix ``[0, size)``;
a **(S, 2) window** means ring ``[start, start+size) mod capacity``.
A sizes vector is exactly a window with ``start == 0``.
"""

from __future__ import annotations

import bisect
import contextlib
import functools
import os
from collections import OrderedDict
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.ref import as_valid_mask
from repro.launch.sharding import memory_sharding, mesh_axis_size


class FrameStore:
    """Raw data layer: two-tier host+disk archive of frames by absolute
    index (ARCHITECTURE.md "Storage tiers").

    Append-only at the front, BOUNDED at the back: ``trim(keep_from)``
    removes every host frame below an absolute id, closing the
    unbounded host-RSS leak a 24/7 stream would otherwise accumulate.
    Frames keep their ABSOLUTE ids across trims — ``_base`` offsets the
    retained list — so every id recorded in index/member tables stays
    stable.

    Without a ``spill_dir`` (the historical single-tier contract),
    trimming DELETES: reading a trimmed frame raises ``IndexError``
    with the trim horizon, never silently returns the wrong frame, and
    the session layer only trims below every live reference (ring
    windows + member reservoirs + un-clustered pending frames).

    With ``spill_dir`` set, ``trim`` becomes a DEMOTION to the paper's
    NVMe archive tier: dropped frames are written to append-only ``.npy``
    segment files of ≤ ``segment_frames`` frames each, contiguously
    tiling ``[0, base)`` (demotions always continue at the current
    base, so segment starts are strictly increasing and ``bisect``
    finds any spilled id). ``get`` then transparently FAULTS spilled
    ids back through a small LRU segment cache (``cache_segments``
    whole segments), returning bytes bit-identical to what was appended
    — the npy container round-trips dtype and contents exactly.
    Durability is a tick-boundary affair: segments are written eagerly
    but ``sync()`` (called by the session manager after each tick's
    trims) is what fsyncs them — and the directory — to disk.
    ``io_stats`` counts demotions (``spilled_frames``/``spilled_bytes``)
    and reads (``spill_faults`` = segment loads from disk,
    ``spill_cache_hits`` = reads served from the LRU cache) so tests
    and benches can account for every demotion and fault. ``close()``
    releases BOTH tiers: host frames, the cache, and every segment
    file (churned sessions must leak neither RSS nor disk)."""

    def __init__(self, spill_dir: Optional[str] = None, *,
                 segment_frames: int = 64, cache_segments: int = 4):
        assert segment_frames >= 1, segment_frames
        assert cache_segments >= 1, cache_segments
        self._frames: List[np.ndarray] = []
        self._base = 0            # absolute id of _frames[0]
        self.trimmed = 0          # total frames dropped from host so far
        self.spill_dir = spill_dir
        self.segment_frames = int(segment_frames)
        self.cache_segments = int(cache_segments)
        # (start, count, path, nbytes) per segment, tiling [0, _base)
        self._segments: List[Tuple[int, int, str, int]] = []
        self._seg_starts: List[int] = []       # bisect key for _segments
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._unsynced: List[str] = []         # written, not yet fsync'd
        self._disk_bytes = 0                   # live segment bytes gauge
        self.io_stats = {"spilled_frames": 0, "spilled_bytes": 0,
                         "spill_faults": 0, "spill_cache_hits": 0}
        self.recovered_frames = 0     # adopted from disk at open
        self.dropped_segments = 0     # rejected as short/corrupt/gapped
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            self._recover_segments()

    def _recover_segments(self) -> None:
        """Re-adopt segment files left by a previous process (crash
        recovery: a store re-opened on an existing spill dir must serve
        the frames it already demoted, not silently alias absolute ids
        from 0 again).

        Adoption walks the ``seg-<start>-<count>.npy`` names in start
        order and accepts the longest VALID prefix tiling ``[0, base)``:
        a segment is rejected — along with everything after it, since
        later starts would leave a hole in the id space — if its start
        leaves a gap or its payload doesn't round-trip as a
        ``(count, ...)`` npy array (the
        truncated-mid-write case: a torn header or short data section
        fails to load rather than returning garbage frames). Rejected
        files are deleted so the on-disk state matches the adopted
        prefix and future demotions can't collide with a half-written
        name; files that don't look like segments at all are left
        untouched (they're not ours to delete). The host tier restarts
        empty at ``base`` = the adopted
        frame count; ``get`` faults adopted ids back exactly as if this
        process had spilled them."""
        try:
            names = sorted(os.listdir(self.spill_dir))
        except OSError:
            return
        parsed = []
        rejects = []
        for name in names:
            parts = name.split("-")
            if (name.endswith(".npy") and len(parts) == 3
                    and parts[0] == "seg" and parts[1].isdigit()
                    and parts[2][:-4].isdigit()):
                parsed.append((int(parts[1]), int(parts[2][:-4]), name))
        parsed.sort()
        base = 0
        for start, count, name in parsed:
            path = os.path.join(self.spill_dir, name)
            ok = start == base and count >= 1
            if ok:
                try:
                    # mmap validates the header AND that the file holds
                    # the full payload (a short data section raises) —
                    # without reading the frames in
                    seg = np.load(path, mmap_mode="r",
                                  allow_pickle=False)
                    ok = seg.shape[0] == count
                    nbytes = seg.size * seg.dtype.itemsize
                    del seg
                except Exception:
                    ok = False
            if not ok:
                rejects.append(name)
                continue
            self._segments.append((start, count, path, nbytes))
            self._seg_starts.append(start)
            self._disk_bytes += nbytes
            base = start + count
        self._base = base
        self.trimmed = base
        self.recovered_frames = base
        for name in rejects:
            self.dropped_segments += 1
            with contextlib.suppress(OSError):
                os.remove(os.path.join(self.spill_dir, name))

    def append(self, frames: np.ndarray) -> None:
        for f in np.asarray(frames):
            self._frames.append(f)

    def __len__(self) -> int:
        """Total frames ever archived (absolute id space, incl. trimmed)."""
        return self._base + len(self._frames)

    @property
    def base(self) -> int:
        """Smallest absolute frame id still retained ON HOST. With
        spill enabled, ids below this are on disk, not gone."""
        return self._base

    @property
    def retained(self) -> int:
        """Frames currently held on host (the actual RSS footprint;
        the LRU fault cache is bounded separately by
        ``cache_segments * segment_frames``)."""
        return len(self._frames)

    @property
    def spill_enabled(self) -> bool:
        return self.spill_dir is not None

    @property
    def spill_floor(self) -> int:
        """Smallest absolute id ``get`` can serve: 0 with spill enabled
        (every demoted frame faults back in), else the host base."""
        return 0 if self.spill_enabled else self._base

    @property
    def disk_bytes(self) -> int:
        """Bytes currently held in spill segment files (gauge — drops
        to 0 at ``close``)."""
        return self._disk_bytes

    def reset_io_stats(self) -> None:
        for k in self.io_stats:
            self.io_stats[k] = 0

    def get(self, idx: Sequence[int]) -> np.ndarray:
        out = []
        for i in idx:
            i = int(i)
            if i >= self._base:
                out.append(self._frames[i - self._base])
            elif self.spill_enabled and 0 <= i < self._base:
                out.append(self._fault(i))
            else:
                raise IndexError(
                    f"frame {i} was trimmed from the archive "
                    f"(retained ids start at {self._base})")
        return np.stack(out)

    def trim(self, keep_from: int) -> int:
        """Drop every frame with absolute id < ``keep_from`` from the
        host tier; returns how many left the host. Trimming past the
        end is clamped. With spill enabled this is a demotion — the
        dropped frames are written to segment files first and stay
        readable through ``get``; without it they are gone."""
        drop = max(0, min(int(keep_from), len(self)) - self._base)
        if drop:
            if self.spill_enabled:
                self._spill(self._frames[:drop])
            del self._frames[:drop]
            self._base += drop
            self.trimmed += drop
        return drop

    def _spill(self, frames: List[np.ndarray]) -> None:
        """Demote ``frames`` (the host prefix starting at the current
        base) into ≤ ``segment_frames``-frame npy segments appended
        after the existing ones."""
        start = self._base
        for off in range(0, len(frames), self.segment_frames):
            chunk = np.stack(frames[off:off + self.segment_frames])
            seg_start = start + off
            path = os.path.join(
                self.spill_dir,
                f"seg-{seg_start:012d}-{len(chunk):05d}.npy")
            np.save(path, chunk, allow_pickle=False)
            self._segments.append(
                (seg_start, len(chunk), path, chunk.nbytes))
            self._seg_starts.append(seg_start)
            self._unsynced.append(path)
            self._disk_bytes += chunk.nbytes
            self.io_stats["spilled_frames"] += len(chunk)
            self.io_stats["spilled_bytes"] += chunk.nbytes

    def _fault(self, i: int) -> np.ndarray:
        """Serve one spilled absolute id from its segment, via the LRU
        whole-segment cache (a miss loads — and counts — one segment)."""
        k = bisect.bisect_right(self._seg_starts, i) - 1
        start, count, path, _ = self._segments[k]
        assert start <= i < start + count, (i, start, count)
        seg = self._cache.get(start)
        if seg is not None:
            self._cache.move_to_end(start)
            self.io_stats["spill_cache_hits"] += 1
        else:
            seg = np.load(path, allow_pickle=False)
            self.io_stats["spill_faults"] += 1
            self._cache[start] = seg
            while len(self._cache) > self.cache_segments:
                self._cache.popitem(last=False)
        return seg[i - start]

    def sync(self) -> int:
        """fsync every segment written since the last sync (plus the
        spill directory, so the new names are durable too). The session
        manager calls this at tick boundaries — segment writes inside a
        tick are buffered, the tick commit is the durability point.
        Returns how many files were synced."""
        if not self._unsynced:
            return 0
        for path in self._unsynced:
            with open(path, "rb") as f:
                os.fsync(f.fileno())
        dfd = os.open(self.spill_dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        n = len(self._unsynced)
        self._unsynced.clear()
        return n

    def close(self) -> None:
        """Release BOTH tiers: host frames, the fault cache, and every
        spill segment file (and the per-session spill directory, if
        empty). Idempotent; counters survive so the session layer can
        fold them into its closed-session sums first."""
        self._frames.clear()
        self._cache.clear()
        self._unsynced.clear()
        for _, _, path, _ in self._segments:
            with contextlib.suppress(OSError):
                os.remove(path)
        self._segments.clear()
        self._seg_starts.clear()
        self._disk_bytes = 0
        if self.spill_dir is not None:
            with contextlib.suppress(OSError):
                os.rmdir(self.spill_dir)


@dataclass
class IndexEntry:
    scene_id: int
    cluster_id: int
    ts: int                      # timestamp (frame index) of indexed frame


# Both mask helpers delegate to the kernels' shared `as_valid_mask`
# definition — the ring-window semantics live in exactly ONE place, so
# the arena, detached, oracle, and Pallas paths cannot diverge.

@functools.partial(jax.jit, static_argnames=("capacity",))
def _ring_valid_mask(head: jnp.ndarray, size: jnp.ndarray, *,
                     capacity: int) -> jnp.ndarray:
    """Physical-row validity of the ring window ``[head, head+size)``
    (mod capacity). ``head == 0`` reduces to the plain prefix mask."""
    return as_valid_mask(jnp.stack([head, size])[None], capacity)[0]


@functools.partial(jax.jit, static_argnames=("capacity",))
def _window_valid_stack(windows: jnp.ndarray, *, capacity: int
                        ) -> jnp.ndarray:
    """(S, 2) int ``[head, size]`` windows -> (S, capacity) bool masks,
    derived on device (only the tiny windows array ever transfers)."""
    return as_valid_mask(windows, capacity)


@functools.partial(jax.jit, donate_argnums=(0,))
def _append_rows(emb: jnp.ndarray, rows: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Append a row block at ``pos``. The index buffer is donated, so
    XLA updates it in place — O(rows) bytes moved, not O(capacity)."""
    return jax.lax.dynamic_update_slice(emb, rows, (pos, 0))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append_member_rows(members: jnp.ndarray, counts: jnp.ndarray,
                        rows: jnp.ndarray, cnts: jnp.ndarray,
                        pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """In-place append of member-reservoir rows + their counts."""
    members = jax.lax.dynamic_update_slice(members, rows, (pos, 0))
    counts = jax.lax.dynamic_update_slice(counts, cnts, (pos,))
    return members, counts


@functools.partial(jax.jit, donate_argnums=(0,))
def _append_id_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                    pos: jnp.ndarray) -> jnp.ndarray:
    """In-place append for 1-D id tables (index_frame)."""
    return jax.lax.dynamic_update_slice(buf, rows, (pos,))


# Symmetric per-row int8 quantisation for the index super-buffers. The
# similarity kernels L2-normalise every index row in-register, so a
# per-row scale CANCELS out of the cosine scores — the kernels consume
# the int8 rows directly (one astype, no scales operand) and stream 4×
# fewer bytes per scan. The scales are still stored (one f32 per row,
# written by the same donated scatter as the rows) so anything that
# needs faithful magnitudes can dequantise: dequant = q * scale.
def quantise_rows(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """rows (..., d) f32 -> (int8 rows, (...,) f32 per-row scales) with
    scale = max|row|/127 (all-zero rows get scale 1.0 so dequant is
    exact there too)."""
    rows = np.asarray(rows, np.float32)
    scale = np.max(np.abs(rows), axis=-1) / 127.0
    scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
    q = np.clip(np.rint(rows / scale[..., None]), -127, 127)
    return q.astype(np.int8), scale


def _index_buf_dtype(index_dtype: str):
    assert index_dtype in ("float32", "int8"), index_dtype
    return jnp.int8 if index_dtype == "int8" else jnp.float32


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _arena_reset_slot(emb: jnp.ndarray, members: jnp.ndarray,
                      counts: jnp.ndarray, ifr: jnp.ndarray,
                      slot: jnp.ndarray):
    """Donated zero-reset of ONE slot's rows across every super-buffer —
    the whole device-side cost of recycling a freed slot for a new
    session is this single program (no reallocation, no restack)."""
    emb = jax.lax.dynamic_update_slice(
        emb, jnp.zeros((1,) + emb.shape[1:], emb.dtype), (slot, 0, 0))
    members = jax.lax.dynamic_update_slice(
        members, jnp.zeros((1,) + members.shape[1:], members.dtype),
        (slot, 0, 0))
    counts = jax.lax.dynamic_update_slice(
        counts, jnp.zeros((1,) + counts.shape[1:], counts.dtype),
        (slot, 0))
    ifr = jax.lax.dynamic_update_slice(
        ifr, jnp.zeros((1,) + ifr.shape[1:], ifr.dtype), (slot, 0))
    return emb, members, counts, ifr


@functools.partial(jax.jit, donate_argnums=(0,))
def _arena_reset_row(buf: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Donated zero-reset of one slot's row in a (S, cap) table — the
    int8 arena's per-row scale buffer at slot-recycle time."""
    return jax.lax.dynamic_update_slice(
        buf, jnp.zeros((1,) + buf.shape[1:], buf.dtype), (slot, 0))


@functools.partial(jax.jit, donate_argnums=(0,))
def _arena_scatter_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                        slots: jnp.ndarray, poss: jnp.ndarray
                        ) -> jnp.ndarray:
    """Donated scatter of a whole TICK's rows — every session's appends
    in one program: buf (S, cap, …) gets rows (B, …) written at
    (slots[i], poss[i]) in place. Padding rows duplicate row 0 (same
    index, same value — a deterministic no-op rewrite)."""
    return buf.at[slots, poss].set(rows)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _arena_scatter_meta(counts: jnp.ndarray, ifr: jnp.ndarray,
                        cnt_rows: jnp.ndarray, if_rows: jnp.ndarray,
                        slots: jnp.ndarray, poss: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Donated per-tick scatter of the small (S, cap) tables."""
    return (counts.at[slots, poss].set(cnt_rows),
            ifr.at[slots, poss].set(if_rows))


# Uniform member pick: one variate per draw slot, represented as an
# integer u ∈ [0, 2^U_BITS) so host (int64) and device (int32) paths
# compute pick = (u * cnt) >> U_BITS *bit-identically* — no float
# rounding can make the two paths disagree at a floor boundary.
U_BITS = 20
_U_CARD = 1 << U_BITS


@jax.jit
def expand_gather(members: jnp.ndarray, counts: jnp.ndarray,
                  draws: jnp.ndarray, valid: jnp.ndarray,
                  u: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device reservoir gather: draws (..., n) index rows of the
    device-resident members table; u (n,) or (..., n) int32 variates pick
    one member per slot. Returns (frame ids (..., n), ok (..., n))."""
    cap = members.shape[0]
    safe = jnp.clip(draws, 0, cap - 1)
    cnt = counts[safe]                                    # (..., n)
    pick = (u.astype(jnp.int32) * cnt) >> U_BITS          # exact floor
    fids = jnp.take_along_axis(members[safe], pick[..., None], -1)[..., 0]
    ok = valid & (cnt > 0) & (draws >= 0)
    return fids, ok


from repro.util import pow2_bucket


# ---------------------------------------------------------------------------
# Eviction policies: what happens when an insert would overflow capacity
# ---------------------------------------------------------------------------


class EvictionPolicy:
    """Bounded-memory policy for sessions that outlive ``capacity``.

    ``none`` preserves the historical contract: overflow raises and the
    session simply stops ingesting. The window policies below turn the
    memory into a device-side ring — ``evict`` advances the logical
    window start (``head``) over the ``need`` oldest rows, O(1) pointer
    motion; the incoming rows then overwrite the evicted physical
    positions in place, so a 24/7 stream runs forever in constant
    device memory.
    """

    name = "none"

    def evict(self, mem: "VenusMemory", need: int) -> None:
        raise RuntimeError("memory capacity exhausted")


class SlidingWindowEviction(EvictionPolicy):
    """Keep only the newest ``capacity`` index rows: evict the oldest
    ``need`` rows by advancing the ring head (the streaming-systems
    baseline — bounded memory + explicit eviction, cf. LiveVLM)."""

    name = "sliding_window"

    def evict(self, mem: "VenusMemory", need: int) -> None:
        mem._advance_head(need)


class ClusterMergeEviction(SlidingWindowEviction):
    """Sliding window that first folds each evictee's member reservoir
    into its most similar surviving index row (cosine ≥ ``threshold``),
    so the raw frames of an evicted cluster stay reachable through the
    merged cluster instead of cliff-dropping at the window edge."""

    name = "cluster_merge"

    def __init__(self, threshold: float = 0.8):
        self.threshold = threshold

    def evict(self, mem: "VenusMemory", need: int) -> None:
        mem._merge_into_survivors(need, self.threshold)
        mem._advance_head(need)


class ConsolidationEviction(ClusterMergeEviction):
    """Hierarchical-tier eviction (paper §IV-C): each evictee folds
    into the session's COARSE summary tier — a running count-weighted
    centroid + merged member reservoir + frame-window metadata — before
    the ring head advances. Unlike ``cluster_merge``, the fold target
    is a dedicated summary row (not a surviving fine row), so evicted
    history stays retrievable through the two-stage coarse→fine scan
    long after it leaves the fine window. Requires the memory to be
    built with ``coarse_capacity > 0``."""

    name = "consolidate"

    def evict(self, mem: "VenusMemory", need: int) -> None:
        mem._consolidate(need, self.threshold)
        mem._advance_head(need)


_EVICTION_POLICIES = {
    "none": EvictionPolicy,
    "sliding_window": SlidingWindowEviction,
    "cluster_merge": ClusterMergeEviction,
    "consolidate": ConsolidationEviction,
}


def get_eviction_policy(policy,
                        threshold: Optional[float] = None) -> EvictionPolicy:
    """Resolve a policy by name (an ``EvictionPolicy`` instance passes
    through, so callers can hand in a configured one). ``threshold``
    configures the similarity cut of the merge/consolidation policies
    (``VenusConfig.merge_threshold`` reaches here); it is validated to
    (0, 1] — cosine similarity of normalised rows — and rejected for
    policies that have no threshold to configure."""
    if threshold is not None:
        if not (0.0 < float(threshold) <= 1.0):
            raise ValueError(
                f"merge threshold must be in (0, 1], got {threshold!r}")
    if isinstance(policy, EvictionPolicy):
        return policy
    try:
        cls = _EVICTION_POLICIES[policy]
    except KeyError:
        raise KeyError(f"unknown eviction policy {policy!r}; known: "
                       f"{sorted(_EVICTION_POLICIES)}") from None
    if threshold is not None and issubclass(cls, ClusterMergeEviction):
        return cls(float(threshold))
    return cls()


def coarse_rows_for(capacity: int, coarse_capacity: int,
                    coarse_block: int) -> Tuple[int, int]:
    """Geometry of the coarse tier: ``(n_blocks, n_coarse)`` where rows
    ``[0, n_blocks)`` are block summaries of the fine tier (one per
    ``coarse_block`` physical fine rows) and rows ``[n_blocks,
    n_coarse)`` are consolidated summaries of evicted history. A
    ``coarse_capacity`` of 0 disables the tier entirely."""
    if coarse_capacity <= 0:
        return 0, 0
    assert coarse_block > 0, coarse_block
    n_blocks = -(-capacity // coarse_block)        # ceil div
    return n_blocks, n_blocks + coarse_capacity


class MemoryArena:
    """Shared device-resident super-buffers for S sessions' memories.

    Sessions allocate their index (``emb``), member reservoirs
    (``members``/``member_count``), and ``index_frame`` rows directly
    inside ``(S, capacity, …)`` buffers owned here, so the fused
    cross-session query path scans the arena buffers AS-IS: batched tick
    appends are donated ``dynamic_update_slice`` writes at
    ``(slot, pos)``, and after warm-up no ingest↔query interleaving ever
    triggers a device-side restack (``stack_rebuilds`` stays 0 — see
    ``MemoryStack``). Per-session valid masks are derived on device from
    the ``(S,)`` sizes vector (the only thing that moves host→device
    per tick besides the appended rows themselves).

    Slot lifecycle: ``add_session`` prefers the free-list — a slot a
    closed session released via ``release_slot`` — and recycles it after
    ONE donated device-side row reset; the buffers grow by a whole slot
    block (a copy, counted in ``io_stats["grows"]``) only when the
    free-list is empty. Session churn therefore holds the slot count at
    its steady-state maximum: creation is warm-up, not the steady
    ingest↔query loop. Each slot carries a ``(head, size)`` ring window
    (``heads``/``sizes`` host mirrors); free slots read ``(0, 0)`` and
    are masked-out padding lanes until reuse.

    ``index_dtype="int8"`` stores the index super-buffer quantised
    (symmetric per-row int8, scales in ``emb_scale``): every append
    quantises once at the donated scatter, every scan streams 4× fewer
    bytes, and the scan math is unchanged because the kernels
    L2-normalise rows — the per-row scale cancels, so no dequant pass
    and no scales operand exist anywhere in the kernel contract.

    **Sharding** (``mesh=`` + the mesh's ``model`` axis size K > 1):
    every super-buffer is placed with ``memory_sharding`` — the leading
    slot axis split into K contiguous slabs, trailing dims replicated —
    and the fused scan entries in ``kernels.ops`` fan the SAME kernels
    out per-slab under ``shard_map`` (the stack kernels are pure
    per-lane programs, so a slab scan is bitwise the single-device scan
    restricted to that slab). To keep slabs rectangular the arena then
    grows in blocks of K slots: the block's first slot is handed out,
    the rest wait in ``virgin_slots`` (already zeroed — claiming one
    costs nothing and is not a ``slot_reuse``); allocation picks the
    free/virgin slot on the least-loaded shard so sessions stay
    balanced across devices. With K == 1 (or no mesh) every code path
    below is byte-for-byte the unsharded PR-6 behaviour — single-slot
    growth, exact LIFO free-list reuse, no placement.

    **Double buffering** (``double_buffer=True``): the arena keeps a
    second, back set of super-buffers one tick behind the front.
    A tick's flush replays last tick's blocks (the ``carry``) plus this
    tick's pending into the BACK set, then swaps front↔back — so the
    donated append scatter never writes the buffers queries are
    scanning, and XLA's async dispatch overlaps ingest with the fused
    query launches instead of serialising on the donation hazard.
    Because scatters compose last-write-wins per (slot, pos), the front
    after every flush is bitwise identical to the single-buffer state;
    slot resets and growth apply to both sets, and the carry is
    filtered when its slot is recycled.
    """

    def __init__(self, capacity: int, dim: int, member_cap: int = 128,
                 index_dtype: str = "float32", *, mesh=None,
                 mesh_axis: str = "model", double_buffer: bool = False,
                 coarse_capacity: int = 0, coarse_block: int = 64):
        self.capacity = capacity
        self.dim = dim
        self.member_cap = member_cap
        self.index_dtype = index_dtype
        self._emb_dtype = _index_buf_dtype(index_dtype)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.n_shards = mesh_axis_size(mesh, mesh_axis)
        self.emb_scale: Optional[jnp.ndarray] = None    # (S, cap) f32
        self.n_sessions = 0       # allocated slots (incl. freed ones)
        self.emb: Optional[jnp.ndarray] = None          # (S, cap, d)
        self.members: Optional[jnp.ndarray] = None      # (S, cap, K)
        self.member_count: Optional[jnp.ndarray] = None  # (S, cap)
        self.index_frame: Optional[jnp.ndarray] = None   # (S, cap)
        self.sizes = np.zeros((0,), np.int32)            # host mirror
        self.heads = np.zeros((0,), np.int32)            # ring starts
        # coarse tier: (S, n_coarse, ·) summary super-buffers — rows
        # [0, n_blocks) summarise fine blocks, [n_blocks, n_coarse)
        # hold consolidated (evicted) history. Always f32: centroids
        # are running means and the scan normalises rows anyway, so
        # quantising the tiny coarse stack buys nothing.
        self.coarse_capacity = coarse_capacity
        self.coarse_block = coarse_block
        self.n_blocks, self.n_coarse = coarse_rows_for(
            capacity, coarse_capacity, coarse_block)
        self.coarse_emb: Optional[jnp.ndarray] = None        # (S, Nc, d)
        self.coarse_members: Optional[jnp.ndarray] = None    # (S, Nc, K)
        self.coarse_member_count: Optional[jnp.ndarray] = None  # (S, Nc)
        self.coarse_index_frame: Optional[jnp.ndarray] = None   # (S, Nc)
        self.coarse_valid = np.zeros((0, self.n_coarse), bool)  # host
        self._coarse_valid_dev: Optional[jnp.ndarray] = None
        self._coarse_valid_ver = -1
        self._coarse_deferred: Optional[list] = None
        self.free_slots: List[int] = []    # released, awaiting reuse
        self.virgin_slots: List[int] = []  # grown, never yet allocated
        self.version = 0          # bumped per append / grow / release
        self._sizes_dev: Optional[jnp.ndarray] = None
        self._windows_dev: Optional[jnp.ndarray] = None
        self._valid_dev: Optional[jnp.ndarray] = None
        self._valid_version = -1
        self._deferred: Optional[list] = None   # open tick batch, or None
        # back buffer set (double_buffer) + last tick's blocks to replay
        self._back: Optional[dict] = (
            {"emb": None, "members": None, "member_count": None,
             "index_frame": None, "emb_scale": None}
            if double_buffer else None)
        self._carry: list = []
        self.io_stats = {"grows": 0, "appends": 0, "appended_rows": 0,
                         "slot_releases": 0, "slot_reuses": 0,
                         "double_flushes": 0, "carry_rows": 0,
                         "coarse_appends": 0, "coarse_appended_rows": 0}

    @property
    def double_buffer(self) -> bool:
        return self._back is not None

    def reset_io_stats(self) -> None:
        for k in self.io_stats:
            self.io_stats[k] = 0

    # ------------------------------------------------------------- lifecycle
    def _place(self, buf: jnp.ndarray) -> jnp.ndarray:
        """Pin a super-buffer to its mesh placement: leading slot axis in
        contiguous per-device slabs, trailing dims replicated (the same
        spec the shard_map scan entries consume). No-op unsharded."""
        if self.mesh is not None and self.n_shards > 1:
            return jax.device_put(
                buf, memory_sharding(self.mesh, buf.ndim, self.mesh_axis))
        return buf

    def _grow(self, buf: Optional[jnp.ndarray], shape: Tuple[int, ...],
              dtype) -> jnp.ndarray:
        if buf is None:
            return self._place(jnp.zeros(shape, dtype))
        pad = [(0, shape[0] - buf.shape[0])] + [(0, 0)] * (buf.ndim - 1)
        # growth moves slab boundaries, so the pad includes a reshard
        # copy — acceptable: growth is warm-up, never the steady loop
        return self._place(jnp.pad(buf, pad))

    def _shard_of(self, slot: int) -> int:
        """Which contiguous slab (device) a slot currently lives on."""
        slab = max(1, self.n_sessions // self.n_shards)
        return min(slot // slab, self.n_shards - 1)

    def _recycle(self, slot: int) -> int:
        """Reset a released slot's device rows (one donated program per
        buffer set) and hand it out again."""
        js = jnp.asarray(slot, jnp.int32)
        (self.emb, self.members, self.member_count,
         self.index_frame) = _arena_reset_slot(
            self.emb, self.members, self.member_count,
            self.index_frame, js)
        if self.emb_scale is not None:
            self.emb_scale = _arena_reset_row(self.emb_scale, js)
        if self._back is not None:
            bk = self._back
            (bk["emb"], bk["members"], bk["member_count"],
             bk["index_frame"]) = _arena_reset_slot(
                bk["emb"], bk["members"], bk["member_count"],
                bk["index_frame"], js)
            if bk["emb_scale"] is not None:
                bk["emb_scale"] = _arena_reset_row(bk["emb_scale"], js)
            # drop the reset slot from the replay queue — last tick's
            # rows must not resurrect inside a recycled slot
            self._carry = [b for b in self._carry if b[0] != slot]
        if self.n_coarse:
            (self.coarse_emb, self.coarse_members, self.coarse_member_count,
             self.coarse_index_frame) = _arena_reset_slot(
                self.coarse_emb, self.coarse_members,
                self.coarse_member_count, self.coarse_index_frame, js)
            self.coarse_valid[slot] = False
        self.sizes[slot] = 0
        self.heads[slot] = 0
        self.version += 1
        self.io_stats["slot_reuses"] += 1
        return slot

    def _grow_block(self) -> int:
        """Grow every super-buffer by one slot block (``n_shards`` slots,
        so S always divides the mesh axis); returns the first new slot,
        parking the rest in ``virgin_slots``."""
        slot = self.n_sessions
        self.n_sessions = s = slot + self.n_shards
        cap, d, k = self.capacity, self.dim, self.member_cap
        self.emb = self._grow(self.emb, (s, cap, d), self._emb_dtype)
        if self.index_dtype == "int8":
            self.emb_scale = self._grow(self.emb_scale, (s, cap),
                                        jnp.float32)
        self.members = self._grow(self.members, (s, cap, k), jnp.int32)
        self.member_count = self._grow(self.member_count, (s, cap),
                                       jnp.int32)
        self.index_frame = self._grow(self.index_frame, (s, cap),
                                      jnp.int32)
        if self._back is not None:
            bk = self._back
            bk["emb"] = self._grow(bk["emb"], (s, cap, d), self._emb_dtype)
            if self.index_dtype == "int8":
                bk["emb_scale"] = self._grow(bk["emb_scale"], (s, cap),
                                             jnp.float32)
            bk["members"] = self._grow(bk["members"], (s, cap, k),
                                       jnp.int32)
            bk["member_count"] = self._grow(bk["member_count"], (s, cap),
                                            jnp.int32)
            bk["index_frame"] = self._grow(bk["index_frame"], (s, cap),
                                           jnp.int32)
        if self.n_coarse:
            nc = self.n_coarse
            self.coarse_emb = self._grow(self.coarse_emb, (s, nc, d),
                                         jnp.float32)
            self.coarse_members = self._grow(self.coarse_members,
                                             (s, nc, k), jnp.int32)
            self.coarse_member_count = self._grow(
                self.coarse_member_count, (s, nc), jnp.int32)
            self.coarse_index_frame = self._grow(
                self.coarse_index_frame, (s, nc), jnp.int32)
            self.coarse_valid = np.concatenate(
                [self.coarse_valid,
                 np.zeros((self.n_shards, nc), bool)])
        self.sizes = np.append(self.sizes,
                               np.zeros((self.n_shards,), np.int32))
        self.heads = np.append(self.heads,
                               np.zeros((self.n_shards,), np.int32))
        self.virgin_slots.extend(range(slot + 1, s))
        self.version += 1
        self.io_stats["grows"] += 1
        return slot

    def add_session(self) -> int:
        """Allocate a slot: recycle a released one (device rows reset
        via one donated program — no growth, no restack), claim a
        still-virgin slot from an earlier growth block, or grow every
        super-buffer by one whole slot block."""
        if self.n_shards == 1:
            # unsharded: exact PR-6 behaviour — LIFO reuse, 1-slot blocks
            if self.free_slots:
                return self._recycle(self.free_slots.pop())
            return self._grow_block()
        cand = sorted(set(self.free_slots) | set(self.virgin_slots))
        if not cand:
            return self._grow_block()
        # balance live sessions across slabs: pick the candidate on the
        # least-loaded shard (tie → lowest slot id)
        dead = set(self.free_slots) | set(self.virgin_slots)
        load = [0] * self.n_shards
        for s in range(self.n_sessions):
            if s not in dead:
                load[self._shard_of(s)] += 1
        slot = min(cand, key=lambda s: (load[self._shard_of(s)], s))
        if slot in self.virgin_slots:
            # never written: its rows are the zeros growth placed there,
            # so claiming costs no device work at all
            self.virgin_slots.remove(slot)
            return slot
        self.free_slots.remove(slot)
        return self._recycle(slot)

    def release_slot(self, slot: int) -> None:
        """Free a closed session's slot into the free-list. The lane's
        window reads ``(0, 0)`` — masked-out padding for every scan —
        until ``add_session`` recycles it; the stale device rows are
        reset at reuse time, so closing costs no device work at all."""
        assert 0 <= slot < self.n_sessions, slot
        assert slot not in self.free_slots, f"slot {slot} already free"
        assert slot not in self.virgin_slots, f"slot {slot} never allocated"
        self.free_slots.append(slot)
        self.sizes[slot] = 0
        self.heads[slot] = 0
        if self.n_coarse:
            # mask the lane's whole coarse tier out of stage-1 scans;
            # the stale device rows reset at reuse time like fine rows
            self.coarse_valid[slot] = False
        self.version += 1
        self.io_stats["slot_releases"] += 1

    # ------------------------------------------------------------ ingestion
    @contextlib.contextmanager
    def deferred_appends(self):
        """Batch every ``append`` issued inside the context into ONE
        donated scatter per super-buffer — the per-tick batched append
        path: a multi-stream ingest tick moves each buffer once, no
        matter how many sessions closed clusters. Device views read
        inside the window see pre-tick state; they refresh at exit (one
        version bump). Re-entrant: the outermost context flushes."""
        if self._deferred is not None:
            yield
            return
        self._deferred = []
        self._coarse_deferred = []
        try:
            yield
        finally:
            pending, self._deferred = self._deferred, None
            coarse, self._coarse_deferred = self._coarse_deferred, None
            self._flush(pending)
            # coarse rows land AFTER the fine flush: block summaries are
            # host-computed from the post-tick mirrors, so their device
            # write must not be overtaken by this tick's fine scatter
            self._flush_coarse(coarse)

    def append(self, slot: int, pos: int, emb_rows: np.ndarray,
               member_rows: np.ndarray, member_cnts: np.ndarray,
               if_rows: np.ndarray, window: Tuple[int, int]) -> int:
        """Append one session's contiguous row run at ``[slot,
        pos:pos+n]`` and record its new ``(head, size)`` ring window
        (applied when the write lands — a wrapped ring write arrives as
        two contiguous runs, each carrying the same final window).

        Inside a ``deferred_appends`` window the run is queued for the
        tick's fused scatter; otherwise it lands immediately as its own
        donated scatter. Either way the row count is bucketed with
        padding rows that DUPLICATE row 0 (same index, same value — a
        deterministic no-op rewrite), which is ring-safe: padding past
        the run could overwrite live rows once a session wraps. Returns
        the rows moved (raw count when deferred, padded when not)."""
        block = (slot, pos, np.asarray(emb_rows), np.asarray(member_rows),
                 np.asarray(member_cnts), np.asarray(if_rows),
                 (int(window[0]), int(window[1])))
        if self._deferred is not None:
            self._deferred.append(block)
            return len(emb_rows)
        return self._flush([block])

    def append_coarse(self, slot: int, pos: int, emb_rows: np.ndarray,
                      member_rows: np.ndarray, member_cnts: np.ndarray,
                      if_rows: np.ndarray, valid_rows: np.ndarray) -> int:
        """Queue one session's coarse summary-row run at ``[slot,
        pos:pos+n]`` — block summaries (``pos < n_blocks``) or
        consolidated rows. Inside a ``deferred_appends`` window the run
        rides the tick's coarse scatter; otherwise it lands immediately.
        ``valid_rows`` is each row's stage-1 visibility (an empty fine
        block's summary is masked out)."""
        assert self.n_coarse, "arena has no coarse tier"
        block = (slot, pos, np.asarray(emb_rows, np.float32),
                 np.asarray(member_rows), np.asarray(member_cnts),
                 np.asarray(if_rows),
                 np.asarray(valid_rows, bool))
        if self._coarse_deferred is not None:
            self._coarse_deferred.append(block)
            return len(emb_rows)
        return self._flush_coarse([block])

    def _flush_coarse(self, blocks: list) -> int:
        """One donated scatter per coarse super-buffer for the tick's
        summary-row writes (same last-write-wins dedup + pow2 bucketing
        as the fine scatter). The coarse tier is single-buffered even
        under ``double_buffer`` — summary rows are tiny, and a stale-by-
        one-tick coarse row only shifts which fine blocks stage 2
        gathers, never correctness."""
        if not blocks:
            return 0
        slots = np.concatenate([np.full(len(e), s, np.int32)
                                for s, _, e, *_ in blocks])
        poss = np.concatenate([np.arange(p, p + len(e), dtype=np.int32)
                               for _, p, e, *_ in blocks])
        emb_rows = np.concatenate([b[2] for b in blocks])
        mem_rows = np.concatenate([b[3] for b in blocks])
        cnt_rows = np.concatenate([b[4] for b in blocks])
        if_rows = np.concatenate([b[5] for b in blocks])
        val_rows = np.concatenate([b[6] for b in blocks])
        lin = slots.astype(np.int64) * self.n_coarse + poss
        if len(np.unique(lin)) != len(lin):
            last = {l: i for i, l in enumerate(lin)}
            keep = np.sort(np.fromiter(last.values(), np.int64))
            slots, poss = slots[keep], poss[keep]
            emb_rows, mem_rows = emb_rows[keep], mem_rows[keep]
            cnt_rows, if_rows = cnt_rows[keep], if_rows[keep]
            val_rows = val_rows[keep]
        self.coarse_valid[slots, poss] = val_rows
        n = len(slots)
        b = pow2_bucket(n, lo=8)
        if b != n:                       # pad = rewrite row 0 in place
            reps = np.zeros((b - n,), np.int32)
            slots = np.concatenate([slots, slots[reps]])
            poss = np.concatenate([poss, poss[reps]])
            emb_rows = np.concatenate([emb_rows, emb_rows[reps]])
            mem_rows = np.concatenate([mem_rows, mem_rows[reps]])
            cnt_rows = np.concatenate([cnt_rows, cnt_rows[reps]])
            if_rows = np.concatenate([if_rows, if_rows[reps]])
        sl, po = jnp.asarray(slots), jnp.asarray(poss)
        self.coarse_emb = _arena_scatter_rows(
            self.coarse_emb, jnp.asarray(emb_rows), sl, po)
        self.coarse_members = _arena_scatter_rows(
            self.coarse_members, jnp.asarray(mem_rows), sl, po)
        self.coarse_member_count, self.coarse_index_frame = \
            _arena_scatter_meta(
                self.coarse_member_count, self.coarse_index_frame,
                jnp.asarray(cnt_rows), jnp.asarray(if_rows), sl, po)
        self.version += 1
        self.io_stats["coarse_appends"] += 1
        self.io_stats["coarse_appended_rows"] += b
        return b

    def _scatter_into(self, bufs: dict, blocks: list) -> Tuple[dict, int]:
        """Apply ``blocks`` to the buffer set ``bufs``: ONE donated
        scatter per super-buffer, with the total row count bucketed
        (padding rows duplicate row 0 — same index, same values, a
        no-op rewrite). An evicting session can wrap within one tick
        and hit the same physical position twice, and the double-buffer
        replay re-applies last tick's blocks before this tick's;
        scatter order over duplicate indices is undefined, so only the
        LAST write per (slot, pos) is kept — which is exactly what
        makes carry+pending composition equal to sequential flushes."""
        slots = np.concatenate([np.full(len(e), s, np.int32)
                                for s, _, e, *_ in blocks])
        poss = np.concatenate([np.arange(p, p + len(e), dtype=np.int32)
                               for _, p, e, *_ in blocks])
        emb_rows = np.concatenate([b[2] for b in blocks])
        mem_rows = np.concatenate([b[3] for b in blocks])
        cnt_rows = np.concatenate([b[4] for b in blocks])
        if_rows = np.concatenate([b[5] for b in blocks])
        lin = slots.astype(np.int64) * self.capacity + poss
        if len(np.unique(lin)) != len(lin):
            last = {l: i for i, l in enumerate(lin)}
            keep = np.sort(np.fromiter(last.values(), np.int64))
            slots, poss = slots[keep], poss[keep]
            emb_rows, mem_rows = emb_rows[keep], mem_rows[keep]
            cnt_rows, if_rows = cnt_rows[keep], if_rows[keep]
        n = len(slots)
        b = pow2_bucket(n, lo=8)
        if b != n:                       # pad = rewrite row 0 in place
            reps = np.zeros((b - n,), np.int32)
            slots = np.concatenate([slots, slots[reps]])
            poss = np.concatenate([poss, poss[reps]])
            emb_rows = np.concatenate([emb_rows, emb_rows[reps]])
            mem_rows = np.concatenate([mem_rows, mem_rows[reps]])
            cnt_rows = np.concatenate([cnt_rows, cnt_rows[reps]])
            if_rows = np.concatenate([if_rows, if_rows[reps]])
        sl, po = jnp.asarray(slots), jnp.asarray(poss)
        out = dict(bufs)
        if self.index_dtype == "int8":
            # quantise ONCE, at the append scatter — scans stream the
            # int8 rows as-is from here on (scale cancels under the
            # kernels' row normalisation; kept for faithful dequant).
            # Pure per-row, so a carry replay re-quantises identically.
            emb_rows, scale_rows = quantise_rows(emb_rows)
            out["emb_scale"] = _arena_scatter_rows(
                bufs["emb_scale"], jnp.asarray(scale_rows), sl, po)
        out["emb"] = _arena_scatter_rows(bufs["emb"],
                                         jnp.asarray(emb_rows), sl, po)
        out["members"] = _arena_scatter_rows(bufs["members"],
                                             jnp.asarray(mem_rows), sl, po)
        out["member_count"], out["index_frame"] = _arena_scatter_meta(
            bufs["member_count"], bufs["index_frame"],
            jnp.asarray(cnt_rows), jnp.asarray(if_rows), sl, po)
        return out, b

    @staticmethod
    def _copy_block(block):
        """Deep-copy a queued block for the carry: ``append`` stores
        VIEWS of the session's host mirrors, which a later ring wrap
        would mutate before the replay lands."""
        s, p, e, m, c, f, w = block
        return (s, p, e.copy(), m.copy(), c.copy(), f.copy(), w)

    def _flush(self, pending: list) -> int:
        """Apply queued blocks; windows apply in queue order, so the
        last block a session queued wins.

        Single-buffer: one donated scatter per super-buffer, straight
        into the live (query-visible) set. Double-buffer: the scatter
        targets the BACK set — last tick's carry replayed first, then
        this tick's pending — and the sets swap, so ingest never
        donates the buffers a concurrent query launch is scanning and
        XLA dispatch overlaps the two instead of serialising. The
        swapped-in front is bitwise the single-buffer result (carry ∘
        pending composes last-write-wins)."""
        if not pending:
            return 0
        if self._back is None:
            bufs = {"emb": self.emb, "members": self.members,
                    "member_count": self.member_count,
                    "index_frame": self.index_frame,
                    "emb_scale": self.emb_scale}
            bufs, b = self._scatter_into(bufs, pending)
        else:
            carry = self._carry
            bufs, b = self._scatter_into(self._back, carry + pending)
            self._back = {"emb": self.emb, "members": self.members,
                          "member_count": self.member_count,
                          "index_frame": self.index_frame,
                          "emb_scale": self.emb_scale}
            self._carry = [self._copy_block(bl) for bl in pending]
            self.io_stats["double_flushes"] += 1
            self.io_stats["carry_rows"] += sum(len(bl[2]) for bl in carry)
        self.emb = bufs["emb"]
        self.members = bufs["members"]
        self.member_count = bufs["member_count"]
        self.index_frame = bufs["index_frame"]
        self.emb_scale = bufs["emb_scale"]
        for slot, _pos, _rows, _m, _c, _f, window in pending:
            self.heads[slot], self.sizes[slot] = window
        self.version += 1
        self.io_stats["appends"] += 1
        self.io_stats["appended_rows"] += b
        return b

    # ----------------------------------------------------------------- views
    def device_sizes(self) -> jnp.ndarray:
        """Per-session sizes (S,) on device (window lengths — pair with
        ``device_windows`` for the ring starts)."""
        if self._sizes_dev is None or self._valid_version != self.version:
            self._refresh_valid()
        return self._sizes_dev

    def device_windows(self) -> jnp.ndarray:
        """(S, 2) int32 ``[head, size]`` ring windows on device — the
        fused scan's ``valid`` operand (masks derive inside the kernel
        wrapper; free slots read ``[0, 0]`` and scan as padding)."""
        if (self._windows_dev is None
                or self._valid_version != self.version):
            self._refresh_valid()
        return self._windows_dev

    def device_valid(self) -> jnp.ndarray:
        """(S, capacity) bool valid mask, derived on device from the
        ring windows and cached per version (no O(S·cap) host traffic —
        only the (S, 2) windows array transfers)."""
        if self._valid_dev is None or self._valid_version != self.version:
            self._refresh_valid()
        return self._valid_dev

    def _refresh_valid(self) -> None:
        self._sizes_dev = jnp.asarray(self.sizes)
        self._windows_dev = jnp.asarray(
            np.stack([self.heads, self.sizes], axis=1).astype(np.int32))
        self._valid_dev = _window_valid_stack(self._windows_dev,
                                              capacity=self.capacity)
        self._valid_version = self.version

    def device_coarse_valid(self) -> jnp.ndarray:
        """(S, n_coarse) bool stage-1 mask for the coarse tier, cached
        per version (coarse validity is sparse and host-authored, so the
        explicit mask form is the canonical valid operand here)."""
        assert self.n_coarse, "arena has no coarse tier"
        if (self._coarse_valid_dev is None
                or self._coarse_valid_ver != self.version):
            self._coarse_valid_dev = jnp.asarray(self.coarse_valid)
            self._coarse_valid_ver = self.version
        return self._coarse_valid_dev

    def has_consolidated(self) -> bool:
        """True iff any lane holds a consolidated summary row — the
        two-stage trigger: until the first consolidation the coarse tier
        is "empty" and every query takes the flat scan unchanged."""
        return bool(self.n_coarse
                    and self.coarse_valid[:, self.n_blocks:].any())


class VenusMemory:
    """Index layer: packed vector store + cluster member reservoirs."""

    def __init__(self, capacity: int, dim: int, member_cap: int = 128,
                 seed: int = 0, *, incremental: bool = True,
                 arena: Optional[MemoryArena] = None,
                 slot: Optional[int] = None,
                 eviction="none", index_dtype: str = "float32",
                 merge_threshold: Optional[float] = None,
                 coarse_capacity: int = 0, coarse_block: int = 64):
        # the exact integer pick (u * cnt) >> U_BITS must fit in int32
        assert member_cap <= (1 << (31 - U_BITS)), member_cap
        self.capacity = capacity
        self.dim = dim
        self.member_cap = member_cap
        self.incremental = incremental
        self.eviction = get_eviction_policy(eviction, merge_threshold)
        # int8 option: host mirrors stay f32 (exact math for merges and
        # host expansion); the DEVICE copy is quantised — arena-backed
        # memories quantise inside the arena's append scatter, detached
        # ones at lazy upload / in-place append. Quantisation is a pure
        # per-row function of the host mirror, so arena and detached
        # device rows are bit-identical for the same contents.
        self.index_dtype = index_dtype
        _index_buf_dtype(index_dtype)          # validate early
        if arena is not None:
            assert arena.index_dtype == index_dtype, \
                (arena.index_dtype, index_dtype)
        # arena-backed: this memory's device rows live inside the shared
        # super-buffers at ``slot`` (appends are donated writes into the
        # arena; nothing is ever lazily uploaded). Detached fallback
        # (arena=None): standalone per-memory device buffers, lazily
        # uploaded on first query and appended in place (PR-1 path).
        self.arena = arena
        self.slot = slot
        if arena is not None:
            assert slot is not None and incremental
            assert (arena.capacity, arena.dim, arena.member_cap) == \
                (capacity, dim, member_cap)
            assert (arena.coarse_capacity, arena.coarse_block) == \
                (coarse_capacity, coarse_block), \
                "memory and arena disagree on coarse-tier geometry"
        # coarse consolidation tier: host-authoritative summary rows.
        # Block summaries ([0, n_blocks)) are computed on demand from
        # the fine mirrors; only the consolidated region keeps host
        # state (running centroid / merged reservoir / frame window).
        self.coarse_capacity = coarse_capacity
        self.coarse_block = coarse_block
        self.n_blocks, self.n_coarse = coarse_rows_for(
            capacity, coarse_capacity, coarse_block)
        if self.n_coarse:
            cc = coarse_capacity
            self._coarse_emb = np.zeros((cc, dim), np.float32)
            self._coarse_members = np.zeros((cc, member_cap), np.int32)
            self._coarse_count = np.zeros((cc,), np.int32)
            self._coarse_ifr = np.zeros((cc,), np.int32)
            self._coarse_weight = np.zeros((cc,), np.int64)
            self._coarse_fid_lo = np.zeros((cc,), np.int64)
            self._coarse_fid_hi = np.zeros((cc,), np.int64)
        self._coarse_csize = 0          # consolidated rows in use
        self._dirty_blocks: set = set()  # fine blocks to re-summarise
        self._emb = np.zeros((capacity, dim), np.float32)
        self._members = np.zeros((capacity, member_cap), np.int32)
        self._member_count = np.zeros((capacity,), np.int32)
        self._index_frame = np.zeros((capacity,), np.int32)
        self._scene_id = np.zeros((capacity,), np.int32)
        self._size = 0
        self._head = 0          # physical position of the oldest row
        self._rng = np.random.default_rng(seed)
        self._emb_dev: Optional[jnp.ndarray] = None
        self._members_dev: Optional[jnp.ndarray] = None
        self._member_count_dev: Optional[jnp.ndarray] = None
        self._index_frame_dev: Optional[jnp.ndarray] = None
        # version of the cached arena-row views (arena appends donate the
        # super-buffers, so row views must be re-sliced after inserts)
        self._emb_row_ver = -1
        self._members_row_ver = -1
        self._if_row_ver = -1
        self.version = 0               # bumped per insert (stack caching)
        self.io_stats = {"full_uploads": 0, "appended_rows": 0,
                         "member_uploads": 0, "appended_member_rows": 0,
                         "index_frame_uploads": 0,
                         "appended_index_frame_rows": 0,
                         "scans": 0, "host_expand_gathers": 0,
                         "device_expand_gathers": 0,
                         "evicted_rows": 0, "reservoir_merges": 0,
                         "consolidated_rows": 0}

    def reset_io_stats(self) -> None:
        """Zero the transfer/scan counters in place (the dict identity is
        preserved, so held references keep observing the live counters).
        Benchmarks and tests use this to assert per-phase counts without
        rebuilding the memory."""
        for k in self.io_stats:
            self.io_stats[k] = 0

    # ------------------------------------------------------------- ingestion
    def insert_cluster(self, embedding: np.ndarray, *, scene_id: int,
                       index_frame: int, member_frames: Sequence[int]
                       ) -> int:
        """Insert one indexed vector linked to its cluster members."""
        return int(self.insert_batch(
            np.asarray(embedding, np.float32)[None],
            scene_ids=[scene_id], index_frames=[index_frame],
            member_lists=[member_frames])[0])

    def insert_batch(self, embeddings: np.ndarray, *,
                     scene_ids: Sequence[int],
                     index_frames: Sequence[int],
                     member_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """Insert a batch of indexed vectors in one shot.

        Host mirrors are written vectorised; if the device copy exists
        it is extended in place (no cache invalidation / full
        re-upload). When the batch would overflow ``capacity`` the
        eviction policy decides: ``none`` raises (the historical
        contract), the window policies advance ``head`` over exactly
        as many oldest rows as the batch needs — O(1) pointer motion —
        and the new rows overwrite the evicted physical positions (a
        ring write, split into at most two contiguous runs at the wrap
        point). Returns the physical slots the rows landed in.
        """
        embeddings = np.asarray(embeddings, np.float32)
        n = embeddings.shape[0]
        assert n == len(scene_ids) == len(index_frames) == len(member_lists)
        if n > self.capacity:
            if self.eviction.name == "none":
                raise RuntimeError("memory capacity exhausted")
            # window policies: the batch alone overflows — only its
            # newest `capacity` rows can survive, so the older ones are
            # evicted on arrival (counted like any other eviction; they
            # never reach a reservoir, so cluster_merge cannot fold
            # them either)
            drop = n - self.capacity
            embeddings = embeddings[drop:]
            scene_ids = list(scene_ids)[drop:]
            index_frames = list(index_frames)[drop:]
            member_lists = list(member_lists)[drop:]
            self.io_stats["evicted_rows"] += drop
            n = self.capacity
        overflow = self._size + n - self.capacity
        if overflow > 0:
            self.eviction.evict(self, overflow)   # raises for "none"
        tail = (self._head + self._size) % self.capacity
        ids = np.asarray(index_frames, np.int32)
        scn = np.asarray(scene_ids, np.int32)
        run1 = min(n, self.capacity - tail)
        runs = [(tail, 0, run1)]
        if run1 < n:                               # wrapped ring write
            runs.append((0, run1, n - run1))
        for pos, off, cnt in runs:
            self._emb[pos:pos + cnt] = embeddings[off:off + cnt]
            self._index_frame[pos:pos + cnt] = ids[off:off + cnt]
            self._scene_id[pos:pos + cnt] = scn[off:off + cnt]
        for j, member_frames in enumerate(member_lists):
            members = np.asarray(member_frames, np.int32)
            m = len(members)
            if m > self.member_cap:            # uniform reservoir
                keep = self._rng.choice(m, self.member_cap, replace=False)
                members = members[np.sort(keep)]
                m = self.member_cap
            pj = (tail + j) % self.capacity
            self._members[pj, :m] = members
            self._members[pj, m:] = 0      # no stale ids past the count
            self._member_count[pj] = m
        self._size += n
        self.version += 1
        self._sync_device(runs)
        if self.n_coarse:
            for pos, _off, cnt in runs:
                self._mark_blocks_dirty(pos, cnt)
            self._refresh_block_summaries()
        return (tail + np.arange(n)) % self.capacity

    def _advance_head(self, need: int) -> None:
        """Sliding-window eviction: drop the ``need`` oldest rows by
        moving the window start — the physical rows stay in place
        (masked invalid by the new window) until the incoming write
        overwrites them, so evicting moves zero bytes."""
        assert 0 <= need <= self._size, (need, self._size)
        if self.n_coarse and need:
            run1 = min(need, self.capacity - self._head)
            self._mark_blocks_dirty(self._head, run1)
            if run1 < need:
                self._mark_blocks_dirty(0, need - run1)
        self._head = (self._head + need) % self.capacity
        self._size -= need
        self.io_stats["evicted_rows"] += need

    # ------------------------------------------------- coarse consolidation
    def _mark_blocks_dirty(self, pos: int, cnt: int) -> None:
        """Fine physical rows ``[pos, pos+cnt)`` changed validity or
        contents — their block summaries must be recomputed."""
        if cnt <= 0:
            return
        lo = pos // self.coarse_block
        hi = (pos + cnt - 1) // self.coarse_block
        self._dirty_blocks.update(range(lo, hi + 1))

    def _refresh_block_summaries(self) -> None:
        """Recompute the summary row (centroid over currently-valid
        fine rows) of every dirty block and push it to the arena's
        coarse tier, riding the tick's deferred scatter. Block
        summaries carry NO reservoir: a stage-1 win on a block expands
        into the block's own fine rows, which carry theirs."""
        if self.arena is None or not self._dirty_blocks:
            self._dirty_blocks.clear()
            return
        cap, blk = self.capacity, self.coarse_block
        idx = np.arange(cap)
        live = ((idx - self._head) % cap) < self._size
        k = self.member_cap
        for b in sorted(self._dirty_blocks):
            rows = slice(b * blk, min((b + 1) * blk, cap))
            v = live[rows]
            any_v = bool(v.any())
            if any_v:
                cen = self._emb[rows][v].mean(0, dtype=np.float64)
                ifr = int(self._index_frame[rows][v][0])
            else:
                cen = np.zeros((self.dim,), np.float64)
                ifr = 0
            self.arena.append_coarse(
                self.slot, b, cen.astype(np.float32)[None],
                np.zeros((1, k), np.int32), np.zeros((1,), np.int32),
                np.asarray([ifr], np.int32), np.asarray([any_v]))
        self._dirty_blocks.clear()

    def _consolidate(self, need: int, threshold: float) -> None:
        """Fold the ``need`` oldest rows into the consolidated region of
        the coarse tier before they leave the fine window: running
        count-weighted centroid, merged member reservoir (evictee's
        index_frame + members, up to ``member_cap``), widened frame
        window. Fold target: the most similar existing summary when its
        cosine clears ``threshold``, a fresh summary row while the
        region has space, else the most similar row unconditionally (a
        full tier degrades to coarser summaries, never to data loss)."""
        if self.n_coarse == 0:
            raise RuntimeError(
                "eviction='consolidate' needs coarse_capacity > 0 "
                "(VenusConfig(coarse_capacity=...))")
        need = min(need, self._size)
        if need <= 0:
            return
        phys = (self._head + np.arange(need)) % self.capacity
        touched = set()
        for pe in phys:
            e = self._emb[pe].astype(np.float64)
            cs = self._coarse_csize
            best, best_sim = -1, -np.inf
            if cs:
                en = e / (np.linalg.norm(e) + 1e-12)
                c = self._coarse_emb[:cs].astype(np.float64)
                cn = c / (np.linalg.norm(c, axis=-1, keepdims=True)
                          + 1e-12)
                best = int(np.argmax(cn @ en))
                best_sim = float(cn[best] @ en)
            cnt_e = int(self._member_count[pe])
            fids = np.concatenate(
                [[int(self._index_frame[pe])],
                 self._members[pe, :cnt_e].astype(np.int64)])
            if best >= 0 and (best_sim >= threshold
                              or cs >= self.coarse_capacity):
                r, w = best, int(self._coarse_weight[best])
                self._coarse_emb[r] = (
                    (self._coarse_emb[r].astype(np.float64) * w + e)
                    / (w + 1)).astype(np.float32)
                self._coarse_weight[r] = w + 1
                ct = int(self._coarse_count[r])
                take = min(len(fids), self.member_cap - ct)
                if take > 0:
                    self._coarse_members[r, ct:ct + take] = fids[:take]
                    self._coarse_count[r] = ct + take
                self._coarse_fid_lo[r] = min(int(self._coarse_fid_lo[r]),
                                             int(fids.min()))
                self._coarse_fid_hi[r] = max(int(self._coarse_fid_hi[r]),
                                             int(fids.max()))
            else:
                r = cs
                self._coarse_csize = cs + 1
                self._coarse_emb[r] = e.astype(np.float32)
                self._coarse_weight[r] = 1
                m = min(len(fids), self.member_cap)
                self._coarse_members[r, :m] = fids[:m]
                self._coarse_members[r, m:] = 0
                self._coarse_count[r] = m
                self._coarse_ifr[r] = int(self._index_frame[pe])
                self._coarse_fid_lo[r] = int(fids.min())
                self._coarse_fid_hi[r] = int(fids.max())
            touched.add(r)
        self.io_stats["consolidated_rows"] += int(need)
        for r in sorted(touched):
            self._resync_coarse(r)

    def _resync_coarse(self, row: int) -> None:
        """Push one consolidated summary row to the arena's coarse tier
        (position offset past the block-summary region)."""
        if self.arena is None:
            return
        self.arena.append_coarse(
            self.slot, self.n_blocks + row,
            self._coarse_emb[row:row + 1],
            self._coarse_members[row:row + 1],
            self._coarse_count[row:row + 1],
            self._coarse_ifr[row:row + 1],
            np.asarray([True]))

    def _merge_into_survivors(self, need: int, threshold: float) -> None:
        """Cluster-merge-aware eviction: before the ``need`` oldest rows
        leave the window, fold each one's member reservoir into its most
        similar SURVIVING index row (cosine ≥ threshold) with spare
        reservoir space, so the merged cluster keeps answering for the
        evicted frames. Host-mirror merge + one re-synced device row per
        modified survivor (coalesced per target)."""
        if need >= self._size:
            return
        cap = self.capacity
        phys = (self._head + np.arange(self._size)) % cap
        ev_phys, sv_phys = phys[:need], phys[need:]

        def _norm(rows):
            return rows / (np.linalg.norm(rows, axis=-1, keepdims=True)
                           + 1e-12)

        sims = _norm(self._emb[ev_phys]) @ _norm(self._emb[sv_phys]).T
        touched = set()
        for i, pe in enumerate(ev_phys):
            j = int(np.argmax(sims[i]))
            if sims[i, j] < threshold:
                continue
            pt = int(sv_phys[j])
            cnt_e = int(self._member_count[pe])
            take = min(cnt_e, self.member_cap
                       - int(self._member_count[pt]))
            if take <= 0:
                continue
            ct = int(self._member_count[pt])
            self._members[pt, ct:ct + take] = self._members[pe, :take]
            self._member_count[pt] = ct + take
            self.io_stats["reservoir_merges"] += 1
            touched.add(pt)
        for pt in sorted(touched):
            self._resync_row(pt)

    def _resync_row(self, pos: int) -> None:
        """Push one already-resident row (reservoir merge) back to the
        device copy through the same append paths inserts use."""
        if self.arena is not None:
            self.arena.append(
                self.slot, pos, self._emb[pos:pos + 1],
                self._members[pos:pos + 1],
                self._member_count[pos:pos + 1],
                self._index_frame[pos:pos + 1], self.window)
            return
        if not self.incremental:
            return              # the insert's sync drops the caches anyway
        if self._members_dev is not None:
            self._members_dev, self._member_count_dev = _append_member_rows(
                self._members_dev, self._member_count_dev,
                jnp.asarray(self._members[pos:pos + 1]),
                jnp.asarray(self._member_count[pos:pos + 1]),
                jnp.asarray(pos, jnp.int32))
            self.io_stats["appended_member_rows"] += 1

    def _sync_device(self, runs) -> None:
        """Push freshly written host-mirror runs to the device copy.
        ``runs`` is a list of contiguous ``(pos, off, cnt)`` physical
        row runs (two when a ring write wraps)."""
        if not self.incremental:
            self._emb_dev = None         # seed behaviour: full re-upload
            self._members_dev = None
            self._member_count_dev = None
            self._index_frame_dev = None
            return
        if self.arena is not None:
            # arena-backed: the rows are resident from this point on, no
            # lazy upload ever happens (full_uploads stays 0). Inside a
            # tick's deferred window the arena fuses every session's
            # blocks into one donated scatter per super-buffer.
            for pos, _off, cnt in runs:
                moved = self.arena.append(
                    self.slot, pos, self._emb[pos:pos + cnt],
                    self._members[pos:pos + cnt],
                    self._member_count[pos:pos + cnt],
                    self._index_frame[pos:pos + cnt], self.window)
                self.io_stats["appended_rows"] += moved
                self.io_stats["appended_member_rows"] += moved
                self.io_stats["appended_index_frame_rows"] += moved
            return
        # bucketed padding past the run is only safe while the memory is
        # a plain append-only prefix (head == 0: padded rows land past
        # the valid window, stay masked, and later appends overwrite
        # them before they can become valid — eviction only ever shrinks
        # validity from the head side); once the ring has wrapped
        # (head != 0), "past the run" can hold live rows — append
        # exactly
        plain = self._head == 0
        for pos, _off, cnt in runs:
            b = (min(pow2_bucket(cnt, lo=8), self.capacity - pos)
                 if plain else cnt)
            if self._emb_dev is not None:  # lazy: first query uploads once
                rows = np.zeros((b, self.dim), np.float32)
                rows[:cnt] = self._emb[pos:pos + cnt]
                if self.index_dtype == "int8":
                    rows = quantise_rows(rows)[0]
                self._emb_dev = _append_rows(self._emb_dev,
                                             jnp.asarray(rows),
                                             jnp.asarray(pos, jnp.int32))
                self.io_stats["appended_rows"] += b
            if self._members_dev is not None:
                rows = np.zeros((b, self.member_cap), np.int32)
                rows[:cnt] = self._members[pos:pos + cnt]
                cnts = np.zeros((b,), np.int32)
                cnts[:cnt] = self._member_count[pos:pos + cnt]
                (self._members_dev,
                 self._member_count_dev) = _append_member_rows(
                    self._members_dev, self._member_count_dev,
                    jnp.asarray(rows), jnp.asarray(cnts),
                    jnp.asarray(pos, jnp.int32))
                self.io_stats["appended_member_rows"] += b
            if self._index_frame_dev is not None:
                rows = np.zeros((b,), np.int32)
                rows[:cnt] = self._index_frame[pos:pos + cnt]
                self._index_frame_dev = _append_id_rows(
                    self._index_frame_dev, jnp.asarray(rows),
                    jnp.asarray(pos, jnp.int32))
                self.io_stats["appended_index_frame_rows"] += b

    # ----------------------------------------------------------------- query
    @property
    def size(self) -> int:
        return self._size

    @property
    def head(self) -> int:
        """Physical position of the oldest (logical-0) valid row."""
        return self._head

    @property
    def window(self) -> Tuple[int, int]:
        """The ``(head, size)`` ring window every valid mask derives
        from; ``(0, size)`` until the first eviction."""
        return self._head, self._size

    def min_live_frame(self) -> int:
        """Smallest absolute frame id any LIVE row still references —
        the archive-trim horizon for this memory: index_frame ids and
        the count-masked member reservoirs of every row inside the
        current ring window. Reservoirs are consulted FIRST-CLASS, so
        cluster_merge's folded members keep their raw frames reachable
        (and untrimmed) long after their own index row left the window.
        Consolidated summary rows count as live references too: their
        merged reservoirs are what a two-stage query expands, so their
        frame windows pin the archive exactly like fine reservoirs do.
        An empty memory returns int64-max: it constrains nothing."""
        lo = int(np.iinfo(np.int64).max)
        if self._size:
            phys = (self._head + np.arange(self._size)) % self.capacity
            lo = int(self._index_frame[phys].min())
            cnt = self._member_count[phys]
            live = np.arange(self.member_cap)[None, :] < cnt[:, None]
            if live.any():
                lo = min(lo, int(self._members[phys][live].min()))
        if self.n_coarse and self._coarse_csize:
            lo = min(lo, int(self._coarse_fid_lo[:self._coarse_csize]
                             .min()))
        return lo

    def detach_from_arena(self) -> None:
        """Sever this memory from its (about to be recycled) arena
        slot. Every previously returned device handle is stale the
        moment the slot is released, so the cached row views are
        dropped; the memory falls back to the detached lazy-upload
        contract over its host mirrors (which it owns and which stay
        correct across the detach)."""
        self.arena = None
        self.slot = None
        self._emb_dev = None
        self._members_dev = None
        self._member_count_dev = None
        self._index_frame_dev = None
        self._emb_row_ver = self._members_row_ver = self._if_row_ver = -1

    def device_index(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(embeddings (cap, d), valid (cap,)) as device arrays.

        Arena-backed: the rows already live on device inside the arena —
        this returns a per-version cached slice of the super-buffer
        (nothing uploads, ``full_uploads`` stays 0). Detached: first call
        uploads the packed host array once; subsequent inserts keep the
        device copy current via ``_append_rows``. NOTE: inserts DONATE
        the current buffer to the in-place append, so a handle returned
        here is invalidated by the next insert — re-call this method
        after inserting rather than holding the arrays."""
        if self.arena is not None:
            # keyed on the ARENA version: appends land at tick-flush
            # time, so that is when row views must refresh
            if (self._emb_dev is None
                    or self._emb_row_ver != self.arena.version):
                self._emb_dev = self.arena.emb[self.slot]
                self._emb_row_ver = self.arena.version
        elif self._emb_dev is None:
            self._emb_dev = jnp.asarray(
                quantise_rows(self._emb)[0]
                if self.index_dtype == "int8" else self._emb)
            self.io_stats["full_uploads"] += 1
        return self._emb_dev, _ring_valid_mask(
            jnp.asarray(self._head, jnp.int32),
            jnp.asarray(self._size, jnp.int32), capacity=self.capacity)

    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (Q,d) -> (sims (Q,cap), probs (Q,cap)) — Eq. 4+5."""
        emb, valid = self.device_index()
        self.io_stats["scans"] += 1
        return kops.similarity(query_emb, emb, tau=tau, valid=valid)

    # ------------------------------------------------- cluster-level expand
    def members_table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self._members), jnp.asarray(self._member_count)

    def device_members(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(members (cap, member_cap), counts (cap,)) device-resident.

        Same contract as ``device_index``: arena rows are sliced from the
        super-buffers (no upload); detached buffers upload once on first
        call, then appends keep them current in place (and DONATE the
        buffers, so re-call after inserting rather than holding)."""
        if self.arena is not None:
            if (self._members_dev is None
                    or self._members_row_ver != self.arena.version):
                self._members_dev = self.arena.members[self.slot]
                self._member_count_dev = self.arena.member_count[self.slot]
                self._members_row_ver = self.arena.version
        elif self._members_dev is None:
            self._members_dev = jnp.asarray(self._members)
            self._member_count_dev = jnp.asarray(self._member_count)
            self.io_stats["member_uploads"] += 1
        return self._members_dev, self._member_count_dev

    def device_index_frames(self) -> jnp.ndarray:
        """index_frame ids (cap,) device-resident — the centroid frame id
        of each memory slot, for strategies whose draws map straight to
        indexed frames (top-k / BOLT / MDF / AKS) rather than through the
        member reservoirs. Same contract as ``device_index``: arena rows
        are sliced from the super-buffer (no upload); detached buffers
        upload once, then append in place (donated)."""
        if self.arena is not None:
            if (self._index_frame_dev is None
                    or self._if_row_ver != self.arena.version):
                self._index_frame_dev = self.arena.index_frame[self.slot]
                self._if_row_ver = self.arena.version
        elif self._index_frame_dev is None:
            self._index_frame_dev = jnp.asarray(self._index_frame)
            self.io_stats["index_frame_uploads"] += 1
        return self._index_frame_dev

    @staticmethod
    def expand_u(seed: int, size) -> np.ndarray:
        """The per-slot pick variates u ∈ [0, 2^U_BITS): one int per draw
        slot, a function of (seed, slot) only — every expansion path
        (loop / vectorised / batched / device) consumes this sequence."""
        return np.random.default_rng(seed).integers(
            0, _U_CARD, size=size, dtype=np.int64)

    def expand_draws(self, draws: np.ndarray, valid: np.ndarray,
                     seed: int = 0) -> np.ndarray:
        """Map index draws to frame ids: each draw of index i samples one
        member uniformly from cluster c(oᵢ) (paper §IV-D1). Vectorised
        fixed-shape gather over the members table — one uniform variate
        is consumed per slot (valid or not) so batched and sequential
        paths agree. Returns the deduplicated, time-ordered frame ids."""
        draws = np.atleast_1d(np.asarray(draws))
        valid = np.atleast_1d(np.asarray(valid, bool))
        u = self.expand_u(seed, draws.shape)
        return self._expand_u(draws, valid, u)

    def expand_draws_batch(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> List[np.ndarray]:
        """Batched expansion: draws/valid (Q, n). Each row consumes the
        same per-slot variate sequence as a sequential ``expand_draws``
        call with the same seed, so results match query-for-query."""
        draws = np.asarray(draws)
        valid = np.asarray(valid, bool)
        q, n = draws.shape
        u = np.broadcast_to(self.expand_u(seed, n), (q, n))
        fids, ok = self._expand_u(draws, valid, u, dedup=False)
        return [np.unique(fids[i][ok[i]]) for i in range(q)]

    def expand_draws_device(self, draws: np.ndarray, valid: np.ndarray,
                            seed: int = 0) -> np.ndarray:
        """``expand_draws`` with the reservoir gather on device: a jit'd
        fixed-shape lookup over ``device_members()`` — no host-side
        members-table access; only the (n,) frame ids transfer back."""
        draws = np.atleast_1d(np.asarray(draws, np.int32))
        valid = np.atleast_1d(np.asarray(valid, bool))
        members, counts = self.device_members()
        u = self.expand_u(seed, draws.shape)
        fids, ok = expand_gather(members, counts, jnp.asarray(draws),
                                  jnp.asarray(valid),
                                  jnp.asarray(u, jnp.int32))
        self.io_stats["device_expand_gathers"] += 1
        fids, ok = np.asarray(fids), np.asarray(ok)
        return np.unique(fids[ok].astype(np.int64))

    def _expand_u(self, draws, valid, u, dedup: bool = True):
        self.io_stats["host_expand_gathers"] += 1
        safe = np.clip(draws, 0, self.capacity - 1)
        cnt = self._member_count[safe].astype(np.int64)
        pick = (np.asarray(u, np.int64) * cnt) >> U_BITS
        fids = self._members[safe, pick].astype(np.int64)
        ok = valid & (cnt > 0) & (draws >= 0)
        if dedup:
            return np.unique(fids[ok])
        return fids, ok

    def _expand_draws_loop(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> np.ndarray:
        """Seed-style per-draw loop over the same sampling scheme —
        reference for the vectorised path (kept for tests/benches)."""
        rng = np.random.default_rng(seed)
        out = []
        for i, ok in zip(np.asarray(draws), np.asarray(valid)):
            u = int(rng.integers(0, _U_CARD, dtype=np.int64))
            if not ok or i < 0:
                continue
            cnt = int(self._member_count[int(i)])
            if cnt == 0:
                continue
            out.append(int(self._members[int(i), (u * cnt) >> U_BITS]))
        return np.unique(np.asarray(out, np.int64))

    def index_frames(self, idx: Sequence[int]) -> np.ndarray:
        return self._index_frame[np.asarray(idx, np.int64)]


# ---------------------------------------------------------------------------
# Cross-session stacked view
# ---------------------------------------------------------------------------


class MemoryStack:
    """Padded-stack view over S same-shape ``VenusMemory`` instances.

    Exposes the sessions' device-resident buffers as ``(S, capacity, …)``
    stacks for the fused cross-session query path. Two regimes:

    * **Arena-backed** (the session manager's default): when every
      member memory lives in one ``MemoryArena`` and together they cover
      it exactly (slots 0..S-1 in order), the views ARE the arena
      super-buffers — appends already landed in place, so no
      ingest↔query interleaving ever rebuilds anything and
      ``search`` passes the arena's (S,) sizes straight to the kernel
      wrapper, which derives the valid masks on device.
    * **Detached fallback**: device-side ``jnp.stack`` of the per-memory
      buffers, cached against the members' insert versions — rebuilt
      when any version changes (the PR-2 behaviour). Each rebuild bumps
      ``io_stats`` and, when provided, ``rebuild_stats["stack_rebuilds"]``
      (the session manager passes its own counter dict here so the
      zero-restack invariant is assertable at the manager level).
    """

    def __init__(self, memories: Sequence[VenusMemory], *,
                 rebuild_stats: Optional[dict] = None):
        memories = list(memories)
        assert memories, "empty stack"
        cap, dim, mcap = (memories[0].capacity, memories[0].dim,
                          memories[0].member_cap)
        for m in memories:
            assert (m.capacity, m.dim, m.member_cap) == (cap, dim, mcap), \
                "stacked memories must share capacity/dim/member_cap"
            assert m.index_dtype == memories[0].index_dtype, \
                "stacked memories must share index_dtype"
        self.memories = memories
        self.capacity, self.dim, self.member_cap = cap, dim, mcap
        self.rebuild_stats = rebuild_stats
        arena = getattr(memories[0], "arena", None)
        self._arena: Optional[MemoryArena] = None
        if (arena is not None
                and all(m.arena is arena for m in memories)
                and [m.slot for m in memories] == list(range(len(memories)))):
            self._arena = arena
        self._emb_stack: Optional[jnp.ndarray] = None
        self._valid: Optional[jnp.ndarray] = None
        self._members_stack: Optional[jnp.ndarray] = None
        self._counts_stack: Optional[jnp.ndarray] = None
        self._index_frame_stack: Optional[jnp.ndarray] = None
        self._emb_versions: Optional[Tuple[int, ...]] = None
        self._mem_versions: Optional[Tuple[int, ...]] = None
        self._if_versions: Optional[Tuple[int, ...]] = None
        self.io_stats = {"stack_builds": 0, "member_stack_builds": 0,
                         "index_frame_stack_builds": 0}

    def __len__(self) -> int:
        return len(self.memories)

    def _versions(self) -> Tuple[int, ...]:
        return tuple(m.version for m in self.memories)

    def arena_view(self) -> Optional[MemoryArena]:
        """The arena, iff this stack still covers it exactly (a session
        added to the arena after this stack was built voids coverage —
        the stack then falls back to the detached view path)."""
        a = self._arena
        if a is not None and len(self.memories) == a.n_sessions:
            return a
        return None

    def _count_rebuild(self) -> None:
        if self.rebuild_stats is not None:
            self.rebuild_stats["stack_rebuilds"] = \
                self.rebuild_stats.get("stack_rebuilds", 0) + 1

    # ----------------------------------------------------------- device views
    def device_stack(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(emb (S, cap, d), valid (S, cap)) device arrays."""
        a = self.arena_view()
        if a is not None:
            return a.emb, a.device_valid()
        vers = self._versions()
        if self._emb_stack is None or vers != self._emb_versions:
            self._emb_stack = jnp.stack(
                [m.device_index()[0] for m in self.memories])
            # windows only change with a version bump, so the valid mask
            # is cached alongside — queries between ticks transfer nothing
            wins = jnp.asarray([m.window for m in self.memories],
                               jnp.int32)
            self._valid = _window_valid_stack(wins, capacity=self.capacity)
            self._emb_versions = vers
            self.io_stats["stack_builds"] += 1
            self._count_rebuild()
        return self._emb_stack, self._valid

    def device_members(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(members (S, cap, member_cap), counts (S, cap)) device arrays."""
        a = self.arena_view()
        if a is not None:
            return a.members, a.member_count
        vers = self._versions()
        if self._members_stack is None or vers != self._mem_versions:
            tabs = [m.device_members() for m in self.memories]
            self._members_stack = jnp.stack([t[0] for t in tabs])
            self._counts_stack = jnp.stack([t[1] for t in tabs])
            self._mem_versions = vers
            self.io_stats["member_stack_builds"] += 1
            self._count_rebuild()
        return self._members_stack, self._counts_stack

    def device_index_frames(self) -> jnp.ndarray:
        """index_frame ids (S, cap) device arrays (cached per version)."""
        a = self.arena_view()
        if a is not None:
            return a.index_frame
        vers = self._versions()
        if self._index_frame_stack is None or vers != self._if_versions:
            self._index_frame_stack = jnp.stack(
                [m.device_index_frames() for m in self.memories])
            self._if_versions = vers
            self.io_stats["index_frame_stack_builds"] += 1
            self._count_rebuild()
        return self._index_frame_stack

    # ----------------------------------------------------------------- query
    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (S, Q, d) -> (sims, probs) (S, Q, cap) — every
        session scanned by ONE fused kernel launch. Arena-backed stacks
        pass the (S, 2) ring windows as ``valid`` — the mask
        materialises on device inside the kernel wrapper."""
        a = self.arena_view()
        if a is not None:
            return kops.similarity_stack(query_emb, a.emb, tau=tau,
                                         valid=a.device_windows(),
                                         mesh=a.mesh, mesh_axis=a.mesh_axis)
        emb, valid = self.device_stack()
        return kops.similarity_stack(query_emb, emb, tau=tau, valid=valid)

    def fused_retrieve(self, query_emb: jnp.ndarray, targets: jnp.ndarray,
                       *, tau: float, n_topk: int) -> "kops.FusedRetrieval":
        """``search``'s one-launch sibling: the same scan operand (arena
        super-buffers or the cached stack) but the draws/top-k resolve
        inside the launch — no (S, Q, cap) score tensor is returned (or,
        on the Pallas backend, ever materialised)."""
        a = self.arena_view()
        if a is not None:
            return kops.fused_retrieve_stack(
                query_emb, a.emb, tau=tau, valid=a.device_windows(),
                targets=targets, n_topk=n_topk,
                mesh=a.mesh, mesh_axis=a.mesh_axis)
        emb, valid = self.device_stack()
        return kops.fused_retrieve_stack(query_emb, emb, tau=tau,
                                         valid=valid, targets=targets,
                                         n_topk=n_topk)


class ArenaStackView:
    """The arena AS the stacked-scan operand: a ``MemoryStack``-shaped
    facade whose lanes are arena SLOTS, not live sessions.

    The session manager hands this to the plan executor whenever a slot
    is free (a closed session awaiting reuse): free slots are padding
    lanes — their windows read ``(0, 0)``, so the device-derived masks
    blank them, and per-lane math keeps every occupied lane
    bit-identical to a subset scan. Nothing is ever built or copied
    here; every view IS an arena super-buffer, so ``stack_builds`` is
    structurally zero."""

    def __init__(self, arena: MemoryArena):
        self.arena = arena
        self.capacity = arena.capacity
        self.dim = arena.dim
        self.member_cap = arena.member_cap
        self.io_stats = {"stack_builds": 0, "member_stack_builds": 0,
                         "index_frame_stack_builds": 0}

    def __len__(self) -> int:
        return self.arena.n_sessions

    def arena_view(self) -> MemoryArena:
        return self.arena

    def device_stack(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.arena.emb, self.arena.device_valid()

    def device_members(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.arena.members, self.arena.member_count

    def device_index_frames(self) -> jnp.ndarray:
        return self.arena.index_frame

    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        a = self.arena
        return kops.similarity_stack(query_emb, a.emb, tau=tau,
                                     valid=a.device_windows(),
                                     mesh=a.mesh, mesh_axis=a.mesh_axis)

    def fused_retrieve(self, query_emb: jnp.ndarray, targets: jnp.ndarray,
                       *, tau: float, n_topk: int) -> "kops.FusedRetrieval":
        a = self.arena
        return kops.fused_retrieve_stack(
            query_emb, a.emb, tau=tau, valid=a.device_windows(),
            targets=targets, n_topk=n_topk,
            mesh=a.mesh, mesh_axis=a.mesh_axis)
