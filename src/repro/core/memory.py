"""Hierarchical memory (paper §IV-C): index layer over a raw data layer.

* **Raw data layer** — every captured frame, archived as-is. Here it is a
  ``FrameStore`` holding frames by absolute index (the paper's NVMe
  archive); reasoning-time expansion pulls raw frames from it.
* **Index data layer** — one vector per *indexed frame* (cluster
  centroid), stored in a fixed-capacity packed array that is directly
  shardable over the ``model`` mesh axis (DESIGN.md: brute-force MXU
  similarity replaces FAISS ANN on TPU). Each indexed vector is linked to
  its scene cluster via a bounded **member reservoir** — up to
  ``member_cap`` member frame ids kept uniformly at random, so
  "uniformly sample n(oᵢ) frames from cluster c(oᵢ)" (§IV-D1) stays a
  fixed-shape gather.

The index is **device-resident and incrementally updated**: the first
query uploads the packed array once; afterwards batched inserts append
rows in place with a jit'd ``dynamic_update_slice`` (bucketed batch
sizes bound the jit cache), so a post-ingest query never re-transfers
the whole ``(capacity, dim)`` buffer. ``io_stats`` counts full uploads
vs appended rows so tests/benches can assert the transfer behaviour.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class FrameStore:
    """Raw data layer: append-only archive of frames by absolute index."""

    def __init__(self):
        self._frames: List[np.ndarray] = []

    def append(self, frames: np.ndarray) -> None:
        for f in np.asarray(frames):
            self._frames.append(f)

    def __len__(self) -> int:
        return len(self._frames)

    def get(self, idx: Sequence[int]) -> np.ndarray:
        return np.stack([self._frames[int(i)] for i in idx])


@dataclass
class IndexEntry:
    scene_id: int
    cluster_id: int
    ts: int                      # timestamp (frame index) of indexed frame


@functools.partial(jax.jit, static_argnames=("capacity",))
def _valid_mask(size: jnp.ndarray, *, capacity: int) -> jnp.ndarray:
    return jnp.arange(capacity) < size


@functools.partial(jax.jit, donate_argnums=(0,))
def _append_rows(emb: jnp.ndarray, rows: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Append a row block at ``pos``. The index buffer is donated, so
    XLA updates it in place — O(rows) bytes moved, not O(capacity)."""
    return jax.lax.dynamic_update_slice(emb, rows, (pos, 0))


from repro.util import pow2_bucket


class VenusMemory:
    """Index layer: packed vector store + cluster member reservoirs."""

    def __init__(self, capacity: int, dim: int, member_cap: int = 128,
                 seed: int = 0, *, incremental: bool = True):
        self.capacity = capacity
        self.dim = dim
        self.member_cap = member_cap
        self.incremental = incremental
        self._emb = np.zeros((capacity, dim), np.float32)
        self._members = np.zeros((capacity, member_cap), np.int32)
        self._member_count = np.zeros((capacity,), np.int32)
        self._index_frame = np.zeros((capacity,), np.int32)
        self._scene_id = np.zeros((capacity,), np.int32)
        self._size = 0
        self._rng = np.random.default_rng(seed)
        self._emb_dev: Optional[jnp.ndarray] = None
        self.io_stats = {"full_uploads": 0, "appended_rows": 0}

    # ------------------------------------------------------------- ingestion
    def insert_cluster(self, embedding: np.ndarray, *, scene_id: int,
                       index_frame: int, member_frames: Sequence[int]
                       ) -> int:
        """Insert one indexed vector linked to its cluster members."""
        return int(self.insert_batch(
            np.asarray(embedding, np.float32)[None],
            scene_ids=[scene_id], index_frames=[index_frame],
            member_lists=[member_frames])[0])

    def insert_batch(self, embeddings: np.ndarray, *,
                     scene_ids: Sequence[int],
                     index_frames: Sequence[int],
                     member_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """Insert a batch of indexed vectors in one shot.

        Host mirrors are written vectorised; if the device copy exists it
        is extended in place with a single jit'd row-block append (no
        cache invalidation / full re-upload).
        """
        embeddings = np.asarray(embeddings, np.float32)
        n = embeddings.shape[0]
        assert n == len(scene_ids) == len(index_frames) == len(member_lists)
        if self._size + n > self.capacity:
            raise RuntimeError("memory capacity exhausted")
        lo = self._size
        self._emb[lo:lo + n] = embeddings
        self._index_frame[lo:lo + n] = np.asarray(index_frames, np.int32)
        self._scene_id[lo:lo + n] = np.asarray(scene_ids, np.int32)
        for j, member_frames in enumerate(member_lists):
            members = np.asarray(member_frames, np.int32)
            m = len(members)
            if m > self.member_cap:            # uniform reservoir
                keep = self._rng.choice(m, self.member_cap, replace=False)
                members = members[np.sort(keep)]
                m = self.member_cap
            self._members[lo + j, :m] = members
            self._member_count[lo + j] = m
        self._size += n
        self._sync_device(lo, n)
        return np.arange(lo, lo + n)

    def _sync_device(self, lo: int, n: int) -> None:
        if self._emb_dev is None:
            return                       # lazy: first query uploads once
        if not self.incremental:
            self._emb_dev = None         # seed behaviour: full re-upload
            return
        # bucket the row count (bounds jit specialisations); padded rows
        # land past the valid region and are overwritten by later appends
        b = min(pow2_bucket(n, lo=8), self.capacity - lo)
        rows = np.zeros((b, self.dim), np.float32)
        rows[:n] = self._emb[lo:lo + n]
        self._emb_dev = _append_rows(self._emb_dev, jnp.asarray(rows),
                                     jnp.asarray(lo, jnp.int32))
        self.io_stats["appended_rows"] += b

    # ----------------------------------------------------------------- query
    @property
    def size(self) -> int:
        return self._size

    def device_index(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(embeddings (cap, d), valid (cap,)) as device arrays.

        First call uploads the packed host array once; subsequent inserts
        keep the device copy current via ``_append_rows``. NOTE: inserts
        DONATE the current buffer to the in-place append, so a handle
        returned here is invalidated by the next insert — re-call this
        method after inserting rather than holding the arrays."""
        if self._emb_dev is None:
            self._emb_dev = jnp.asarray(self._emb)
            self.io_stats["full_uploads"] += 1
        return self._emb_dev, _valid_mask(jnp.asarray(self._size, jnp.int32),
                                          capacity=self.capacity)

    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (Q,d) -> (sims (Q,cap), probs (Q,cap)) — Eq. 4+5."""
        emb, valid = self.device_index()
        return kops.similarity(query_emb, emb, tau=tau, valid=valid)

    # ------------------------------------------------- cluster-level expand
    def members_table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self._members), jnp.asarray(self._member_count)

    def expand_draws(self, draws: np.ndarray, valid: np.ndarray,
                     seed: int = 0) -> np.ndarray:
        """Map index draws to frame ids: each draw of index i samples one
        member uniformly from cluster c(oᵢ) (paper §IV-D1). Vectorised
        fixed-shape gather over the members table — one uniform variate
        is consumed per slot (valid or not) so batched and sequential
        paths agree. Returns the deduplicated, time-ordered frame ids."""
        draws = np.atleast_1d(np.asarray(draws))
        valid = np.atleast_1d(np.asarray(valid, bool))
        u = np.random.default_rng(seed).random(draws.shape)
        return self._expand_u(draws, valid, u)

    def expand_draws_batch(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> List[np.ndarray]:
        """Batched expansion: draws/valid (Q, n). Each row consumes the
        same per-slot variate sequence as a sequential ``expand_draws``
        call with the same seed, so results match query-for-query."""
        draws = np.asarray(draws)
        valid = np.asarray(valid, bool)
        q, n = draws.shape
        u = np.broadcast_to(np.random.default_rng(seed).random(n), (q, n))
        fids, ok = self._expand_u(draws, valid, u, dedup=False)
        return [np.unique(fids[i][ok[i]]) for i in range(q)]

    def _expand_u(self, draws, valid, u, dedup: bool = True):
        safe = np.clip(draws, 0, self.capacity - 1)
        cnt = self._member_count[safe]
        pick = np.minimum((u * cnt).astype(np.int64),
                          np.maximum(cnt - 1, 0))
        fids = self._members[safe, pick].astype(np.int64)
        ok = valid & (cnt > 0) & (draws >= 0)
        if dedup:
            return np.unique(fids[ok])
        return fids, ok

    def _expand_draws_loop(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> np.ndarray:
        """Seed-style per-draw loop over the same sampling scheme —
        reference for the vectorised path (kept for tests/benches)."""
        rng = np.random.default_rng(seed)
        out = []
        for i, ok in zip(np.asarray(draws), np.asarray(valid)):
            u = rng.random()
            if not ok or i < 0:
                continue
            cnt = int(self._member_count[int(i)])
            if cnt == 0:
                continue
            out.append(int(self._members[int(i), min(int(u * cnt),
                                                     cnt - 1)]))
        return np.unique(np.asarray(out, np.int64))

    def index_frames(self, idx: Sequence[int]) -> np.ndarray:
        return self._index_frame[np.asarray(idx, np.int64)]
