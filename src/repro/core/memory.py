"""Hierarchical memory (paper §IV-C): index layer over a raw data layer.

* **Raw data layer** — every captured frame, archived as-is. Here it is a
  ``FrameStore`` holding frames by absolute index (the paper's NVMe
  archive); reasoning-time expansion pulls raw frames from it.
* **Index data layer** — one vector per *indexed frame* (cluster
  centroid), stored in a fixed-capacity packed array that is directly
  shardable over the ``model`` mesh axis (DESIGN.md: brute-force MXU
  similarity replaces FAISS ANN on TPU). Each indexed vector is linked to
  its scene cluster via a bounded **member reservoir** — up to
  ``member_cap`` member frame ids kept uniformly at random, so
  "uniformly sample n(oᵢ) frames from cluster c(oᵢ)" (§IV-D1) stays a
  fixed-shape gather.

The index is **device-resident and incrementally updated**: the first
query uploads the packed array once; afterwards batched inserts append
rows in place with a jit'd ``dynamic_update_slice`` (bucketed batch
sizes bound the jit cache), so a post-ingest query never re-transfers
the whole ``(capacity, dim)`` buffer. The member reservoirs get the
same treatment (``device_members``), so reasoning-time expansion is a
jit'd on-device gather (``expand_draws_device``) instead of a host
lookup. ``io_stats`` counts full uploads vs appended rows (and host vs
device expansion gathers) so tests/benches can assert the transfer
behaviour.

``MemoryStack`` stacks several sessions' device buffers into
``(S, capacity, …)`` views for the cross-session fused query path: one
kernel launch scans every session, one jit'd gather expands every
session's draws. Stacks are cached against per-memory insert versions.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class FrameStore:
    """Raw data layer: append-only archive of frames by absolute index."""

    def __init__(self):
        self._frames: List[np.ndarray] = []

    def append(self, frames: np.ndarray) -> None:
        for f in np.asarray(frames):
            self._frames.append(f)

    def __len__(self) -> int:
        return len(self._frames)

    def get(self, idx: Sequence[int]) -> np.ndarray:
        return np.stack([self._frames[int(i)] for i in idx])


@dataclass
class IndexEntry:
    scene_id: int
    cluster_id: int
    ts: int                      # timestamp (frame index) of indexed frame


@functools.partial(jax.jit, static_argnames=("capacity",))
def _valid_mask(size: jnp.ndarray, *, capacity: int) -> jnp.ndarray:
    return jnp.arange(capacity) < size


@functools.partial(jax.jit, donate_argnums=(0,))
def _append_rows(emb: jnp.ndarray, rows: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Append a row block at ``pos``. The index buffer is donated, so
    XLA updates it in place — O(rows) bytes moved, not O(capacity)."""
    return jax.lax.dynamic_update_slice(emb, rows, (pos, 0))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append_member_rows(members: jnp.ndarray, counts: jnp.ndarray,
                        rows: jnp.ndarray, cnts: jnp.ndarray,
                        pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """In-place append of member-reservoir rows + their counts."""
    members = jax.lax.dynamic_update_slice(members, rows, (pos, 0))
    counts = jax.lax.dynamic_update_slice(counts, cnts, (pos,))
    return members, counts


@functools.partial(jax.jit, donate_argnums=(0,))
def _append_id_rows(buf: jnp.ndarray, rows: jnp.ndarray,
                    pos: jnp.ndarray) -> jnp.ndarray:
    """In-place append for 1-D id tables (index_frame)."""
    return jax.lax.dynamic_update_slice(buf, rows, (pos,))


# Uniform member pick: one variate per draw slot, represented as an
# integer u ∈ [0, 2^U_BITS) so host (int64) and device (int32) paths
# compute pick = (u * cnt) >> U_BITS *bit-identically* — no float
# rounding can make the two paths disagree at a floor boundary.
U_BITS = 20
_U_CARD = 1 << U_BITS


@jax.jit
def expand_gather(members: jnp.ndarray, counts: jnp.ndarray,
                  draws: jnp.ndarray, valid: jnp.ndarray,
                  u: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device reservoir gather: draws (..., n) index rows of the
    device-resident members table; u (n,) or (..., n) int32 variates pick
    one member per slot. Returns (frame ids (..., n), ok (..., n))."""
    cap = members.shape[0]
    safe = jnp.clip(draws, 0, cap - 1)
    cnt = counts[safe]                                    # (..., n)
    pick = (u.astype(jnp.int32) * cnt) >> U_BITS          # exact floor
    fids = jnp.take_along_axis(members[safe], pick[..., None], -1)[..., 0]
    ok = valid & (cnt > 0) & (draws >= 0)
    return fids, ok


from repro.util import pow2_bucket


class VenusMemory:
    """Index layer: packed vector store + cluster member reservoirs."""

    def __init__(self, capacity: int, dim: int, member_cap: int = 128,
                 seed: int = 0, *, incremental: bool = True):
        # the exact integer pick (u * cnt) >> U_BITS must fit in int32
        assert member_cap <= (1 << (31 - U_BITS)), member_cap
        self.capacity = capacity
        self.dim = dim
        self.member_cap = member_cap
        self.incremental = incremental
        self._emb = np.zeros((capacity, dim), np.float32)
        self._members = np.zeros((capacity, member_cap), np.int32)
        self._member_count = np.zeros((capacity,), np.int32)
        self._index_frame = np.zeros((capacity,), np.int32)
        self._scene_id = np.zeros((capacity,), np.int32)
        self._size = 0
        self._rng = np.random.default_rng(seed)
        self._emb_dev: Optional[jnp.ndarray] = None
        self._members_dev: Optional[jnp.ndarray] = None
        self._member_count_dev: Optional[jnp.ndarray] = None
        self._index_frame_dev: Optional[jnp.ndarray] = None
        self.version = 0               # bumped per insert (stack caching)
        self.io_stats = {"full_uploads": 0, "appended_rows": 0,
                         "member_uploads": 0, "appended_member_rows": 0,
                         "index_frame_uploads": 0,
                         "appended_index_frame_rows": 0,
                         "scans": 0, "host_expand_gathers": 0,
                         "device_expand_gathers": 0}

    def reset_io_stats(self) -> None:
        """Zero the transfer/scan counters in place (the dict identity is
        preserved, so held references keep observing the live counters).
        Benchmarks and tests use this to assert per-phase counts without
        rebuilding the memory."""
        for k in self.io_stats:
            self.io_stats[k] = 0

    # ------------------------------------------------------------- ingestion
    def insert_cluster(self, embedding: np.ndarray, *, scene_id: int,
                       index_frame: int, member_frames: Sequence[int]
                       ) -> int:
        """Insert one indexed vector linked to its cluster members."""
        return int(self.insert_batch(
            np.asarray(embedding, np.float32)[None],
            scene_ids=[scene_id], index_frames=[index_frame],
            member_lists=[member_frames])[0])

    def insert_batch(self, embeddings: np.ndarray, *,
                     scene_ids: Sequence[int],
                     index_frames: Sequence[int],
                     member_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """Insert a batch of indexed vectors in one shot.

        Host mirrors are written vectorised; if the device copy exists it
        is extended in place with a single jit'd row-block append (no
        cache invalidation / full re-upload).
        """
        embeddings = np.asarray(embeddings, np.float32)
        n = embeddings.shape[0]
        assert n == len(scene_ids) == len(index_frames) == len(member_lists)
        if self._size + n > self.capacity:
            raise RuntimeError("memory capacity exhausted")
        lo = self._size
        self._emb[lo:lo + n] = embeddings
        self._index_frame[lo:lo + n] = np.asarray(index_frames, np.int32)
        self._scene_id[lo:lo + n] = np.asarray(scene_ids, np.int32)
        for j, member_frames in enumerate(member_lists):
            members = np.asarray(member_frames, np.int32)
            m = len(members)
            if m > self.member_cap:            # uniform reservoir
                keep = self._rng.choice(m, self.member_cap, replace=False)
                members = members[np.sort(keep)]
                m = self.member_cap
            self._members[lo + j, :m] = members
            self._member_count[lo + j] = m
        self._size += n
        self.version += 1
        self._sync_device(lo, n)
        return np.arange(lo, lo + n)

    def _sync_device(self, lo: int, n: int) -> None:
        if not self.incremental:
            self._emb_dev = None         # seed behaviour: full re-upload
            self._members_dev = None
            self._member_count_dev = None
            self._index_frame_dev = None
            return
        # bucket the row count (bounds jit specialisations); padded rows
        # land past the valid region and are overwritten by later appends
        b = min(pow2_bucket(n, lo=8), self.capacity - lo)
        if self._emb_dev is not None:    # lazy: first query uploads once
            rows = np.zeros((b, self.dim), np.float32)
            rows[:n] = self._emb[lo:lo + n]
            self._emb_dev = _append_rows(self._emb_dev, jnp.asarray(rows),
                                         jnp.asarray(lo, jnp.int32))
            self.io_stats["appended_rows"] += b
        if self._members_dev is not None:
            rows = np.zeros((b, self.member_cap), np.int32)
            rows[:n] = self._members[lo:lo + n]
            cnts = np.zeros((b,), np.int32)
            cnts[:n] = self._member_count[lo:lo + n]
            self._members_dev, self._member_count_dev = _append_member_rows(
                self._members_dev, self._member_count_dev,
                jnp.asarray(rows), jnp.asarray(cnts),
                jnp.asarray(lo, jnp.int32))
            self.io_stats["appended_member_rows"] += b
        if self._index_frame_dev is not None:
            rows = np.zeros((b,), np.int32)
            rows[:n] = self._index_frame[lo:lo + n]
            self._index_frame_dev = _append_id_rows(
                self._index_frame_dev, jnp.asarray(rows),
                jnp.asarray(lo, jnp.int32))
            self.io_stats["appended_index_frame_rows"] += b

    # ----------------------------------------------------------------- query
    @property
    def size(self) -> int:
        return self._size

    def device_index(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(embeddings (cap, d), valid (cap,)) as device arrays.

        First call uploads the packed host array once; subsequent inserts
        keep the device copy current via ``_append_rows``. NOTE: inserts
        DONATE the current buffer to the in-place append, so a handle
        returned here is invalidated by the next insert — re-call this
        method after inserting rather than holding the arrays."""
        if self._emb_dev is None:
            self._emb_dev = jnp.asarray(self._emb)
            self.io_stats["full_uploads"] += 1
        return self._emb_dev, _valid_mask(jnp.asarray(self._size, jnp.int32),
                                          capacity=self.capacity)

    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (Q,d) -> (sims (Q,cap), probs (Q,cap)) — Eq. 4+5."""
        emb, valid = self.device_index()
        self.io_stats["scans"] += 1
        return kops.similarity(query_emb, emb, tau=tau, valid=valid)

    # ------------------------------------------------- cluster-level expand
    def members_table(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self._members), jnp.asarray(self._member_count)

    def device_members(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(members (cap, member_cap), counts (cap,)) device-resident.

        Same contract as ``device_index``: first call uploads once,
        subsequent inserts append in place (and DONATE the buffers, so
        re-call after inserting rather than holding the handles)."""
        if self._members_dev is None:
            self._members_dev = jnp.asarray(self._members)
            self._member_count_dev = jnp.asarray(self._member_count)
            self.io_stats["member_uploads"] += 1
        return self._members_dev, self._member_count_dev

    def device_index_frames(self) -> jnp.ndarray:
        """index_frame ids (cap,) device-resident — the centroid frame id
        of each memory slot, for strategies whose draws map straight to
        indexed frames (top-k / BOLT / MDF / AKS) rather than through the
        member reservoirs. Same contract as ``device_index``: first call
        uploads once, subsequent inserts append in place (donated)."""
        if self._index_frame_dev is None:
            self._index_frame_dev = jnp.asarray(self._index_frame)
            self.io_stats["index_frame_uploads"] += 1
        return self._index_frame_dev

    @staticmethod
    def expand_u(seed: int, size) -> np.ndarray:
        """The per-slot pick variates u ∈ [0, 2^U_BITS): one int per draw
        slot, a function of (seed, slot) only — every expansion path
        (loop / vectorised / batched / device) consumes this sequence."""
        return np.random.default_rng(seed).integers(
            0, _U_CARD, size=size, dtype=np.int64)

    def expand_draws(self, draws: np.ndarray, valid: np.ndarray,
                     seed: int = 0) -> np.ndarray:
        """Map index draws to frame ids: each draw of index i samples one
        member uniformly from cluster c(oᵢ) (paper §IV-D1). Vectorised
        fixed-shape gather over the members table — one uniform variate
        is consumed per slot (valid or not) so batched and sequential
        paths agree. Returns the deduplicated, time-ordered frame ids."""
        draws = np.atleast_1d(np.asarray(draws))
        valid = np.atleast_1d(np.asarray(valid, bool))
        u = self.expand_u(seed, draws.shape)
        return self._expand_u(draws, valid, u)

    def expand_draws_batch(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> List[np.ndarray]:
        """Batched expansion: draws/valid (Q, n). Each row consumes the
        same per-slot variate sequence as a sequential ``expand_draws``
        call with the same seed, so results match query-for-query."""
        draws = np.asarray(draws)
        valid = np.asarray(valid, bool)
        q, n = draws.shape
        u = np.broadcast_to(self.expand_u(seed, n), (q, n))
        fids, ok = self._expand_u(draws, valid, u, dedup=False)
        return [np.unique(fids[i][ok[i]]) for i in range(q)]

    def expand_draws_device(self, draws: np.ndarray, valid: np.ndarray,
                            seed: int = 0) -> np.ndarray:
        """``expand_draws`` with the reservoir gather on device: a jit'd
        fixed-shape lookup over ``device_members()`` — no host-side
        members-table access; only the (n,) frame ids transfer back."""
        draws = np.atleast_1d(np.asarray(draws, np.int32))
        valid = np.atleast_1d(np.asarray(valid, bool))
        members, counts = self.device_members()
        u = self.expand_u(seed, draws.shape)
        fids, ok = expand_gather(members, counts, jnp.asarray(draws),
                                  jnp.asarray(valid),
                                  jnp.asarray(u, jnp.int32))
        self.io_stats["device_expand_gathers"] += 1
        fids, ok = np.asarray(fids), np.asarray(ok)
        return np.unique(fids[ok].astype(np.int64))

    def _expand_u(self, draws, valid, u, dedup: bool = True):
        self.io_stats["host_expand_gathers"] += 1
        safe = np.clip(draws, 0, self.capacity - 1)
        cnt = self._member_count[safe].astype(np.int64)
        pick = (np.asarray(u, np.int64) * cnt) >> U_BITS
        fids = self._members[safe, pick].astype(np.int64)
        ok = valid & (cnt > 0) & (draws >= 0)
        if dedup:
            return np.unique(fids[ok])
        return fids, ok

    def _expand_draws_loop(self, draws: np.ndarray, valid: np.ndarray,
                           seed: int = 0) -> np.ndarray:
        """Seed-style per-draw loop over the same sampling scheme —
        reference for the vectorised path (kept for tests/benches)."""
        rng = np.random.default_rng(seed)
        out = []
        for i, ok in zip(np.asarray(draws), np.asarray(valid)):
            u = int(rng.integers(0, _U_CARD, dtype=np.int64))
            if not ok or i < 0:
                continue
            cnt = int(self._member_count[int(i)])
            if cnt == 0:
                continue
            out.append(int(self._members[int(i), (u * cnt) >> U_BITS]))
        return np.unique(np.asarray(out, np.int64))

    def index_frames(self, idx: Sequence[int]) -> np.ndarray:
        return self._index_frame[np.asarray(idx, np.int64)]


# ---------------------------------------------------------------------------
# Cross-session stacked view
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("capacity",))
def _valid_stack(sizes: jnp.ndarray, *, capacity: int) -> jnp.ndarray:
    return jnp.arange(capacity)[None, :] < sizes[:, None]


class MemoryStack:
    """Padded-stack view over S same-shape ``VenusMemory`` instances.

    Exposes the sessions' device-resident buffers as ``(S, capacity, …)``
    stacks for the fused cross-session query path. The stacks are built
    *device-side* from the per-session device buffers (``jnp.stack`` of
    resident arrays — no host↔device transfer beyond each memory's one
    lazy first upload) and cached against the members' insert versions,
    so repeated queries between ingest ticks rebuild nothing.
    """

    def __init__(self, memories: Sequence[VenusMemory]):
        memories = list(memories)
        assert memories, "empty stack"
        cap, dim, mcap = (memories[0].capacity, memories[0].dim,
                          memories[0].member_cap)
        for m in memories:
            assert (m.capacity, m.dim, m.member_cap) == (cap, dim, mcap), \
                "stacked memories must share capacity/dim/member_cap"
        self.memories = memories
        self.capacity, self.dim, self.member_cap = cap, dim, mcap
        self._emb_stack: Optional[jnp.ndarray] = None
        self._valid: Optional[jnp.ndarray] = None
        self._members_stack: Optional[jnp.ndarray] = None
        self._counts_stack: Optional[jnp.ndarray] = None
        self._index_frame_stack: Optional[jnp.ndarray] = None
        self._emb_versions: Optional[Tuple[int, ...]] = None
        self._mem_versions: Optional[Tuple[int, ...]] = None
        self._if_versions: Optional[Tuple[int, ...]] = None
        self.io_stats = {"stack_builds": 0, "member_stack_builds": 0,
                         "index_frame_stack_builds": 0}

    def __len__(self) -> int:
        return len(self.memories)

    def _versions(self) -> Tuple[int, ...]:
        return tuple(m.version for m in self.memories)

    # ----------------------------------------------------------- device views
    def device_stack(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(emb (S, cap, d), valid (S, cap)) device arrays."""
        vers = self._versions()
        if self._emb_stack is None or vers != self._emb_versions:
            self._emb_stack = jnp.stack(
                [m.device_index()[0] for m in self.memories])
            # sizes only change with a version bump, so the valid mask is
            # cached alongside — queries between ticks transfer nothing
            sizes = jnp.asarray([m.size for m in self.memories], jnp.int32)
            self._valid = _valid_stack(sizes, capacity=self.capacity)
            self._emb_versions = vers
            self.io_stats["stack_builds"] += 1
        return self._emb_stack, self._valid

    def device_members(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(members (S, cap, member_cap), counts (S, cap)) device arrays."""
        vers = self._versions()
        if self._members_stack is None or vers != self._mem_versions:
            tabs = [m.device_members() for m in self.memories]
            self._members_stack = jnp.stack([t[0] for t in tabs])
            self._counts_stack = jnp.stack([t[1] for t in tabs])
            self._mem_versions = vers
            self.io_stats["member_stack_builds"] += 1
        return self._members_stack, self._counts_stack

    def device_index_frames(self) -> jnp.ndarray:
        """index_frame ids (S, cap) device arrays (cached per version)."""
        vers = self._versions()
        if self._index_frame_stack is None or vers != self._if_versions:
            self._index_frame_stack = jnp.stack(
                [m.device_index_frames() for m in self.memories])
            self._if_versions = vers
            self.io_stats["index_frame_stack_builds"] += 1
        return self._index_frame_stack

    # ----------------------------------------------------------------- query
    def search(self, query_emb: jnp.ndarray, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """query_emb (S, Q, d) -> (sims, probs) (S, Q, cap) — every
        session scanned by ONE fused kernel launch."""
        emb, valid = self.device_stack()
        return kops.similarity_stack(query_emb, emb, tau=tau, valid=valid)
