"""Distributed Venus memory: the index sharded across the pod.

On a real deployment the edge keeps its own small index, but Venus's
memory also has a *fleet* story (DESIGN.md §5): a site with many cameras
aggregates indexed vectors into one pod-resident memory, sharded over the
``model`` mesh axis. Retrieval is then a shard_map program:

  1. every shard scans its local slice with the fused similarity kernel
     (Eq. 4) — embarrassingly parallel, MXU-bound;
  2. each shard reduces its slice to its local top-M candidates
     (M = n_max, so no recall loss for any budget ≤ n_max);
  3. one small all_gather of (M scores, M global ids) per shard —
     K·M·8 bytes, independent of index size;
  4. the temperature softmax (Eq. 5) + sampling/AKR run on the gathered
     candidate set exactly as in the single-node path.

Exactness: softmax probabilities of the true global top-(≤M) survivors
are identical to the dense computation restricted to them; AKR's mass
accounting is conservative (it can only under-count tail mass it would
never have sampled at θ ≤ the candidate mass). An empty (or
all-invalid) index returns ZERO mass — candidates carry ``probs == 0``
so no downstream sampler can draw garbage ids (a plain softmax over
all-``-1e30`` logits would have handed back a uniform distribution).

Ingestion is batched: a block of rows is round-robined across shards
with ONE scatter per insert call (no per-row ``.at[pos].set``) that
DONATES both sharded operands — the same in-place convention as the
arena's tick scatter, so an insert moves O(rows) bytes, never the full
``(capacity, d)`` buffer (``io_stats["scatter_bytes"]`` counts exactly
what crosses). The global-id → insert-order translation after search is
a vectorised device op rather than a per-candidate host loop.

This module and the arena path (``MemoryArena(mesh=...)`` +
``kernels.ops``' shard_map scan entries) share one substrate: the
``launch.sharding.shard_map`` compat symbol, the ``memory_sharding``
slab placement, and the per-shard-top-M + small-gather retrieval shape.
The arena generalises the (N, d) flat index here to per-session
``(S, capacity, ·)`` lanes; this class remains the flat pod-level
aggregate.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.launch.sharding import (memory_sharding, mesh_axis_size,
                                   shard_map as _shard_map)


@functools.partial(jax.jit, static_argnames=("top_m", "mesh", "mesh_axis"))
def _sharded_scan(query: jnp.ndarray, index: jnp.ndarray,
                  valid: jnp.ndarray, *, top_m: int, mesh,
                  mesh_axis: str = "model"):
    """query (d,) replicated; index (N, d) + valid (N,) sharded on axis 0
    over ``mesh_axis``. Returns (scores (K·M,), ids (K·M,)) gathered."""

    def local(q, idx, val):
        # idx: (N/K, d) local slice
        sims, _ = kops.similarity(q[None], idx, tau=1.0, valid=val)
        s = jnp.where(val, sims[0], -jnp.inf)
        m = min(top_m, s.shape[0])
        top_s, top_i = jax.lax.top_k(s, m)
        shard = jax.lax.axis_index(mesh_axis)
        gids = top_i + shard * s.shape[0]          # global ids
        # per-shard candidates; the sharded out_specs stitch them into
        # (K·M,) arrays — the all-gather happens at the consumer
        return top_s, gids

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(mesh_axis, None), P(mesh_axis)),
        out_specs=(P(mesh_axis), P(mesh_axis)))(query, index, valid)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_rows(emb: jnp.ndarray, valid: jnp.ndarray,
                  rows: jnp.ndarray, pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One batched scatter of ``rows`` into slots ``pos`` (+ validity).
    Both sharded operands are DONATED (the arena's donated-scatter
    convention): XLA updates them in place, so an insert moves O(rows)
    bytes instead of copying the whole (capacity, d) buffer per call."""
    return (emb.at[pos].set(rows), valid.at[pos].set(True))


class DistributedVenusMemory:
    """Pod-resident index: batched host inserts, shard_map retrieval."""

    def __init__(self, capacity: int, dim: int, mesh, *,
                 mesh_axis: str = "model", top_m: int = 64):
        k = mesh_axis_size(mesh, mesh_axis)
        assert capacity % k == 0, (capacity, k)
        self.capacity, self.dim = capacity, dim
        self.mesh, self.mesh_axis, self.top_m = mesh, mesh_axis, top_m
        self._emb = jax.device_put(jnp.zeros((capacity, dim), jnp.float32),
                                   memory_sharding(mesh, 2, mesh_axis))
        self._valid = jax.device_put(jnp.zeros((capacity,), bool),
                                     memory_sharding(mesh, 1, mesh_axis))
        self._size = 0
        # what actually crosses host→device per insert: the donated
        # scatter writes only the row block + its validity bits in
        # place, so scatter_bytes is O(rows·dim), independent of
        # capacity — the no-copy assertion tests pin this
        self.io_stats = {"inserts": 0, "scatter_rows": 0,
                         "scatter_bytes": 0, "searches": 0}

    @property
    def size(self) -> int:
        return self._size

    @property
    def _shards(self) -> int:
        return mesh_axis_size(self.mesh, self.mesh_axis)

    def insert(self, embeddings) -> None:
        """Append a batch of indexed vectors (host-side, like FAISS add).

        Round-robins rows across shards so load stays balanced; the whole
        block lands in one scatter instead of a per-row update loop."""
        embeddings = jnp.asarray(embeddings, jnp.float32)
        n = embeddings.shape[0]
        if self._size + n > self.capacity:
            raise RuntimeError("distributed memory capacity exhausted")
        k = self._shards
        per = self.capacity // k
        s = self._size + jnp.arange(n)             # insert orders
        pos = (s % k) * per + s // k               # slot of each row
        self._emb, self._valid = _scatter_rows(self._emb, self._valid,
                                               embeddings, pos)
        self._size += n
        self.io_stats["inserts"] += 1
        self.io_stats["scatter_rows"] += n
        # rows (n·d f32) + validity (n bool) + positions (n int32): the
        # donated in-place update moves nothing else
        self.io_stats["scatter_bytes"] += n * (self.dim * 4 + 1 + 4)

    def insert_orders(self, gids: jnp.ndarray) -> jnp.ndarray:
        """Vectorised global-id → insert-order translation (device op)."""
        per = self.capacity // self._shards
        return (gids % per) * self._shards + gids // per

    def global_id_to_insert_order(self, gid: int) -> int:
        return int(self.insert_orders(jnp.asarray(int(gid))))

    def search(self, query_emb, *, tau: float
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (candidate insert-order ids (K·M,), probs (K·M,)) —
        Eq. 4+5 over the gathered global candidate set.

        The softmax is MASKED: invalid candidate lanes (per-shard top-M
        slots whose score is ±inf/NaN — empty shards, padding past the
        live rows) contribute zero numerator AND are excluded from the
        normaliser, so an empty or all-invalid index returns all-zero
        probabilities instead of a uniform distribution over garbage
        ids. Callers detect "nothing to retrieve" as ``probs.sum() ==
        0`` — no candidate is ever drawable with zero valid mass."""
        self.io_stats["searches"] += 1
        scores, gids = _sharded_scan(
            jnp.asarray(query_emb, jnp.float32), self._emb,
            self._valid, top_m=self.top_m, mesh=self.mesh,
            mesh_axis=self.mesh_axis)
        finite = jnp.isfinite(scores)
        logits = jnp.where(finite, scores / tau, -1e30)
        # max over the finite lanes only; -1e30 for an all-invalid set
        # keeps exp() well-defined (everything hits the `finite` mask)
        e = jnp.where(finite, jnp.exp(logits - jnp.max(logits)), 0.0)
        z = jnp.sum(e)
        probs = jnp.where(z > 0, e / jnp.maximum(z, 1e-30), 0.0)
        return self.insert_orders(gids), probs
