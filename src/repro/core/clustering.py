"""Incremental frame clustering within a scene partition (paper §IV-B2).

The first frame seeds cluster c₀; each subsequent frame joins the nearest
existing centroid if its L2 distance is within ``threshold``, otherwise it
seeds a new cluster. Centroids are running means of their members (the
temporal-contiguity property the paper wants falls out of processing
frames in order). Implemented as a fixed-capacity ``lax.scan`` so it jits:
state carries (centroid sums, counts, n_clusters) with a max-clusters
bound; overflow joins the nearest cluster regardless of threshold.

``cluster_partition`` returns per-frame assignments plus, per cluster, the
**index frame** — the member closest to the final centroid (the paper's
"centroid frame") — which is what gets embedded into memory.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.util import pow2_bucket


def frame_vectors(frames: jnp.ndarray, pool: int = 8) -> jnp.ndarray:
    """(T,H,W,3) -> (T, d) pooled+flattened pixel vectors (the paper's
    "flatten raw pixel values", made cheap via average pooling)."""
    t, h, w, c = frames.shape
    ph, pw = h // pool, w // pool
    x = frames[:, : ph * pool, : pw * pool]
    x = x.reshape(t, ph, pool, pw, pool, c).mean(axis=(2, 4))
    return x.reshape(t, -1)


class ClusterResult(NamedTuple):
    assignments: jnp.ndarray       # (T,) int32 cluster id per frame
    n_clusters: jnp.ndarray        # () int32
    centroids: jnp.ndarray         # (K_max, d) running-mean centroids
    counts: jnp.ndarray            # (K_max,) member counts
    index_frames: jnp.ndarray      # (K_max,) member idx closest to centroid


def cluster_partition(vecs: jnp.ndarray, *, threshold: float,
                      max_clusters: int) -> ClusterResult:
    """vecs: (T, d) frame vectors of one partition.

    Pads T to the next power of two so the jit cache sees O(log T)
    distinct shapes instead of one per partition length (online
    partitions have arbitrary lengths)."""
    t = vecs.shape[0]
    tp = pow2_bucket(t)
    padded = jnp.pad(vecs, ((0, tp - t), (0, 0)))
    n_valid = jnp.asarray(t, jnp.int32)
    res = _cluster_padded(padded, n_valid, threshold=float(threshold),
                          max_clusters=int(max_clusters))
    return ClusterResult(res.assignments[:t], res.n_clusters,
                         res.centroids, res.counts, res.index_frames)


@functools.partial(jax.jit, static_argnames=("threshold", "max_clusters"))
def _cluster_padded(vecs: jnp.ndarray, n_valid: jnp.ndarray, *,
                    threshold: float, max_clusters: int) -> ClusterResult:
    t, d = vecs.shape
    kmax = max_clusters

    def step(state, inp):
        sums, counts, n = state
        i, v = inp
        ok = i < n_valid
        means = sums / jnp.maximum(counts, 1.0)[:, None]
        dist = jnp.sqrt(jnp.sum((means - v[None]) ** 2, axis=-1) + 1e-12)
        dist = jnp.where(jnp.arange(kmax) < n, dist, jnp.inf)
        nearest = jnp.argmin(dist)
        near_ok = dist[nearest] <= threshold
        can_new = n < kmax
        make_new = ((~near_ok) & can_new) | (n == 0)
        cid = jnp.where(make_new, n, nearest).astype(jnp.int32)
        cid = jnp.where(ok, cid, 0)
        upd = ok.astype(jnp.float32)
        sums = sums.at[cid].add(v * upd)
        counts = counts.at[cid].add(upd)
        n = n + (make_new & ok).astype(jnp.int32)
        return (sums, counts, n), cid

    init = (jnp.zeros((kmax, d), jnp.float32),
            jnp.zeros((kmax,), jnp.float32),
            jnp.zeros((), jnp.int32))
    (sums, counts, n), assignments = jax.lax.scan(
        step, init, (jnp.arange(t), vecs.astype(jnp.float32)))
    centroids = sums / jnp.maximum(counts, 1.0)[:, None]

    # index frame per cluster: member closest to the final centroid
    d2 = jnp.sum((vecs.astype(jnp.float32)[:, None, :]
                  - centroids[None, :, :]) ** 2, axis=-1)   # (T, K)
    member = ((assignments[:, None] == jnp.arange(kmax)[None, :])
              & (jnp.arange(t)[:, None] < n_valid))
    d2 = jnp.where(member, d2, jnp.inf)
    index_frames = jnp.argmin(d2, axis=0).astype(jnp.int32)
    return ClusterResult(assignments, n, centroids, counts, index_frames)
