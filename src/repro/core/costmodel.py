"""Edge/cloud latency & cost model (paper Fig. 2 / Table II / Fig. 12).

We cannot measure a Jetson or a 100 Mbps WAN here, so end-to-end response
latency is decomposed exactly as the paper does and each term is either
**measured** on this host (edge compute: scene seg, clustering, MEM embed,
retrieval) or **modeled analytically** with the paper's constants
(communication at 100 Mbps; cloud VLM inference from a per-frame token
cost). Benchmarks label which is which.

Paper constants: 100 Mbps edge↔cloud; videos at 8 FPS; VLM consumes ~196
visual tokens/frame (LLaVA-OV).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class LinkModel:
    bandwidth_bps: float = 100e6          # paper: 100 Mbps
    rtt_s: float = 0.05

    def transfer_s(self, n_bytes: float) -> float:
        return self.rtt_s + 8.0 * n_bytes / self.bandwidth_bps


@dataclass(frozen=True)
class CloudVLMModel:
    """Analytic VLM inference latency: prefill dominated by visual tokens."""
    tokens_per_frame: int = 196
    prefill_tok_per_s: float = 8000.0     # L40S-class 7B prefill
    decode_tok_per_s: float = 60.0
    answer_tokens: int = 48

    def infer_s(self, n_frames: int, text_tokens: int = 64) -> float:
        prefill = (n_frames * self.tokens_per_frame + text_tokens
                   ) / self.prefill_tok_per_s
        return prefill + self.answer_tokens / self.decode_tok_per_s


@dataclass(frozen=True)
class FrameFormat:
    height: int = 448
    width: int = 448
    bytes_per_frame_jpeg: int = 60_000    # ~60 KB at 448², the paper's
                                          # uploads are compressed frames

    def raw_bytes(self) -> int:
        return self.height * self.width * 3


@dataclass
class LatencyBreakdown:
    parts: Dict[str, float] = field(default_factory=dict)

    def add(self, name: str, seconds: float) -> None:
        self.parts[name] = self.parts.get(name, 0.0) + seconds

    @property
    def total(self) -> float:
        return sum(self.parts.values())

    def __str__(self) -> str:
        inner = ", ".join(f"{k}={v:.3f}s" for k, v in self.parts.items())
        return f"LatencyBreakdown(total={self.total:.3f}s; {inner})"


def venus_query_latency(*, measured_edge_s: Dict[str, float],
                        n_frames_uploaded: int,
                        link: LinkModel = LinkModel(),
                        vlm: CloudVLMModel = CloudVLMModel(),
                        fmt: FrameFormat = FrameFormat()
                        ) -> LatencyBreakdown:
    """Assemble a Venus-style response latency: measured edge terms +
    modeled upload + modeled cloud inference."""
    b = LatencyBreakdown()
    for k, v in measured_edge_s.items():
        b.add(f"edge/{k}", v)
    b.add("comm/upload", link.transfer_s(
        n_frames_uploaded * fmt.bytes_per_frame_jpeg))
    b.add("cloud/vlm", vlm.infer_s(n_frames_uploaded))
    return b


def cloud_only_latency(*, video_frames: int, selected_frames: int,
                       select_algo_s: float,
                       link: LinkModel = LinkModel(),
                       vlm: CloudVLMModel = CloudVLMModel(),
                       fmt: FrameFormat = FrameFormat()
                       ) -> LatencyBreakdown:
    """BOLT/AKS cloud-only: ship the whole clip, select + infer on cloud."""
    b = LatencyBreakdown()
    b.add("comm/upload_video", link.transfer_s(
        video_frames * fmt.bytes_per_frame_jpeg))
    b.add("cloud/select", select_algo_s)
    b.add("cloud/vlm", vlm.infer_s(selected_frames))
    return b


def edge_cloud_latency(*, edge_select_s: float, selected_frames: int,
                       link: LinkModel = LinkModel(),
                       vlm: CloudVLMModel = CloudVLMModel(),
                       fmt: FrameFormat = FrameFormat()
                       ) -> LatencyBreakdown:
    """BOLT/AKS edge-cloud: frame-wise selection on the edge (slow), then
    upload only selected frames."""
    b = LatencyBreakdown()
    b.add("edge/select", edge_select_s)
    b.add("comm/upload", link.transfer_s(
        selected_frames * fmt.bytes_per_frame_jpeg))
    b.add("cloud/vlm", vlm.infer_s(selected_frames))
    return b
