"""Scene detection & segmentation (paper §IV-B1, Eq. 1).

The stream is partitioned where the frame-difference score φ exceeds a
threshold; a *maximum* partition duration handles static cameras (the
paper's "minimum temporal threshold": if no scene change occurs within a
set duration, the period becomes one partition).

Two entry points:
* ``scene_scores`` — φ per frame (Pallas kernel or jnp oracle).
* ``segment`` — boundary decisions as a ``lax.scan`` over φ, carrying the
  frames-since-boundary counter; returns a boundary mask and per-frame
  partition ids so downstream stages stay fixed-shape under jit.

``StreamSegmenter`` is the online wrapper: it consumes chunks of frames,
maintains carry state across chunks (the previous chunk's tail φ counter)
and emits closed partitions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

DEFAULT_WEIGHTS = (1.0, 1.0, 1.0, 2.0)       # (hue, sat, light, edge)


def scene_scores(frames: jnp.ndarray,
                 weights: Tuple[float, float, float, float] = DEFAULT_WEIGHTS
                 ) -> jnp.ndarray:
    """frames: (T,H,W,3) float in [0,1] -> φ (T,); φ[0]=0."""
    return kops.scene_score(frames, weights)


def segment(phi: jnp.ndarray, *, threshold: float,
            max_partition_len: int,
            carry_in: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Boundary decision per frame.

    Returns (boundary (T,) bool — True means frame i *starts* a new
    partition; part_id (T,) int32 0-based within this call; carry_out —
    frames since the last boundary after the final frame).
    """
    carry0 = jnp.zeros((), jnp.int32) if carry_in is None else carry_in

    def step(since, p):
        new = (p > threshold) | (since >= max_partition_len)
        since = jnp.where(new, 1, since + 1)
        return since, new

    carry_out, boundary = jax.lax.scan(step, carry0, phi)
    # frame 0 with no carry begins partition 0 implicitly
    boundary = boundary.at[0].set(boundary[0] | (carry0 == 0))
    part_id = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    part_id = jnp.maximum(part_id, 0)
    return boundary, part_id, carry_out


@dataclass
class Partition:
    """A closed scene partition: [start, end) absolute frame indices."""
    start: int
    end: int


@dataclass
class StreamSegmenter:
    threshold: float = 0.08
    max_partition_len: int = 256
    weights: Tuple[float, float, float, float] = DEFAULT_WEIGHTS

    _since: int = 0
    _open_start: int = 0
    _abs: int = 0
    _started: bool = False
    _last_frame: Optional[jnp.ndarray] = None

    def ingest(self, frames: jnp.ndarray) -> List[Partition]:
        """Consume a chunk (T,H,W,3); return partitions closed by it."""
        if self._last_frame is not None:
            ext = jnp.concatenate([self._last_frame[None], frames], axis=0)
            phi = np.asarray(scene_scores(ext, self.weights))[1:]
        else:
            phi = np.asarray(scene_scores(frames, self.weights))
        self._last_frame = frames[-1]
        closed: List[Partition] = []
        for i, p in enumerate(phi):
            t = self._abs + i
            is_boundary = (self._started
                           and (p > self.threshold
                                or self._since >= self.max_partition_len))
            if is_boundary:
                closed.append(Partition(self._open_start, t))
                self._open_start = t
                self._since = 1
            else:
                self._since += 1
            self._started = True
        self._abs += len(phi)
        return closed

    def flush(self) -> List[Partition]:
        if self._started and self._abs > self._open_start:
            part = [Partition(self._open_start, self._abs)]
            self._open_start = self._abs
            return part
        return []
