"""Auxiliary model stubs (paper Eq. 2): OCR / detector text prompts.

The paper runs lightweight proprietary models (EasyOCR, YOLO) over each
indexed frame and formats their outputs into textual templates that are
embedded *jointly* with the frame by the MEM. Their vision backbones are
out of scope (assignment carve-out); the interface is real: an AuxModel
maps a frame (+ optional ground-truth annotations from the synthetic
world) to template text, and the pipeline turns that text into tokens for
the MEM text tower.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Sequence

import numpy as np


class AuxModel(Protocol):
    name: str

    def describe(self, frame: np.ndarray,
                 annotations: Optional[Dict] = None) -> str: ...


@dataclass
class OCRStub:
    """Emits the synthetic world's text annotation (what EasyOCR would
    read off the frame)."""
    name: str = "ocr"

    def describe(self, frame, annotations=None) -> str:
        if annotations and annotations.get("text"):
            return f"text: {annotations['text']}"
        return ""


@dataclass
class DetectorStub:
    """Emits object labels (what YOLO would detect)."""
    name: str = "yolo"

    def describe(self, frame, annotations=None) -> str:
        if annotations and annotations.get("objects"):
            return "objects: " + ", ".join(annotations["objects"])
        return ""


def build_aux_prompt(models: Sequence[AuxModel], frame: np.ndarray,
                     annotations: Optional[Dict] = None) -> str:
    """Eq. 2: t_i = AuxModels(k_i), formatted into one template string."""
    parts = [m.describe(frame, annotations) for m in models]
    return " | ".join(p for p in parts if p)
