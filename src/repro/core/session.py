"""Session layer: multi-stream, batch-first Venus (paper Fig. 6 at scale).

The monolithic single-stream system is decomposed into composable
per-stream stages operating on a ``SessionState``:

* ``segment_stage``   — chunk → closed scene partitions (①),
* ``cluster_stage``   — one closed partition → an ``EmbedJob`` holding
  its centroid index frames + cluster membership (②–③),
* ``commit_jobs``     — ALL embed jobs closed in one tick, across every
  session, concatenated into a SINGLE jit'd MEM call, then scattered
  into each session's device-resident memory with batched appends (④).

``SessionManager`` owns N concurrent streams (the edge box's cameras)
and drives the stages; ``query_batch`` runs Q queries through ONE
similarity scan (the Pallas kernel already takes ``(Q, d)``), a vmapped
sampling/AKR pass, and one vectorised cluster expansion — matching the
sequential ``query`` path result-for-result while amortising every
device round-trip across the batch.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import retrieval as rt
from repro.core.aux_models import AuxModel, build_aux_prompt
from repro.core.clustering import cluster_partition, frame_vectors
from repro.core.memory import (FrameStore, MemoryStack, VenusMemory,
                               expand_gather)
from repro.core.scene import Partition, StreamSegmenter


@dataclass(frozen=True)
class VenusConfig:
    # ingestion
    scene_threshold: float = 0.075
    max_partition_len: int = 256
    cluster_threshold: float = 0.35
    max_clusters_per_partition: int = 16
    cluster_pool: int = 8
    # memory
    memory_capacity: int = 8192
    member_cap: int = 128
    # querying (Eq. 5-7)
    tau: float = 0.1
    theta: float = 0.9
    beta: float = 1.0
    n_max: int = 32
    seed: int = 0


@dataclass
class QueryResult:
    frame_ids: np.ndarray          # selected raw-frame ids (deduped)
    draws: np.ndarray              # index draws
    n_drawn: int
    mass: float
    timings: Dict[str, float]


@dataclass
class EmbedJob:
    """One closed partition's centroid frames awaiting MEM embedding."""
    sid: int
    scene_id: int
    frames: np.ndarray                       # (n, H, W, 3) index frames
    frame_ids: np.ndarray                    # (n,) absolute frame ids
    member_lists: List[np.ndarray]           # per-cluster member frame ids
    aux_texts: Optional[List[str]]


class SessionState:
    """Per-stream state: segmenter, pending buffer, archive, memory."""

    def __init__(self, sid: int, cfg: VenusConfig, embed_dim: int):
        self.sid = sid
        self.cfg = cfg
        self.segmenter = StreamSegmenter(
            threshold=cfg.scene_threshold,
            max_partition_len=cfg.max_partition_len)
        self.memory = VenusMemory(cfg.memory_capacity, embed_dim,
                                  cfg.member_cap, seed=cfg.seed)
        self.frames = FrameStore()
        self.pending: List[np.ndarray] = []   # frames not yet clustered
        self.pending_base = 0                 # abs index of pending[0]
        self.key = jax.random.key(cfg.seed)
        self.stats = {"frames_seen": 0, "frames_embedded": 0,
                      "partitions": 0, "clusters": 0}

    def next_keys(self, n: int) -> jnp.ndarray:
        """Advance the session PRNG chain n steps — the same chain a
        sequence of n single queries would consume, so batched and
        sequential querying draw identical subkeys."""
        subs = []
        for _ in range(n):
            self.key, sub = jax.random.split(self.key)
            subs.append(sub)
        return jnp.stack(subs)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


def segment_stage(state: SessionState, chunk: np.ndarray) -> List[Partition]:
    """① scene segmentation: archive the chunk, return closed partitions."""
    chunk = np.asarray(chunk, np.float32)
    state.frames.append(chunk)
    state.stats["frames_seen"] += len(chunk)
    closed = state.segmenter.ingest(jnp.asarray(chunk))
    state.pending.extend(chunk)
    return closed


def cluster_stage(state: SessionState, part: Partition,
                  aux_models: Sequence[AuxModel] = (),
                  annotation_fn=None) -> EmbedJob:
    """②–③ incremental clustering of one closed partition → embed job."""
    cfg = state.cfg
    lo = part.start - state.pending_base
    hi = part.end - state.pending_base
    pframes = np.stack(state.pending[lo:hi])
    vecs = frame_vectors(jnp.asarray(pframes), cfg.cluster_pool)
    res = cluster_partition(vecs, threshold=cfg.cluster_threshold,
                            max_clusters=cfg.max_clusters_per_partition)
    n = int(res.n_clusters)
    assign = np.asarray(res.assignments)
    index_local = np.asarray(res.index_frames)[:n]
    scene_id = state.stats["partitions"]
    members = [part.start + np.nonzero(assign == c)[0] for c in range(n)]
    aux_texts = None
    if aux_models and annotation_fn is not None:
        aux_texts = [build_aux_prompt(
            aux_models, pframes[int(index_local[j])],
            annotation_fn(part.start + int(index_local[j])))
            for j in range(n)]
    state.stats["partitions"] += 1
    state.stats["clusters"] += n
    return EmbedJob(sid=state.sid, scene_id=scene_id,
                    frames=pframes[index_local],
                    frame_ids=part.start + index_local,
                    member_lists=members, aux_texts=aux_texts)


def release_pending(state: SessionState, closed: List[Partition]) -> None:
    if closed:
        consumed = closed[-1].end - state.pending_base
        state.pending = state.pending[consumed:]
        state.pending_base = closed[-1].end


def commit_jobs(sessions: Mapping[int, SessionState], embedder,
                jobs: Sequence[EmbedJob]) -> int:
    """④ ONE batched MEM call over every index frame closed this tick,
    scattered into each owning session's memory with batched appends."""
    if not jobs:
        return 0
    frames = np.concatenate([j.frames for j in jobs])
    ids = np.concatenate([j.frame_ids for j in jobs])
    aux = None
    if any(j.aux_texts for j in jobs):
        aux = []
        for j in jobs:
            aux.extend(j.aux_texts or [""] * len(j.frame_ids))
    embs = embedder.embed_frames(frames, aux, frame_ids=ids)
    off = 0
    for j in jobs:
        n = len(j.frame_ids)
        st = sessions[j.sid]
        st.memory.insert_batch(
            embs[off:off + n], scene_ids=[j.scene_id] * n,
            index_frames=j.frame_ids, member_lists=j.member_lists)
        st.stats["frames_embedded"] += n
        off += n
    return len(ids)


# ---------------------------------------------------------------------------
# Fused sampling → AKR → reservoir expansion (cross-session, on device)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("theta", "beta", "n_max"))
def _fused_akr_expand(probs, keys, members, counts, u, *, theta, beta,
                      n_max):
    """probs (S,Q,cap) + keys (S,Q) → AKR draws (S,Q,n_max) → member
    frame ids (S,Q,n_max), all in one program: the reservoir gather runs
    on the device-resident members stack, so nothing round-trips to host
    between sampling and expansion. Each (s, q) lane is bitwise the
    scalar ``akr_progressive`` + ``expand_draws`` chain for that key."""
    akr = jax.vmap(lambda p, k: rt.akr_progressive_batch(
        p, k, theta=theta, beta=beta, n_max=n_max))(probs, keys)
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, akr.draws, akr.valid)
    return akr, fids, ok


@functools.partial(jax.jit, static_argnames=("n",))
def _fused_sample_expand(probs, keys, members, counts, u, *, n):
    """Fixed-budget variant: n draws per lane, every slot valid."""
    draws, _ = jax.vmap(lambda p, k: rt.sampling_retrieve_batch(
        p, k, n))(probs, keys)
    valid = jnp.ones(draws.shape, bool)
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, draws, valid)
    return draws, fids, ok


# ---------------------------------------------------------------------------
# Session manager
# ---------------------------------------------------------------------------


class SessionManager:
    """N concurrent streams sharing one embedder and one jit cache."""

    def __init__(self, cfg: VenusConfig, embedder, embed_dim: int,
                 aux_models: Sequence[AuxModel] = (), annotation_fn=None):
        self.cfg = cfg
        self.embedder = embedder
        self.embed_dim = embed_dim
        self.aux_models = list(aux_models)
        self.annotation_fn = annotation_fn
        self.sessions: Dict[int, SessionState] = {}
        self._next_sid = 0
        self._stacks: Dict[Tuple[int, ...], MemoryStack] = {}
        # per-session scans vs fused cross-session scans, for the "one
        # scan per query tick" invariant (tests/benches assert on these)
        self.io_stats = {"scans": 0, "fused_scans": 0, "device_expands": 0}

    # ------------------------------------------------------------- lifecycle
    def create_session(self, sid: Optional[int] = None) -> int:
        if sid is None:
            sid = self._next_sid
        assert sid not in self.sessions, sid
        self._next_sid = max(self._next_sid, sid) + 1
        self.sessions[sid] = SessionState(sid, self.cfg, self.embed_dim)
        return sid

    def __getitem__(self, sid: int) -> SessionState:
        return self.sessions[sid]

    def __len__(self) -> int:
        return len(self.sessions)

    # ------------------------------------------------------------- ingestion
    def ingest_tick(self, chunks: Mapping[int, np.ndarray]
                    ) -> Dict[str, float]:
        """Consume one chunk per stream; embed everything that closed
        across ALL streams in one batched MEM call. Returns stage
        timings for the tick."""
        t0 = time.perf_counter()
        closed_by_sid = {sid: segment_stage(self.sessions[sid], chunk)
                         for sid, chunk in chunks.items()}
        t_seg = time.perf_counter()
        jobs: List[EmbedJob] = []
        for sid, closed in closed_by_sid.items():
            st = self.sessions[sid]
            for part in closed:
                jobs.append(cluster_stage(st, part, self.aux_models,
                                          self.annotation_fn))
            release_pending(st, closed)
        t_clu = time.perf_counter()
        n_emb = commit_jobs(self.sessions, self.embedder, jobs)
        t_emb = time.perf_counter()
        return {"segment": t_seg - t0, "cluster": t_clu - t_seg,
                "embed_insert": t_emb - t_clu, "embedded": float(n_emb)}

    def flush(self, sids: Optional[Sequence[int]] = None) -> None:
        """Close every open partition and embed the remainder batched."""
        jobs: List[EmbedJob] = []
        for sid in (sids if sids is not None else list(self.sessions)):
            st = self.sessions[sid]
            for part in st.segmenter.flush():
                jobs.append(cluster_stage(st, part, self.aux_models,
                                          self.annotation_fn))
            st.pending = []
            st.pending_base = st.stats["frames_seen"]
        commit_jobs(self.sessions, self.embedder, jobs)

    # -------------------------------------------------------------- querying
    def query(self, sid: int, text: str, *, budget: Optional[int] = None,
              use_akr: bool = True, query_emb: Optional[np.ndarray] = None
              ) -> QueryResult:
        """Single-query path (budget set ⇒ fixed-N sampling; else AKR)."""
        cfg = self.cfg
        st = self.sessions[sid]
        timings: Dict[str, float] = {}
        t0 = time.perf_counter()
        if query_emb is None:
            query_emb = self.embedder.embed_query(text)
        timings["embed_query"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sims, probs = st.memory.search(jnp.asarray(query_emb)[None],
                                       tau=cfg.tau)
        self.io_stats["scans"] += 1
        probs0 = probs[0]
        timings["similarity"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sub = st.next_keys(1)[0]
        if budget is not None and not use_akr:
            draws, _ = rt.sampling_retrieve(probs0, sub, budget)
            valid = np.ones((budget,), bool)
            n_drawn, mass = budget, float("nan")
        else:
            n_max = budget if budget is not None else cfg.n_max
            res = rt.akr_progressive(probs0, sub, theta=cfg.theta,
                                     beta=cfg.beta, n_max=n_max)
            draws, valid = np.asarray(res.draws), np.asarray(res.valid)
            n_drawn, mass = int(res.n_drawn), float(res.mass)
        timings["sampling"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        frame_ids = st.memory.expand_draws(np.asarray(draws), valid,
                                           seed=cfg.seed)
        timings["expand"] = time.perf_counter() - t0
        return QueryResult(frame_ids=frame_ids, draws=np.asarray(draws),
                           n_drawn=n_drawn, mass=mass, timings=timings)

    def query_batch(self, sid: int, texts: Optional[Sequence[str]] = None,
                    *, query_embs: Optional[np.ndarray] = None,
                    budget: Optional[int] = None, use_akr: bool = True
                    ) -> List[QueryResult]:
        """Q queries through ONE similarity scan + vmapped sampling/AKR +
        vectorised expansion. Draws the same per-query subkeys as Q
        sequential ``query`` calls, so results match query-for-query."""
        cfg = self.cfg
        st = self.sessions[sid]
        timings: Dict[str, float] = {}
        t0 = time.perf_counter()
        if query_embs is None:
            query_embs = self.embedder.embed_queries(list(texts))
        qe = jnp.asarray(query_embs)
        qn = qe.shape[0]
        timings["embed_query"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sims, probs = st.memory.search(qe, tau=cfg.tau)     # (Q, cap)
        self.io_stats["scans"] += 1
        timings["similarity"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        keys = st.next_keys(qn)
        if budget is not None and not use_akr:
            draws, _ = rt.sampling_retrieve_batch(probs, keys, budget)
            draws = np.asarray(draws)
            valid = np.ones((qn, budget), bool)
            n_drawn = np.full((qn,), budget)
            mass = np.full((qn,), np.nan)
        else:
            n_max = budget if budget is not None else cfg.n_max
            res = rt.akr_progressive_batch(probs, keys, theta=cfg.theta,
                                           beta=cfg.beta, n_max=n_max)
            draws, valid = np.asarray(res.draws), np.asarray(res.valid)
            n_drawn, mass = np.asarray(res.n_drawn), np.asarray(res.mass)
        timings["sampling"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        frame_lists = st.memory.expand_draws_batch(draws, valid,
                                                   seed=cfg.seed)
        timings["expand"] = time.perf_counter() - t0
        # timings are whole-batch stage times; each result gets its own
        # copy so callers can annotate without aliasing the others
        return [QueryResult(frame_ids=frame_lists[i], draws=draws[i],
                            n_drawn=int(n_drawn[i]), mass=float(mass[i]),
                            timings=dict(timings)) for i in range(qn)]

    def query_batch_cross(self, sids: Sequence[int],
                          texts: Optional[Sequence[str]] = None, *,
                          query_embs: Optional[np.ndarray] = None,
                          budget: Optional[int] = None,
                          use_akr: bool = True) -> List[QueryResult]:
        """Queries against SEVERAL sessions through ONE fused scan.

        ``sids[j]`` is the session query j targets. The queries are
        packed into a per-session padded block (S, Qmax, d), scanned over
        the ``MemoryStack`` in a single kernel launch, and sampled +
        expanded by one jit'd program over the device-resident members
        stack — zero host-side reservoir gathers. Each session's PRNG
        chain advances by exactly its own query count (padding lanes
        consume dummy keys), so results are equivalent query-for-query
        to per-session ``query_batch`` calls and to sequential
        ``query`` calls. Results come back in input order."""
        cfg = self.cfg
        sids = [int(s) for s in sids]
        timings: Dict[str, float] = {}
        t0 = time.perf_counter()
        if query_embs is None:
            query_embs = self.embedder.embed_queries(list(texts))
        qe = np.asarray(query_embs, np.float32)
        assert len(sids) == qe.shape[0]

        # group by session, preserving within-session arrival order (the
        # order the per-session subkey chain is consumed in)
        order: Dict[int, List[int]] = {}
        for j, sid in enumerate(sids):
            order.setdefault(sid, []).append(j)
        group_sids = sorted(order)
        sn = len(group_sids)
        qmax = max(len(order[s]) for s in group_sids)
        q_stack = np.zeros((sn, qmax, qe.shape[1]), np.float32)
        key_rows = []
        for si, sid in enumerate(group_sids):
            idxs = order[sid]
            q_stack[si, :len(idxs)] = qe[idxs]
            ks = self.sessions[sid].next_keys(len(idxs))
            if len(idxs) < qmax:      # padding lanes: dummy keys, results
                pad = jax.random.split(jax.random.key(0), qmax - len(idxs))
                ks = jnp.concatenate([ks, pad])
            key_rows.append(ks)
        keys = jnp.stack(key_rows)                          # (S, Qmax)
        timings["embed_query"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        stack = self.memory_stack(tuple(group_sids))
        sims, probs = stack.search(jnp.asarray(q_stack), tau=cfg.tau)
        self.io_stats["fused_scans"] += 1
        timings["similarity"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        members, counts = stack.device_members()
        if budget is not None and not use_akr:
            u = jnp.asarray(VenusMemory.expand_u(cfg.seed, budget),
                            jnp.int32)
            draws, fids, ok = _fused_sample_expand(
                probs, keys, members, counts, u, n=budget)
            draws = np.asarray(draws)
            n_drawn = np.full((sn, qmax), budget)
            mass = np.full((sn, qmax), np.nan)
        else:
            n_max = budget if budget is not None else cfg.n_max
            u = jnp.asarray(VenusMemory.expand_u(cfg.seed, n_max),
                            jnp.int32)
            akr, fids, ok = _fused_akr_expand(
                probs, keys, members, counts, u,
                theta=cfg.theta, beta=cfg.beta, n_max=n_max)
            draws = np.asarray(akr.draws)
            n_drawn, mass = np.asarray(akr.n_drawn), np.asarray(akr.mass)
        self.io_stats["device_expands"] += 1
        fids, ok = np.asarray(fids), np.asarray(ok)
        timings["sample_expand"] = time.perf_counter() - t0

        results: List[Optional[QueryResult]] = [None] * len(sids)
        for si, sid in enumerate(group_sids):
            for qi, j in enumerate(order[sid]):
                frame_ids = np.unique(
                    fids[si, qi][ok[si, qi]].astype(np.int64))
                results[j] = QueryResult(
                    frame_ids=frame_ids, draws=draws[si, qi],
                    n_drawn=int(n_drawn[si, qi]),
                    mass=float(mass[si, qi]), timings=dict(timings))
        return results

    # stacked device views are ~S×(index + members) buffers each; bound
    # how many distinct session subsets stay cached (LRU) so arbitrary
    # query groupings can't grow device memory without limit
    MAX_CACHED_STACKS = 8

    def memory_stack(self, sids: Tuple[int, ...]) -> MemoryStack:
        """The cached ``MemoryStack`` over the given session tuple."""
        stk = self._stacks.pop(sids, None)
        if stk is None:
            stk = MemoryStack([self.sessions[s].memory for s in sids])
            while len(self._stacks) >= self.MAX_CACHED_STACKS:
                self._stacks.pop(next(iter(self._stacks)))
        self._stacks[sids] = stk          # re-insert = mark most recent
        return stk

    def query_topk(self, sid: int, text: str, k: int,
                   query_emb: Optional[np.ndarray] = None) -> np.ndarray:
        st = self.sessions[sid]
        if query_emb is None:
            query_emb = self.embedder.embed_query(text)
        # same device-index path as query/query_batch: the scan runs over
        # memory.search so io_stats (uploads + scans) stays accountable
        sims, _ = st.memory.search(jnp.asarray(query_emb)[None],
                                   tau=self.cfg.tau)
        self.io_stats["scans"] += 1
        _, valid = st.memory.device_index()
        idx = rt.topk_retrieve(sims[0], valid, k)
        return st.memory.index_frames(np.asarray(idx))
