"""Session layer: multi-stream, batch-first Venus (paper Fig. 6 at scale).

The monolithic single-stream system is decomposed into composable
per-stream stages operating on a ``SessionState``:

* ``segment_stage``   — chunk → closed scene partitions (①),
* ``cluster_stage``   — one closed partition → an ``EmbedJob`` holding
  its centroid index frames + cluster membership (②–③),
* ``commit_jobs``     — ALL embed jobs closed in one tick, across every
  session, concatenated into a SINGLE jit'd MEM call, then scattered
  into each session's device-resident memory with batched appends (④).

``SessionManager`` owns N concurrent streams (the edge box's cameras)
and drives the stages. By default every session's memory lives inside
one shared ``MemoryArena`` — device-resident ``(S, capacity, …)``
super-buffers that tick appends extend in place with donated writes, so
the fused query path scans the arena buffers directly and NO
ingest↔query interleaving ever restacks anything
(``io_stats["stack_rebuilds"]`` stays 0; ``use_arena=False`` restores
the PR-2 detached memories + version-cached ``MemoryStack`` path).
Querying is declarative: ``plan(specs)`` groups ``QuerySpec``s into
execution groups and ``execute(plan)`` runs ONE fused similarity scan
per group over the arena (or stack) views plus vmapped per-strategy
post-processing (``repro.core.queryplan``). The legacy entry points —
``query``, ``query_batch``, ``query_batch_cross``, ``query_topk`` — are
thin shims over plan/execute and stay draw-for-draw identical to their
pre-redesign outputs (same per-session PRNG chains).

Sessions have a full LIFECYCLE (ARCHITECTURE.md draws the state
machine): ``create_session`` → ingest ⇄ query → (at capacity, with a
window ``EvictionPolicy``) evict ⇄ ingest/query → ``close_session`` →
slot reuse. Closing frees the session's arena slot into a free-list —
its lane scans as masked-out padding, no restack — and the next
``create_session`` recycles it after one donated device-side row
reset, so 24/7 churn holds the arena at its steady-state slot count.
Ownership here: the SessionManager owns the arena (and the embedder /
jit caches); each ``SessionState`` owns its host mirrors, PRNG chain,
segmenter, and raw-frame archive — which is why a closed session's
memory handle stays readable after detach while its device rows are
recycled under a new tenant.
"""

from __future__ import annotations

import contextlib
import os
import time
import weakref
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aux_models import AuxModel, build_aux_prompt
from repro.core.clustering import cluster_partition, frame_vectors
from repro.core.memory import (ArenaStackView, FrameStore, MemoryArena,
                               MemoryStack, VenusMemory)
from repro.core.queryplan import (QueryPlan, QueryResult, QuerySpec,
                                  build_plan, execute_plan)
from repro.core.scene import Partition, StreamSegmenter
from repro.core.standing import Alert, StandingRegistry

# live managers, so test harnesses can reset every launch/transfer
# counter between tests without threading references around
# (tests/conftest.py) — weak so managers die with their tests
_LIVE_MANAGERS: "weakref.WeakSet" = weakref.WeakSet()


def reset_all_io_stats() -> None:
    """Reset the io_stats of every live ``SessionManager`` (and their
    memories/arena). Test-isolation hook: launch-count assertions must
    not depend on which tests ran before them."""
    for mgr in list(_LIVE_MANAGERS):
        mgr.reset_io_stats()


@dataclass(frozen=True)
class VenusConfig:
    # ingestion
    scene_threshold: float = 0.075
    max_partition_len: int = 256
    cluster_threshold: float = 0.35
    max_clusters_per_partition: int = 16
    cluster_pool: int = 8
    # memory
    memory_capacity: int = 8192
    member_cap: int = 128
    # index storage dtype: "float32", or "int8" for the quantised index
    # (symmetric per-row int8 + f32 scales, quantised once at the append
    # scatter; scans stream 4× fewer bytes — see ARCHITECTURE.md)
    index_dtype: str = "float32"
    # lifecycle: what a session does when it outlives memory_capacity —
    # "none" (overflow raises; the pre-lifecycle contract),
    # "sliding_window" (device-side ring: evict the oldest rows, O(1)
    # head motion), "cluster_merge" (sliding window that first folds
    # evicted member reservoirs into similar surviving clusters), or
    # "consolidate" (evictees fold into the hierarchical coarse tier's
    # compressed summary rows — requires coarse_capacity > 0)
    eviction: str = "none"
    # cosine threshold for cluster_merge/consolidate folds: an evictee
    # joins its most similar survivor/summary only at >= this similarity
    # (None = the policy default, 0.8); validated in (0, 1] by
    # get_eviction_policy
    merge_threshold: Optional[float] = None
    # hierarchical two-level memory (ARCHITECTURE.md "Hierarchical
    # consolidation tier"): coarse_capacity > 0 gives every arena slot a
    # summary tier of ceil(capacity / coarse_block) block centroids plus
    # coarse_capacity consolidated rows; once consolidation populates
    # it, fused queries run the two-stage coarse-scan → winner-gather
    # path, streaming ~n_coarse + coarse_topb·coarse_block rows per
    # query instead of the full capacity
    coarse_capacity: int = 0
    coarse_block: int = 64
    coarse_topb: int = 4
    # disk spill tier (ARCHITECTURE.md "Storage tiers"): spill_dir set
    # turns FrameStore.trim into a DEMOTION — dropped host frames are
    # written to append-only npy segment files under
    # spill_dir/session-<sid>/ and get() faults them back through an
    # LRU segment cache, so every historical absolute id stays readable
    # (the paper's NVMe archive tier). host_retain additionally bounds
    # the HOST tier: _trim_archives demotes frames beyond the newest
    # host_retain even for eviction="none" sessions (closing their 24/7
    # RSS leak without breaking the keep-everything contract — the
    # history moves to disk instead of growing RSS forever).
    spill_dir: Optional[str] = None
    spill_segment_frames: int = 64
    spill_cache_segments: int = 4
    host_retain: Optional[int] = None
    # querying (Eq. 5-7)
    tau: float = 0.1
    theta: float = 0.9
    beta: float = 1.0
    n_max: int = 32
    seed: int = 0

    def __post_init__(self):
        if self.spill_segment_frames < 1:
            raise ValueError(
                f"spill_segment_frames must be >= 1, got "
                f"{self.spill_segment_frames}")
        if self.spill_cache_segments < 1:
            raise ValueError(
                f"spill_cache_segments must be >= 1, got "
                f"{self.spill_cache_segments}")
        if self.host_retain is not None:
            if self.spill_dir is None:
                raise ValueError(
                    "host_retain bounds the HOST tier by demoting cold "
                    "frames to disk — it requires spill_dir to be set "
                    "(without a spill tier, demotion would be deletion "
                    "and break the keep-everything contract)")
            if self.host_retain < 1:
                raise ValueError(
                    f"host_retain must be >= 1, got {self.host_retain}")


@dataclass
class EmbedJob:
    """One closed partition's centroid frames awaiting MEM embedding."""
    sid: int
    scene_id: int
    frames: np.ndarray                       # (n, H, W, 3) index frames
    frame_ids: np.ndarray                    # (n,) absolute frame ids
    member_lists: List[np.ndarray]           # per-cluster member frame ids
    aux_texts: Optional[List[str]]


class SessionState:
    """Per-stream state: segmenter, pending buffer, archive, memory."""

    def __init__(self, sid: int, cfg: VenusConfig, embed_dim: int,
                 arena: Optional[MemoryArena] = None,
                 slot: Optional[int] = None,
                 eviction: Optional[str] = None):
        self.sid = sid
        self.cfg = cfg
        self.segmenter = StreamSegmenter(
            threshold=cfg.scene_threshold,
            max_partition_len=cfg.max_partition_len)
        self.memory = VenusMemory(cfg.memory_capacity, embed_dim,
                                  cfg.member_cap, seed=cfg.seed,
                                  arena=arena, slot=slot,
                                  eviction=(cfg.eviction if eviction
                                            is None else eviction),
                                  index_dtype=cfg.index_dtype,
                                  merge_threshold=cfg.merge_threshold,
                                  coarse_capacity=cfg.coarse_capacity,
                                  coarse_block=cfg.coarse_block)
        spill = (None if cfg.spill_dir is None
                 else os.path.join(cfg.spill_dir, f"session-{sid:05d}"))
        self.frames = FrameStore(
            spill, segment_frames=cfg.spill_segment_frames,
            cache_segments=cfg.spill_cache_segments)
        self.pending: List[np.ndarray] = []   # frames not yet clustered
        self.pending_base = 0                 # abs index of pending[0]
        self.key = jax.random.key(cfg.seed)
        self.stats = {"frames_seen": 0, "frames_embedded": 0,
                      "partitions": 0, "clusters": 0,
                      "frames_trimmed": 0}

    def next_keys(self, n: int) -> jnp.ndarray:
        """Advance the session PRNG chain n steps — the same chain a
        sequence of n single queries would consume, so batched and
        sequential querying draw identical subkeys."""
        subs = []
        for _ in range(n):
            self.key, sub = jax.random.split(self.key)
            subs.append(sub)
        return jnp.stack(subs)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


def segment_stage(state: SessionState, chunk: np.ndarray) -> List[Partition]:
    """① scene segmentation: archive the chunk, return closed partitions."""
    chunk = np.asarray(chunk, np.float32)
    state.frames.append(chunk)
    state.stats["frames_seen"] += len(chunk)
    closed = state.segmenter.ingest(jnp.asarray(chunk))
    state.pending.extend(chunk)
    return closed


def cluster_stage(state: SessionState, part: Partition,
                  aux_models: Sequence[AuxModel] = (),
                  annotation_fn=None) -> EmbedJob:
    """②–③ incremental clustering of one closed partition → embed job."""
    cfg = state.cfg
    lo = part.start - state.pending_base
    hi = part.end - state.pending_base
    pframes = np.stack(state.pending[lo:hi])
    vecs = frame_vectors(jnp.asarray(pframes), cfg.cluster_pool)
    res = cluster_partition(vecs, threshold=cfg.cluster_threshold,
                            max_clusters=cfg.max_clusters_per_partition)
    n = int(res.n_clusters)
    assign = np.asarray(res.assignments)
    index_local = np.asarray(res.index_frames)[:n]
    scene_id = state.stats["partitions"]
    members = [part.start + np.nonzero(assign == c)[0] for c in range(n)]
    aux_texts = None
    if aux_models and annotation_fn is not None:
        aux_texts = [build_aux_prompt(
            aux_models, pframes[int(index_local[j])],
            annotation_fn(part.start + int(index_local[j])))
            for j in range(n)]
    state.stats["partitions"] += 1
    state.stats["clusters"] += n
    return EmbedJob(sid=state.sid, scene_id=scene_id,
                    frames=pframes[index_local],
                    frame_ids=part.start + index_local,
                    member_lists=members, aux_texts=aux_texts)


def release_pending(state: SessionState, closed: List[Partition]) -> None:
    if closed:
        consumed = closed[-1].end - state.pending_base
        state.pending = state.pending[consumed:]
        state.pending_base = closed[-1].end


def commit_jobs(sessions: Mapping[int, SessionState], embedder,
                jobs: Sequence[EmbedJob], *,
                standing: Optional[StandingRegistry] = None,
                io_stats: Optional[Dict[str, int]] = None) -> int:
    """④ ONE batched MEM call over every index frame closed this tick,
    scattered into each owning session's memory with batched appends.
    Arena-backed sessions defer their device writes into the tick's
    fused scatter (one donated program per super-buffer per tick, no
    matter how many sessions closed clusters). This is also where the
    eviction hook fires: a session at ``memory_capacity`` consults its
    ``EvictionPolicy`` inside ``insert_batch`` — a sliding-window
    session sheds exactly as many oldest rows as the tick closed (O(1)
    head motion; the new rows overwrite the evicted positions within
    the same deferred scatter), so a 24/7 stream ingests forever in
    constant DEVICE memory. The raw-frame ``FrameStore`` (the paper's
    NVMe archive layer) is bounded separately: after the tick's commits
    the manager trims every host frame below the session's live
    references — see ``SessionManager._trim_archives``.

    ``standing`` hooks the standing-query registry into the tick: the
    physical slots every ``insert_batch`` returns are collected per
    session and — after the deferred scatters flush — evaluated with
    ONE extra fused launch over only those new rows (never a
    full-capacity re-scan; see ``repro.core.standing``). Fired alerts
    land in the registry's priority queue; counters bump in
    ``io_stats``."""
    if not jobs:
        return 0
    # fail fast on eviction="none" sessions about to overflow: raising
    # here — before the embed call and the deferred scatter — names the
    # session and the fix, instead of a deep-in-scatter shape error
    # after embedding work is already spent
    incoming: Dict[int, int] = {}
    for j in jobs:
        incoming[j.sid] = incoming.get(j.sid, 0) + len(j.frame_ids)
    for sid, n_new in incoming.items():
        mem = sessions[sid].memory
        if mem.eviction.name == "none" and mem.size + n_new > mem.capacity:
            raise RuntimeError(
                f"session {sid}: memory full ({mem.size} rows + {n_new} "
                f"incoming > capacity {mem.capacity}) — enable eviction "
                f"or consolidation (VenusConfig(eviction='sliding_window'"
                f" | 'cluster_merge' | 'consolidate'))")
    frames = np.concatenate([j.frames for j in jobs])
    ids = np.concatenate([j.frame_ids for j in jobs])
    aux = None
    if any(j.aux_texts for j in jobs):
        aux = []
        for j in jobs:
            aux.extend(j.aux_texts or [""] * len(j.frame_ids))
    embs = embedder.embed_frames(frames, aux, frame_ids=ids)
    arenas = {id(a): a for a in
              (sessions[j.sid].memory.arena for j in jobs)
              if a is not None}
    new_by_sid: Dict[int, List[np.ndarray]] = {}
    with contextlib.ExitStack() as stack:
        for a in arenas.values():
            stack.enter_context(a.deferred_appends())
        off = 0
        for j in jobs:
            n = len(j.frame_ids)
            st = sessions[j.sid]
            phys = st.memory.insert_batch(
                embs[off:off + n], scene_ids=[j.scene_id] * n,
                index_frames=j.frame_ids, member_lists=j.member_lists)
            new_by_sid.setdefault(j.sid, []).append(phys)
            st.stats["frames_embedded"] += n
            off += n
    if standing is not None:
        standing.evaluate(sessions, new_by_sid, io_stats)
    return len(ids)


# ---------------------------------------------------------------------------
# Session manager
# ---------------------------------------------------------------------------


class SessionManager:
    """N concurrent streams sharing one embedder and one jit cache."""

    def __init__(self, cfg: VenusConfig, embedder, embed_dim: int,
                 aux_models: Sequence[AuxModel] = (), annotation_fn=None,
                 *, use_arena: bool = True, mesh=None,
                 double_buffer: Optional[bool] = None):
        self.cfg = cfg
        self.embedder = embedder
        self.embed_dim = embed_dim
        self.aux_models = list(aux_models)
        self.annotation_fn = annotation_fn
        self.sessions: Dict[int, SessionState] = {}
        self._next_sid = 0
        self._stacks: Dict[Tuple[int, ...], MemoryStack] = {}
        # grow-in-place arena (default): sessions allocate their device
        # rows inside shared (S, capacity, …) super-buffers, so queries
        # never restack grown sessions. use_arena=False restores the
        # PR-2 detached memories + version-cached MemoryStack path.
        # mesh= shards the arena's slot axis over the mesh's "model"
        # axis (slabs of contiguous slots per device; the fused scans
        # fan out per shard under shard_map). double_buffer defaults on
        # whenever a mesh is given — ingest scatters target the back
        # buffer set so they overlap the fused query launches — and can
        # be forced either way explicitly.
        self.use_arena = use_arena
        self.mesh = mesh
        self.double_buffer = ((mesh is not None) if double_buffer is None
                              else double_buffer)
        self.arena: Optional[MemoryArena] = None
        # per-session scans vs fused cross-session scans, for the "one
        # scan per query tick" invariant (tests/benches assert on these);
        # group_scans counts every executor launch regardless of S;
        # stack_rebuilds counts device-side restacks of session buffers
        # (MUST stay 0 in arena mode — the zero-restack invariant)
        self.io_stats = {"scans": 0, "fused_scans": 0,
                         "device_expands": 0, "group_scans": 0,
                         "stack_rebuilds": 0, "sessions_closed": 0,
                         "sharded_group_scans": 0,
                         "two_stage_groups": 0,
                         "archive_trimmed_frames": 0,
                         "alerts_fired": 0, "alerts_suppressed": 0}
        # standing queries: persistent per-session QuerySpecs evaluated
        # inside commit_jobs against each tick's newly committed rows
        # (one extra slab launch per tick — see repro.core.standing)
        self.standing = StandingRegistry(cfg)
        # summed io_stats of closed sessions' memories: keeps the
        # service-level mem_* monitoring counters monotonic across
        # stream closes (a popped session takes its live dict with it)
        self.closed_mem_stats: Dict[str, int] = {}
        # same treatment for closed sessions' FrameStore spill counters
        # (close_session deletes the store's segments, so the counters
        # must be folded here first to stay monotonic)
        self.closed_frame_stats: Dict[str, int] = {}
        self._arena_stack: Optional[ArenaStackView] = None
        _LIVE_MANAGERS.add(self)

    def reset_io_stats(self, *, include_memories: bool = True) -> None:
        """Zero the scan counters (dict identity preserved) and, by
        default, every session memory's (and the arena's) transfer
        counters too — so benchmarks/tests can assert per-phase counts
        without rebuilding the manager."""
        for k in self.io_stats:
            self.io_stats[k] = 0
        if include_memories:
            self.closed_mem_stats.clear()
            self.closed_frame_stats.clear()
            for st in self.sessions.values():
                st.memory.reset_io_stats()
                st.frames.reset_io_stats()
            if self.arena is not None:
                self.arena.reset_io_stats()

    # ------------------------------------------------------------- lifecycle
    #
    # A session's memory walks one state machine (ARCHITECTURE.md):
    #   create → ingest ⇄ query → [evict ⇄ ingest/query] → close → reuse
    # ``create_session`` allocates — or, after a ``close_session``,
    # RECYCLES — an arena slot; ``close_session`` frees the slot into
    # the arena free-list (its lane scans as masked-out padding, so no
    # restack ever happens while holes exist); eviction runs inside
    # ``commit_jobs`` via each memory's ``EvictionPolicy``.

    def create_session(self, sid: Optional[int] = None, *,
                       eviction: Optional[str] = None) -> int:
        """Open a stream. Arena mode allocates a slot — reusing a freed
        one (a single donated device-side row reset, no growth) when the
        free-list is non-empty. ``eviction`` overrides ``cfg.eviction``
        for this session only (e.g. one 24/7 stream among bounded
        ones)."""
        if sid is None:
            sid = self._next_sid
        assert sid not in self.sessions, sid
        self._next_sid = max(self._next_sid, sid) + 1
        arena = slot = None
        if self.use_arena:
            if self.arena is None:
                self.arena = MemoryArena(
                    self.cfg.memory_capacity, self.embed_dim,
                    self.cfg.member_cap,
                    index_dtype=self.cfg.index_dtype, mesh=self.mesh,
                    double_buffer=self.double_buffer,
                    coarse_capacity=self.cfg.coarse_capacity,
                    coarse_block=self.cfg.coarse_block)
            arena, slot = self.arena, self.arena.add_session()
        self.sessions[sid] = SessionState(sid, self.cfg, self.embed_dim,
                                          arena=arena, slot=slot,
                                          eviction=eviction)
        return sid

    def close_session(self, sid: int) -> Dict[str, int]:
        """End a stream and free its memory slot for reuse.

        The session's arena slot goes onto the free-list — its lane
        reads window ``(0, 0)`` and scans as masked-out padding, so
        closing costs no device work and triggers no restack — and the
        NEXT ``create_session`` recycles it after one donated row
        reset. The popped session's memory is detached from the arena
        first, so any handle the caller still holds reads the session's
        own host mirrors instead of rows that are about to be recycled.
        Frame storage is released on BOTH tiers: the host ``FrameStore``
        is dropped and its spill segment files are deleted, so a churn
        workload leaks neither RSS nor disk (the store's spill counters
        are folded into ``closed_frame_stats`` first, keeping the
        service-level sums monotonic). Returns the session's final
        ingest stats."""
        st = self.sessions.pop(sid)
        for k, v in st.memory.io_stats.items():
            self.closed_mem_stats[k] = self.closed_mem_stats.get(k, 0) + v
        for k, v in st.frames.io_stats.items():
            self.closed_frame_stats[k] = (
                self.closed_frame_stats.get(k, 0) + v)
        st.frames.close()
        # drop the session's standing specs: a recycled slot's next
        # tenant must not inherit the old tenant's triggers (already
        # fired alerts stay pollable — they reference history, which
        # outlives the stream)
        self.standing.drop_session(sid)
        self._stacks = {k: v for k, v in self._stacks.items()
                        if sid not in k}
        if self.arena is not None:
            slot = st.memory.slot
            st.memory.detach_from_arena()
            self.arena.release_slot(slot)
        self.io_stats["sessions_closed"] += 1
        return dict(st.stats)

    def __getitem__(self, sid: int) -> SessionState:
        return self.sessions[sid]

    def __len__(self) -> int:
        return len(self.sessions)

    # ------------------------------------------------------------- ingestion
    def ingest_tick(self, chunks: Mapping[int, np.ndarray]
                    ) -> Dict[str, float]:
        """Consume one chunk per stream; embed everything that closed
        across ALL streams in one batched MEM call. Returns stage
        timings for the tick."""
        t0 = time.perf_counter()
        closed_by_sid = {sid: segment_stage(self.sessions[sid], chunk)
                         for sid, chunk in chunks.items()}
        t_seg = time.perf_counter()
        jobs: List[EmbedJob] = []
        for sid, closed in closed_by_sid.items():
            st = self.sessions[sid]
            for part in closed:
                jobs.append(cluster_stage(st, part, self.aux_models,
                                          self.annotation_fn))
            release_pending(st, closed)
        t_clu = time.perf_counter()
        n_emb = commit_jobs(self.sessions, self.embedder, jobs,
                            standing=self.standing,
                            io_stats=self.io_stats)
        n_trim = self._trim_archives(chunks.keys())
        t_emb = time.perf_counter()
        return {"segment": t_seg - t0, "cluster": t_clu - t_seg,
                "embed_insert": t_emb - t_clu, "embedded": float(n_emb),
                "trimmed": float(n_trim)}

    def flush(self, sids: Optional[Sequence[int]] = None) -> None:
        """Close every open partition and embed the remainder batched."""
        jobs: List[EmbedJob] = []
        sids = list(sids if sids is not None else self.sessions)
        for sid in sids:
            st = self.sessions[sid]
            for part in st.segmenter.flush():
                jobs.append(cluster_stage(st, part, self.aux_models,
                                          self.annotation_fn))
            st.pending = []
            st.pending_base = st.stats["frames_seen"]
        commit_jobs(self.sessions, self.embedder, jobs,
                    standing=self.standing, io_stats=self.io_stats)
        self._trim_archives(sids)

    def _trim_archives(self, sids) -> int:
        """Bound the raw-frame archive: after a tick's commits, drop
        every host frame BELOW all of a session's live references —
        the min over (a) index_frame ids and count-masked member
        reservoirs of the rows inside the current ring window (so
        ``cluster_merge``'s folded members keep their evicted frames
        reachable and retained) and (b) ``pending_base`` (frames not
        yet clustered).

        Without a spill tier, only sessions with a window eviction
        policy trim — under ``eviction="none"`` nothing ever leaves the
        window, so the historical keep-everything archive contract is
        untouched — and the ``uniform`` query strategy (which draws
        arbitrary archive ids) is incompatible with window-evicting
        sessions: ``build_plan`` rejects that combination up front and
        trimmed ids fail fast in ``FrameStore.get`` rather than
        silently aliasing.

        With ``VenusConfig(spill_dir=...)`` the trim is a DEMOTION —
        dropped frames move to npy segments and fault back through
        ``get`` — which changes the policy in two ways: (1)
        ``host_retain`` bounds the host tier even for
        ``eviction="none"`` sessions (their cold frames demote instead
        of growing RSS forever; every id stays readable, so the
        keep-everything contract holds at the *store* level), and (2)
        window-evicting sessions may demote beyond the live-reference
        horizon too (a faulted read is legal now), so ``uniform`` and
        ``cluster_merge``'s folded-reservoir reads succeed from disk.
        Demoting below ``pending_base`` is safe with spill on: frames
        awaiting clustering are duplicated in ``SessionState.pending``,
        which is what ``cluster_stage`` reads. Each session's store is
        ``sync()``'d here — the tick boundary is the fsync/durability
        point for that tick's demotions."""
        trimmed = 0
        retain = self.cfg.host_retain
        for sid in sids:
            st = self.sessions[sid]
            fs = st.frames
            spill = fs.spill_enabled
            if st.memory.eviction.name == "none":
                if not (spill and retain is not None):
                    continue
                keep = len(fs) - retain
            else:
                keep = min(st.memory.min_live_frame(), st.pending_base)
                if spill and retain is not None:
                    keep = max(keep, len(fs) - retain)
            n = fs.trim(keep)
            if spill:
                fs.sync()
            if n:
                st.stats["frames_trimmed"] += n
                trimmed += n
        self.io_stats["archive_trimmed_frames"] += trimmed
        return trimmed

    # -------------------------------------------------------------- querying
    #
    # The declarative plan/execute pair is the ONE query path; everything
    # below it is a thin shim kept for API compatibility. All shims
    # preserve the per-session PRNG chains draw-for-draw (see
    # tests/test_crosssession.py + tests/test_queryplan.py).

    def plan(self, specs: Sequence[QuerySpec]) -> QueryPlan:
        """Group specs into execution groups (one fused scan each).
        Passing the live sessions lets the planner reject plans that
        could only fail deep in execution (e.g. ``uniform`` against a
        window-evicting session with no spill tier)."""
        return build_plan(specs, self.cfg, sessions=self.sessions)

    def execute(self, plan: QueryPlan, *, fused: bool = True,
                coarse: bool = True) -> List[QueryResult]:
        """Run a plan: ONE scan launch per group. ``fused=True`` (the
        default) resolves sampling/AKR/top-k groups inside the launch —
        draws and top-k come back instead of dense scores; strategies
        that genuinely need the (S, Q, cap) score tensor (BOLT/MDF/AKS,
        plus uniform) fall back to the dense scan per group regardless.
        ``fused=False`` forces the dense path for everything (debugging /
        A-B measurement escape hatch; results are draw-for-draw
        identical either way). ``coarse=False`` disables the two-stage
        coarse-tier path even when the arena holds consolidated summary
        rows (the flat-scan escape hatch — bit-identical to a build
        without a coarse tier)."""
        return execute_plan(self, plan, fused=fused, coarse=coarse)

    def query_specs(self, specs: Sequence[QuerySpec]) -> List[QueryResult]:
        """Convenience: ``execute(plan(specs))``."""
        return self.execute(self.plan(specs))

    # ------------------------------------------------------ standing queries
    #
    # The inverted loop: instead of ask-then-scan, a spec registered
    # here is evaluated inside every ingest tick's ``commit_jobs``
    # against ONLY that tick's newly committed rows (one extra fused
    # launch over the (G, max_new, d) new-row slab — never a
    # full-capacity re-scan; ``kops standing_scan_bytes`` pins it) and
    # fires ``Alert`` records through threshold + hysteresis + cooldown
    # debouncing. See repro.core.standing for the trigger semantics.

    def register_standing(self, sid: int, spec: QuerySpec, *,
                          threshold: float, hysteresis: float = 0.0,
                          cooldown_ticks: int = 0,
                          priority: float = 0.0) -> int:
        """Register a persistent query on ``sid``; returns its spec id.

        ``spec`` is validated through ``build_plan(standing=True)``
        (deterministic fused strategy — ``topk`` — and no explicit
        seed; budget/tau resolve exactly as an ad-hoc plan would, which
        is what makes standing scores bitwise comparable to ad-hoc
        ones). ``threshold`` is a raw cosine-similarity level (the
        fused scan's top-k scores); an alert fires when the best new
        row reaches it, then the spec re-arms only after the score
        falls to ``threshold - hysteresis`` and ``cooldown_ticks``
        committing ticks have drained. ``priority`` orders delivery in
        ``poll_alerts``. Text specs are embedded once, here."""
        assert sid in self.sessions, sid
        emb = spec.embedding
        if emb is None:
            emb = np.asarray(
                self.embedder.embed_queries([spec.text])[0], np.float32)
        return self.standing.register(
            sid, spec, emb, threshold=threshold, hysteresis=hysteresis,
            cooldown_ticks=cooldown_ticks, priority=priority,
            sessions=self.sessions)

    def unregister_standing(self, spec_id: int) -> None:
        """Remove one standing spec (already fired alerts stay
        pollable)."""
        self.standing.unregister(spec_id)

    def poll_alerts(self, max_alerts: Optional[int] = None
                    ) -> List[Alert]:
        """Drain pending standing-query alerts, priority-ordered
        (priority desc, score desc, tick, firing order)."""
        return self.standing.poll_alerts(max_alerts)

    @staticmethod
    def _legacy_strategy(budget: Optional[int], use_akr: bool) -> str:
        return "sampling" if (budget is not None and not use_akr) else "akr"

    def query(self, sid: int, text: str, *, budget: Optional[int] = None,
              use_akr: bool = True, query_emb: Optional[np.ndarray] = None
              ) -> QueryResult:
        """Single-query shim (budget set ⇒ fixed-N sampling; else AKR)."""
        return self.query_specs([QuerySpec(
            sid=sid, text=text, embedding=query_emb,
            strategy=self._legacy_strategy(budget, use_akr),
            budget=budget)])[0]

    def query_batch(self, sid: int, texts: Optional[Sequence[str]] = None,
                    *, query_embs: Optional[np.ndarray] = None,
                    budget: Optional[int] = None, use_akr: bool = True
                    ) -> List[QueryResult]:
        """Q same-session queries → one single-group plan → ONE scan.
        Draws the same per-query subkeys as Q sequential ``query`` calls,
        so results match query-for-query."""
        n = len(query_embs) if query_embs is not None else len(texts)
        return self.query_batch_cross(
            [sid] * n, texts, query_embs=query_embs, budget=budget,
            use_akr=use_akr)

    def query_batch_cross(self, sids: Sequence[int],
                          texts: Optional[Sequence[str]] = None, *,
                          query_embs: Optional[np.ndarray] = None,
                          budget: Optional[int] = None,
                          use_akr: bool = True) -> List[QueryResult]:
        """Queries against SEVERAL sessions through ONE fused scan.

        ``sids[j]`` is the session query j targets. All specs share one
        strategy/budget, so the planner emits a single execution group:
        one padded-stack scan + one fused sampling→expansion program,
        with each session's PRNG chain advancing by exactly its own
        query count. Results come back in input order."""
        sids = [int(s) for s in sids]
        strategy = self._legacy_strategy(budget, use_akr)
        if query_embs is not None:
            qe = np.asarray(query_embs, np.float32)
            assert len(sids) == qe.shape[0]
            specs = [QuerySpec(sid=s, embedding=qe[j], strategy=strategy,
                               budget=budget)
                     for j, s in enumerate(sids)]
        else:
            assert len(sids) == len(texts)
            specs = [QuerySpec(sid=s, text=t, strategy=strategy,
                               budget=budget)
                     for s, t in zip(sids, texts)]
        return self.query_specs(specs)

    # stacked device views are ~S×(index + members) buffers each; bound
    # how many distinct session subsets stay cached (LRU) so arbitrary
    # query groupings can't grow device memory without limit (arena-
    # covering stacks are views, not copies — they cost nothing extra)
    MAX_CACHED_STACKS = 8

    def scan_lanes(self, sids: Sequence[int]
                   ) -> Tuple[Optional[int], ...]:
        """The lanes one fused scan covers, in scan-lane order.

        Arena mode: ALWAYS one lane per arena SLOT, in slot order — the
        arena super-buffers ARE the scan operand, so a group targeting
        any subset of sessions still consumes them as-is. Lanes without
        queries are padding, and a FREE slot (closed session awaiting
        reuse) appears as ``None``: its window reads ``(0, 0)``, so the
        device-derived mask blanks it. Per-lane math is independent, so
        results for the queried lanes are bit-identical to a subset
        scan, and nothing ever restacks. Detached mode: exactly the
        requested sessions, stacked (and version-cached) on demand."""
        if self.arena is not None:
            by_slot = {st.memory.slot: s
                       for s, st in self.sessions.items()}
            return tuple(by_slot.get(k)
                         for k in range(self.arena.n_sessions))
        return tuple(sids)

    def memory_stack(self, lanes: Tuple[Optional[int], ...]):
        """The scan view over the given lanes.

        Lanes containing holes (``None`` — freed arena slots) get the
        zero-copy ``ArenaStackView``, whose lanes are the arena slots
        themselves. Hole-free lane tuples keep the cached
        ``MemoryStack`` (which detects full-arena coverage and aliases
        the super-buffers — still zero-copy, still zero rebuilds)."""
        if any(s is None for s in lanes):
            assert self.arena is not None
            if (self._arena_stack is None
                    or self._arena_stack.arena is not self.arena):
                self._arena_stack = ArenaStackView(self.arena)
            return self._arena_stack
        stk = self._stacks.pop(lanes, None)
        if stk is None:
            stk = MemoryStack([self.sessions[s].memory for s in lanes],
                              rebuild_stats=self.io_stats)
            while len(self._stacks) >= self.MAX_CACHED_STACKS:
                self._stacks.pop(next(iter(self._stacks)))
        self._stacks[lanes] = stk         # re-insert = mark most recent
        return stk

    def query_topk(self, sid: int, text: str, k: int,
                   query_emb: Optional[np.ndarray] = None) -> np.ndarray:
        """Greedy Top-K shim: same accounted device-index path as every
        other strategy (scan counted, no re-upload), frame ids in rank
        order via the device-resident index_frame table."""
        res = self.query_specs([QuerySpec(
            sid=sid, text=text, embedding=query_emb, strategy="topk",
            budget=k)])[0]
        return res.frame_ids
