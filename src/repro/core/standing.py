"""Standing queries: persistent triggers evaluated on the ingest path.

Venus's plan/execute pair is pull-based — a user asks, the system
scans. Surveillance/dashcam/broadcast deployments equally need the
inverted loop: a query registered ONCE ("alert when X appears") that
fires when matching content *arrives*. This module is that loop:

* ``StandingRegistry`` — a per-session registry of persistent
  ``QuerySpec``s (``SessionManager.register_standing``), each with a
  firing ``threshold``, two-sided ``hysteresis`` band, debounce
  ``cooldown_ticks``, and delivery ``priority``.
* ``evaluate`` — called from ``commit_jobs`` each tick with the
  PHYSICAL arena positions the tick's rows landed in. It gathers only
  those rows into a compact ``(G, max_new, d)`` slab (host mirrors —
  ring-wrap falls out of physical addressing, and ``quantise_rows``
  reproduces the arena's int8 rows bitwise) and runs ONE extra fused
  launch over it (``kops.fused_retrieve_stack(tier="standing")``),
  never a full-capacity re-scan: the streamed bytes — counted into
  ``standing_scan_bytes`` — are O(new_rows · d) by construction
  because the slab IS the operand.
* ``Alert`` — fired records, delivered priority-ordered (priority
  desc, score desc, tick, registration order) through
  ``poll_alerts()`` / ``on_alert`` callbacks.

Determinism contract (the differential harness in
``tests/test_standing.py`` pins it): a standing evaluation's per-spec
scores and frame ids are BITWISE what an ad-hoc top-k ``QuerySpec``
executed against the same rows produces. That holds because top-k
scores are masked cosine similarities — per-lane math independent of
operand padding, tau, and the other lanes — and ``lax.top_k``'s
prefix is stable under larger k, so batching specs of different
budgets into one launch changes nothing. Standing evaluation is
fully deterministic (top-k only, no draws): it never touches a
session's PRNG chain, so replayed tick sequences fire the identical
alert stream draw-for-draw.

Trigger state machine, per spec, stepped only on ticks that committed
new rows for its session (the crossing/fire/re-arm decisions run
device-side as one jitted program over all evaluated specs):

    cooldown = max(cooldown - 1, 0)
    crossed  = score >= threshold
    fire     = crossed and armed and cooldown == 0
               → emit Alert, armed = False, cooldown = cooldown_ticks
    crossed and not fire → alerts_suppressed += 1 (debounced)
    score <= threshold - hysteresis → armed = True   (re-arm band)

``hysteresis`` widens the re-arm band below the threshold so a score
flapping around it fires once per excursion, not once per tick;
``cooldown_ticks`` additionally rate-limits re-fires after re-arming.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory import quantise_rows
from repro.core.queryplan import QuerySpec, build_plan
from repro.kernels import ops as kops

# masked top-k slots carry ref.NEG_INF (-1e30); anything above this is
# a real scored lane (same sentinel test as the two-stage executor)
_VALID_SCORE = -1e29


@dataclass
class Alert:
    """One standing-query firing. ``frame_ids`` are the matching new
    rows' index-frame ids in rank (score-descending) order, capped at
    the spec's budget; ``score`` is the best matching row's cosine
    similarity; ``tick`` is the registry's committing-tick counter."""
    sid: int
    spec_id: int
    frame_ids: np.ndarray
    score: float
    tick: int
    priority: float = 0.0


@dataclass
class StandingEntry:
    """A registered standing query plus its live trigger state."""
    spec_id: int
    sid: int
    spec: QuerySpec                 # validated, embedding resolved
    embedding: np.ndarray           # (d,) f32 query embedding
    budget: int                     # alert frame_ids cap (resolved k)
    threshold: float
    hysteresis: float
    cooldown_ticks: int
    priority: float
    armed: bool = True
    cooldown: int = 0


@jax.jit
def _trigger_step(score, armed, cooldown, threshold, hysteresis,
                  cooldown_ticks):
    """Device-side threshold crossing + hysteresis + cooldown for every
    evaluated spec at once: (L,) arrays in → (fire, suppressed,
    armed', cooldown') out. One excursion above the threshold fires at
    most once until the score falls back through the re-arm band
    (threshold − hysteresis) AND the cooldown has drained."""
    cd = jnp.maximum(cooldown - 1, 0)
    crossed = score >= threshold
    fire = crossed & armed & (cd == 0)
    suppressed = crossed & ~fire
    rearm = score <= threshold - hysteresis
    armed_out = jnp.where(fire, False, armed | rearm)
    cd_out = jnp.where(fire, cooldown_ticks, cd)
    return fire, suppressed, armed_out, cd_out


def _pow2(n: int, floor: int = 1) -> int:
    """Next power of two ≥ max(n, floor) — buckets the slab shapes so
    the per-tick launch compiles O(log) distinct shapes, while keeping
    the padded operand within 2× of the real new-row count (the
    ``standing_scan_bytes`` = O(new_rows · d) contract survives)."""
    v = max(int(n), floor)
    return 1 << (v - 1).bit_length()


class StandingRegistry:
    """Per-manager registry of standing queries + their alert queue.

    Owned by ``SessionManager`` (one per manager); ``commit_jobs``
    calls ``evaluate`` after the tick's deferred appends flush. All
    host state (entries, trigger state, the alert heap) lives here;
    the only device work per tick is the one slab launch plus the
    jitted trigger step.
    """

    def __init__(self, cfg):
        self.cfg = cfg
        self.entries: Dict[int, StandingEntry] = {}
        self.by_sid: Dict[int, List[int]] = {}
        self._next_id = 0
        self._seq = 0               # tie-break for the priority heap
        self.tick = 0               # committing ticks seen (Alert.tick)
        self._heap: List = []       # (-prio, -score, tick, seq, Alert)
        self._callbacks: List[Callable[[Alert], None]] = []

    # ---------------------------------------------------------- registration
    @property
    def n_specs(self) -> int:
        return len(self.entries)

    def register(self, sid: int, spec: QuerySpec, embedding: np.ndarray,
                 *, threshold: float, hysteresis: float = 0.0,
                 cooldown_ticks: int = 0, priority: float = 0.0,
                 sessions: Optional[Mapping[int, object]] = None) -> int:
        """Validate and register one standing spec; returns its id.

        ``build_plan(..., standing=True)`` does the spec-level
        validation (deterministic fused strategy, no explicit seed)
        and resolves the budget the same way an ad-hoc plan would —
        which is what keeps the differential harness honest."""
        if not np.isfinite(threshold):
            raise ValueError(f"threshold must be finite, got {threshold}")
        if hysteresis < 0:
            raise ValueError(
                f"hysteresis must be >= 0, got {hysteresis}")
        if cooldown_ticks < 0:
            raise ValueError(
                f"cooldown_ticks must be >= 0, got {cooldown_ticks}")
        spec = replace(spec, sid=int(sid))
        plan = build_plan([spec], self.cfg, sessions=sessions,
                          standing=True)
        key = plan.groups[0].key
        emb = np.asarray(embedding, np.float32).reshape(-1)
        spec_id = self._next_id
        self._next_id += 1
        self.entries[spec_id] = StandingEntry(
            spec_id=spec_id, sid=int(sid), spec=spec, embedding=emb,
            budget=int(key.budget), threshold=float(threshold),
            hysteresis=float(hysteresis),
            cooldown_ticks=int(cooldown_ticks),
            priority=float(priority))
        self.by_sid.setdefault(int(sid), []).append(spec_id)
        return spec_id

    def unregister(self, spec_id: int) -> None:
        e = self.entries.pop(spec_id)
        self.by_sid[e.sid].remove(spec_id)
        if not self.by_sid[e.sid]:
            del self.by_sid[e.sid]

    def drop_session(self, sid: int) -> int:
        """Remove every spec registered on ``sid`` (close_session /
        slot-recycle hook: a recycled slot's new tenant must not
        inherit the old tenant's triggers — no ghost-firing). Already
        fired alerts STAY pollable; they reference the closed stream's
        history, which outlives the stream."""
        ids = list(self.by_sid.get(int(sid), ()))
        for spec_id in ids:
            self.unregister(spec_id)
        return len(ids)

    # --------------------------------------------------------------- alerts
    def on_alert(self, callback: Callable[[Alert], None]) -> None:
        """Register a delivery callback: invoked once per fired alert,
        in priority order within each tick, right after the tick's
        evaluation. Alerts remain pollable regardless — callbacks
        observe the stream, ``poll_alerts`` drains it."""
        self._callbacks.append(callback)

    def poll_alerts(self, max_alerts: Optional[int] = None) -> List[Alert]:
        """Drain (up to ``max_alerts`` of) the pending alerts,
        priority-ordered: priority desc, then score desc, then tick,
        then firing order."""
        out: List[Alert] = []
        while self._heap and (max_alerts is None
                              or len(out) < max_alerts):
            out.append(heapq.heappop(self._heap)[-1])
        return out

    @property
    def pending_alerts(self) -> int:
        return len(self._heap)

    # ------------------------------------------------------------ evaluation
    def evaluate(self, sessions: Mapping[int, object],
                 new_by_sid: Mapping[int, Sequence[np.ndarray]],
                 io_stats: Optional[Dict[str, int]] = None
                 ) -> List[Alert]:
        """Evaluate every registered spec against ONLY the tick's new
        rows. ``new_by_sid`` maps sid → the list of physical-slot
        arrays ``insert_batch`` returned for that sid this tick, in
        commit order (chronological — which is also the order the rows
        occupy the slab, so top-k tie-breaks match an ad-hoc scan over
        the same rows).

        Returns the alerts fired this tick (already enqueued and
        delivered to callbacks)."""
        self.tick += 1
        live = [(sid, new_by_sid[sid]) for sid in sorted(new_by_sid)
                if self.by_sid.get(sid) and
                sum(len(p) for p in new_by_sid[sid])]
        if not live:
            return []
        # --- the (G, max_new, d) new-row slab -------------------------
        d = len(next(iter(self.entries.values())).embedding)
        ents = [[self.entries[i] for i in self.by_sid[sid]]
                for sid, _ in live]
        phys = [np.concatenate([np.asarray(p, np.int64) for p in plist])
                for _, plist in live]
        g = len(live)
        n_pad = _pow2(max(len(p) for p in phys))
        q_pad = _pow2(max(len(e) for e in ents))
        k = min(n_pad, max(e.budget for es in ents for e in es))
        slab = np.zeros((g, n_pad, d), np.float32)
        q_stack = np.zeros((g, q_pad, d), np.float32)
        sizes = np.zeros((g,), np.int32)
        ifr = np.zeros((g, n_pad), np.int64)
        for gi, ((sid, _), p) in enumerate(zip(live, phys)):
            mem = sessions[sid].memory
            slab[gi, :len(p)] = mem._emb[p]
            ifr[gi, :len(p)] = mem._index_frame[p]
            sizes[gi] = len(p)
            for qi, e in enumerate(ents[gi]):
                q_stack[gi, qi] = e.embedding
        index = slab
        if getattr(self.cfg, "index_dtype", "float32") == "int8":
            # per-row symmetric quantisation — bitwise the rows the
            # append scatter stored in the arena (scales cancel under
            # kernel row normalisation, exactly as on the query path)
            index, _ = quantise_rows(slab)
        # --- ONE fused launch over the slab (never the arena) ---------
        # Always unsharded: the slab is a fresh compact operand (like
        # the tiering stage-2 gather), so sharded-arena managers take
        # the identical path — same launch, same bytes, same alerts.
        fr = kops.fused_retrieve_stack(
            jnp.asarray(q_stack), jnp.asarray(index),
            tau=float(getattr(self.cfg, "tau", 0.1)),
            valid=jnp.asarray(sizes),
            targets=jnp.zeros((g, q_pad, 1), jnp.float32),
            n_topk=k, tier="standing")
        tv = np.asarray(fr.topk_v)          # (G, Q, K) masked sims
        ti = np.asarray(fr.topk_i)          # (G, Q, K) slab row indices
        # --- device-side trigger step over all evaluated specs --------
        flat = [(gi, qi, e) for gi, es in enumerate(ents)
                for qi, e in enumerate(es)]
        n_flat = len(flat)
        l_pad = _pow2(n_flat)
        score = np.full((l_pad,), -np.inf, np.float32)
        armed = np.zeros((l_pad,), bool)
        cooldown = np.zeros((l_pad,), np.int32)
        thr = np.full((l_pad,), np.inf, np.float32)
        hys = np.zeros((l_pad,), np.float32)
        cdt = np.zeros((l_pad,), np.int32)
        for li, (gi, qi, e) in enumerate(flat):
            score[li] = tv[gi, qi, 0]
            armed[li] = e.armed
            cooldown[li] = e.cooldown
            thr[li] = e.threshold
            hys[li] = e.hysteresis
            cdt[li] = e.cooldown_ticks
        fire, supp, armed_out, cd_out = (
            np.asarray(x) for x in _trigger_step(
                jnp.asarray(score), jnp.asarray(armed),
                jnp.asarray(cooldown), jnp.asarray(thr),
                jnp.asarray(hys), jnp.asarray(cdt)))
        fired: List[Alert] = []
        n_supp = 0
        for li, (gi, qi, e) in enumerate(flat):
            e.armed = bool(armed_out[li])
            e.cooldown = int(cd_out[li])
            if supp[li]:
                n_supp += 1
            if not fire[li]:
                continue
            kk = min(e.budget, k)
            vals = tv[gi, qi, :kk]
            sel = (vals >= e.threshold) & (vals > _VALID_SCORE)
            fids = ifr[gi, ti[gi, qi, :kk][sel]]
            fired.append(Alert(
                sid=e.sid, spec_id=e.spec_id, frame_ids=fids,
                score=float(tv[gi, qi, 0]), tick=self.tick,
                priority=e.priority))
        if io_stats is not None:
            io_stats["alerts_fired"] = (
                io_stats.get("alerts_fired", 0) + len(fired))
            io_stats["alerts_suppressed"] = (
                io_stats.get("alerts_suppressed", 0) + n_supp)
        for a in sorted(fired, key=lambda a: (-a.priority, -a.score)):
            heapq.heappush(self._heap,
                           (-a.priority, -a.score, a.tick, self._seq, a))
            self._seq += 1
            for cb in self._callbacks:
                cb(a)
        return fired
