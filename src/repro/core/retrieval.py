"""Query-relevant keyframe retrieval (paper §IV-D) + all baselines.

* ``sampling_retrieve`` — Eq. 5: N draws from the temperature-softmax
  distribution over indexed vectors (relevance + diversity).
* ``akr_progressive`` — Eq. 6/7: threshold-driven progressive sampling
  with the N_min lower bound and an N_max transmission-budget cap,
  implemented as a fixed-shape ``lax.while_loop`` (TPU needs static
  shapes; unsampled slots carry a validity mask).
* Baselines: greedy Top-K (the paper's "vanilla"), uniform sampling,
  MDF-style dominant-frame filtering, BOLT inverse-transform sampling,
  and an AKS-style judge-&-split selection. The latter three follow the
  cited papers' core selection rules (not their full pipelines — noted in
  DESIGN.md) so Table I/II-shaped comparisons are possible.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

# Canonical inverse-CDF draw primitives — defined once in
# ``kernels.draws`` (a leaf module) and shared verbatim with the fused
# kernel epilogue so fused draws are bit-identical to this module's
# materialised path. Re-exported here as the public retrieval API.
from repro.kernels.draws import (  # noqa: F401
    DRAW_BLK,
    DRAW_U_BITS,
    blockwise_cdf,
    categorical_from_targets,
    draw_targets,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Venus: fixed-budget sampling retrieval (Eq. 5)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def sampling_retrieve(probs: jnp.ndarray, key, n: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """probs: (cap,) — returns (draws (n,) int32, counts (cap,) int32)."""
    draws = categorical_from_targets(probs, draw_targets(key, n))
    counts = jnp.zeros(probs.shape, jnp.int32).at[draws].add(1)
    return draws, counts


@functools.partial(jax.jit, static_argnames=("n",))
def sampling_retrieve_batch(probs: jnp.ndarray, keys, n: int
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorised over queries: probs (Q, cap) + keys (Q,) — each lane
    draws exactly what ``sampling_retrieve`` would with its key."""
    return jax.vmap(lambda p, k: sampling_retrieve(p, k, n))(probs, keys)


# ---------------------------------------------------------------------------
# Venus: adaptive keyframe retrieval (Eq. 6 / 7)
# ---------------------------------------------------------------------------


class AKRResult(NamedTuple):
    draws: jnp.ndarray          # (n_max,) int32 sampled index per step
    valid: jnp.ndarray          # (n_max,) bool — slot actually drawn
    n_drawn: jnp.ndarray        # () int32 total draws
    mass: jnp.ndarray           # () f32 cumulative prob of distinct indices
    n_min: jnp.ndarray          # () int32 Eq. 7 lower bound


@functools.partial(jax.jit, static_argnames=("n_max",))
def akr_from_draws(draws: jnp.ndarray, drawn_p: jnp.ndarray,
                   p_max: jnp.ndarray, *, theta: float = 0.9,
                   beta: float = 1.0, n_max: int = 32) -> AKRResult:
    """Eq. 6/7 stopping rule over a precomputed draw sequence.

    ``draws``/``drawn_p`` are the n_max inverse-CDF draws and their
    probabilities (the full variate budget drawn up front); ``p_max`` is
    max pⱼ. The progressive loop is then pure arithmetic: distinct-ness
    of draw i is a pairwise compare against draws[:i], the running mass
    a sequential cumsum of the distinct-masked drawn probabilities, and
    the stop step the first n with mass/β ≥ θ and n ≥ N_min. Shared by
    the materialised path (gathered drawn_p) and the fused kernel path
    (crossing-accumulated drawn_p, p_max = 1/l) so both stop on
    bit-identical state.
    """
    n_min = (beta * jnp.ceil(theta / jnp.maximum(
        p_max, 1e-9))).astype(jnp.int32)
    n_min = jnp.minimum(jnp.maximum(n_min, 1), n_max)
    eq = draws[:, None] == draws[None, :]
    seen_before = jnp.any(jnp.tril(eq, k=-1), axis=-1)
    inc = jnp.where(seen_before, 0.0, drawn_p.astype(jnp.float32))
    cum = jnp.cumsum(inc)
    steps = jnp.arange(1, n_max + 1)
    done = (cum / beta >= theta) & (steps >= n_min)
    n_drawn = jnp.where(jnp.any(done), jnp.argmax(done) + 1,
                        n_max).astype(jnp.int32)
    valid = jnp.arange(n_max) < n_drawn
    mass = cum[n_drawn - 1]
    return AKRResult(jnp.where(valid, draws, -1).astype(jnp.int32),
                     valid, n_drawn, mass, n_min)


@functools.partial(jax.jit, static_argnames=("n_max",))
def akr_progressive(probs: jnp.ndarray, key, *, theta: float = 0.9,
                    beta: float = 1.0, n_max: int = 32) -> AKRResult:
    """Threshold-driven progressive sampling.

    Draw from P until the cumulative probability mass of the *distinct*
    selected indices satisfies mass/β ≥ θ (Eq. 6), with at least
    N_min = β·⌈θ / max pⱼ⌉ draws (Eq. 7) and at most n_max (bandwidth
    bound). Narrow queries (peaked P) stop after a few draws; dispersed
    queries keep sampling for coverage. The full n_max variate budget is
    drawn up front (one key consumption) and the stopping rule applied
    by ``akr_from_draws`` — identical draw-for-draw to the fused
    in-kernel path.
    """
    draws = categorical_from_targets(probs, draw_targets(key, n_max))
    drawn_p = probs[draws].astype(jnp.float32)
    return akr_from_draws(draws, drawn_p, jnp.max(probs), theta=theta,
                          beta=beta, n_max=n_max)


@functools.partial(jax.jit, static_argnames=("n_max",))
def akr_progressive_batch(probs: jnp.ndarray, keys, *, theta: float = 0.9,
                          beta: float = 1.0, n_max: int = 32) -> AKRResult:
    """Vectorised AKR over Q queries: probs (Q, cap) + keys (Q,).

    ``vmap`` of the ``while_loop`` runs until every lane terminates but
    masks per-lane updates, so each lane's draws/mass are identical to a
    sequential ``akr_progressive`` call with the same key."""
    fn = lambda p, k: akr_progressive(p, k, theta=theta, beta=beta,
                                      n_max=n_max)
    return jax.vmap(fn)(probs, keys)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def topk_retrieve(sims: jnp.ndarray, valid: jnp.ndarray, k: int
                  ) -> jnp.ndarray:
    """Greedy Top-K over similarity (the paper's vanilla; Fig. 5b/10)."""
    masked = jnp.where(valid, sims, NEG_INF)
    _, idx = jax.lax.top_k(masked, k)
    return idx.astype(jnp.int32)


def uniform_retrieve(total_frames: int, n: int) -> jnp.ndarray:
    """Uniform sampling baseline: fixed-interval frame ids."""
    return jnp.linspace(0, total_frames - 1, n).astype(jnp.int32)


def bolt_inverse_transform(sims: jnp.ndarray, valid: jnp.ndarray, n: int,
                           *, tau: float = 0.1) -> jnp.ndarray:
    """BOLT [arXiv CVPR'25]: inverse transform sampling — deterministic
    quantiles of the (time-ordered) similarity CDF."""
    logits = jnp.where(valid, sims / tau, NEG_INF)
    p = jax.nn.softmax(logits)
    cdf = jnp.cumsum(p)
    u = (jnp.arange(n) + 0.5) / n
    idx = jnp.searchsorted(cdf, u)
    return jnp.clip(idx, 0, sims.shape[0] - 1).astype(jnp.int32)


def mdf_retrieve(embs: jnp.ndarray, valid: jnp.ndarray, n: int,
                 *, sim_threshold: float = 0.95) -> jnp.ndarray:
    """MDF-style query-agnostic dominant-frame filtering: scan in time
    order, keep frames dissimilar to the last kept one, then uniformly
    sub-sample the kept set to n."""
    x = embs.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)

    def step(carry, inp):
        last, kept_count = carry
        v, ok = inp
        sim = jnp.sum(last * v)
        keep = ok & (sim < sim_threshold)
        last = jnp.where(keep, v, last)
        return (last, kept_count + keep.astype(jnp.int32)), keep

    (_, _), keep = jax.lax.scan(step, (jnp.zeros_like(x[0]),
                                       jnp.zeros((), jnp.int32)),
                                (x, valid))
    kept_idx = jnp.nonzero(keep, size=x.shape[0], fill_value=0)[0]
    n_kept = jnp.maximum(jnp.sum(keep.astype(jnp.int32)), 1)
    pick = (jnp.arange(n) * n_kept // n).astype(jnp.int32)
    return kept_idx[pick].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_retrieve_batch(sims: jnp.ndarray, valid: jnp.ndarray, k: int
                        ) -> jnp.ndarray:
    """Stacked Top-K: sims (S, Q, cap) + valid (S, cap) -> (S, Q, k).
    Each (s, q) lane is exactly ``topk_retrieve(sims[s, q], valid[s], k)``."""
    return jax.vmap(lambda s, v: jax.vmap(
        lambda sq: topk_retrieve(sq, v, k))(s))(sims, valid)


@functools.partial(jax.jit, static_argnames=("n",))
def uniform_retrieve_batch(total_frames: jnp.ndarray, n: int) -> jnp.ndarray:
    """Per-session uniform baseline: total_frames (S,) -> (S, n) frame
    ids; row s matches ``uniform_retrieve(total_frames[s], n)``."""
    return jax.vmap(lambda t: uniform_retrieve(t, n))(total_frames)


@functools.partial(jax.jit, static_argnames=("n",))
def bolt_inverse_transform_batch(sims: jnp.ndarray, valid: jnp.ndarray,
                                 n: int, *, tau: float = 0.1) -> jnp.ndarray:
    """Stacked BOLT: sims (S, Q, cap) + valid (S, cap) -> (S, Q, n)."""
    return jax.vmap(lambda s, v: jax.vmap(
        lambda sq: bolt_inverse_transform(sq, v, n, tau=tau))(s))(sims, valid)


@functools.partial(jax.jit, static_argnames=("n",))
def mdf_retrieve_batch(embs: jnp.ndarray, valid: jnp.ndarray, n: int,
                       *, sim_threshold: float = 0.95) -> jnp.ndarray:
    """Stacked MDF (query-agnostic): embs (S, cap, d) + valid (S, cap)
    -> (S, n); row s matches ``mdf_retrieve(embs[s], valid[s], n)``."""
    return jax.vmap(lambda e, v: mdf_retrieve(
        e, v, n, sim_threshold=sim_threshold))(embs, valid)


def aks_retrieve(sims: jnp.ndarray, valid: jnp.ndarray, n: int,
                 *, depth: int = 3) -> jnp.ndarray:
    """AKS-style judge-&-split: recursively split the timeline, allocate
    the frame budget proportionally to each half's relevance mass, then
    take top scores within leaf regions (coverage + relevance)."""
    cap = sims.shape[0]
    s = jnp.where(valid, sims, NEG_INF)
    mass = jnp.where(valid, jax.nn.softmax(jnp.where(valid, sims, NEG_INF)),
                     0.0)

    def alloc(lo: int, hi: int, budget: int, d: int):
        if budget <= 0:
            return []
        if d == 0 or hi - lo <= budget:
            region = s[lo:hi]
            k = min(budget, hi - lo)
            _, idx = jax.lax.top_k(region, k)
            return [idx + lo]
        mid = (lo + hi) // 2
        m_l = jnp.sum(mass[lo:mid])
        m_r = jnp.sum(mass[mid:hi])
        b_l = jnp.round(budget * m_l / jnp.maximum(m_l + m_r, 1e-9))
        b_l = int(jnp.clip(b_l, 0, budget))      # static via concretisation
        return (alloc(lo, mid, b_l, d - 1)
                + alloc(mid, hi, budget - b_l, d - 1))

    parts = alloc(0, cap, n, depth)
    idx = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.int32)
    pad = n - idx.shape[0]
    if pad > 0:
        idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
    return idx[:n].astype(jnp.int32)
