"""Declarative query plans: ``QuerySpec`` → planner → fused executor.

The querying stage is one algorithm family (a similarity scan over the
hierarchical memory followed by a selection rule), but the legacy API
exposed it through four divergent entry points of which only the
sampling/AKR pair reached the fused cross-session device path. This
module unifies all of it behind three layers:

* **QuerySpec** — a declarative description of ONE query against ONE
  session: text or precomputed embedding, retrieval strategy name,
  budget, per-query ``tau``/``theta``/``beta`` overrides, and a seed
  policy (``seed=None`` consumes the session's PRNG chain exactly like
  the legacy paths; an explicit seed derives a detached key and leaves
  the chain untouched).
* **Planner** (``build_plan``) — groups compatible specs into
  ``ExecutionGroup``s (same strategy + resolved budget + scan/sampling
  parameters → one padded block) and emits an explicit ``QueryPlan``
  the caller can inspect before running anything.
* **Executor** (``execute_plan``) — runs ONE scan launch per group and
  dispatches vmapped per-strategy post-processing, so every registered
  strategy — not just sampling/AKR — gets the "one scan, zero host
  gathers" path. For sampling/AKR/top-k groups that one launch is the
  FUSED retrieval scan (``kops.fused_retrieve_stack``): the inverse-CDF
  draws, drawn probabilities, and top-k resolve inside the kernel
  epilogue, so no (S, Q, cap) score tensor crosses the launch boundary
  (AKR's stop rule then runs over the already-computed draw state — no
  re-scoring). BOLT/MDF/AKS (and uniform) genuinely consume dense
  scores/embeddings, so their groups keep the materialising
  ``stack.search`` launch; ``execute_plan(..., fused=False)`` forces
  that dense path for every strategy (results are draw-for-draw
  identical — the fused epilogue computes the same canonical chunked
  CDF over the same probabilities). With the manager's ``MemoryArena``
  (the default) the scan operand IS the arena's grow-in-place
  super-buffers: every group scans all arena SLOTS in slot order (lanes
  without queries are padding, freed slots of closed sessions are
  ``None`` hole lanes whose ``(0, 0)`` windows mask them out — per-lane
  math is independent, so the queried lanes are bit-identical to a
  subset scan) and NO ingest↔query interleaving, close, or slot reuse
  ever restacks device buffers
  (``manager.io_stats["stack_rebuilds"]`` stays 0). The scan's
  ``valid`` operand is the arena's ``(S, 2)`` ``(start, size)`` window
  array — a session under sliding-window eviction is a device-side
  ring, so validity wraps; masks derive on device. Detached managers
  fall back to the per-group version-cached ``MemoryStack``.

Strategies live in a registry (``register_strategy`` / ``get_strategy``)
wrapping every selection rule in ``repro.core.retrieval`` behind a
common batched interface over ``(S, Q, cap)`` scan outputs. Each
strategy declares how its draws expand to raw frame ids:

* ``members`` — through the cluster member reservoirs, fused with the
  sampling itself into one jit'd device program (sampling, AKR);
* ``index``  — draws are memory slots mapped to their centroid frame id
  via the device-resident index_frame table (top-k, BOLT, MDF, AKS);
* ``raw``    — draws already are frame ids (uniform).

PRNG discipline: within a group, sessions are visited in sorted-sid
order and each session's chain advances by exactly its own chain-policy
query count (padding lanes consume dummy keys), so every legacy entry
point shimmed over this module stays draw-for-draw identical to its
pre-redesign output — see tests/test_crosssession.py and
tests/test_queryplan.py.

Ownership/staleness at this layer: the executor owns NOTHING — it
borrows device views (arena super-buffers or cached stacks) from the
manager per group, inside one call, and never caches them across
calls. That is what makes it safe against the arena's donation rule
(any ingest tick invalidates previously returned handles): each group
re-reads its views after the point where ticks could have run.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import retrieval as rt
from repro.core import tiering
from repro.core.memory import VenusMemory, expand_gather


# ---------------------------------------------------------------------------
# Specs and plans
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuerySpec:
    """One query against one session, declaratively.

    ``budget`` means "draw count" for sampling/uniform/BOLT/MDF/AKS,
    "k" for top-k, and "n_max" for AKR; ``None`` falls back to the
    manager config (``cfg.n_max``). ``tau``/``theta``/``beta`` override
    the config per query (``tau`` feeds both the scan softmax and
    BOLT's inverse-transform CDF). ``seed=None`` = chain policy (consume
    the session PRNG chain); an int detaches the query from the chain.
    """
    sid: int
    text: Optional[str] = None
    embedding: Optional[np.ndarray] = None
    strategy: str = "akr"
    budget: Optional[int] = None
    tau: Optional[float] = None
    theta: Optional[float] = None
    beta: Optional[float] = None
    seed: Optional[int] = None


class GroupKey(NamedTuple):
    """Resolved compatibility key: specs sharing it run as one block."""
    strategy: str
    budget: int
    tau: float
    theta: float
    beta: float


@dataclass
class ExecutionGroup:
    """One padded execution block: ONE fused scan answers every spec."""
    strategy: "RetrievalStrategy"
    key: GroupKey
    indices: List[int] = field(default_factory=list)   # spec positions
    order: Dict[int, List[int]] = field(default_factory=dict)

    @property
    def sids(self) -> Tuple[int, ...]:
        return tuple(sorted(self.order))

    @property
    def qmax(self) -> int:
        return max(len(v) for v in self.order.values())

    def describe(self) -> str:
        k = self.key
        return (f"{k.strategy}(budget={k.budget}, tau={k.tau:g}, "
                f"theta={k.theta:g}, beta={k.beta:g}) "
                f"sessions={list(self.sids)} queries={len(self.indices)}")


@dataclass
class QueryPlan:
    """The planner's output: inspectable before (or instead of) running."""
    specs: List[QuerySpec]
    groups: List[ExecutionGroup]

    @property
    def n_scans(self) -> int:
        """Fused scan launches this plan will cost — one per group."""
        return len(self.groups)

    def describe(self) -> str:
        lines = [f"QueryPlan: {len(self.specs)} specs -> "
                 f"{len(self.groups)} groups ({self.n_scans} scans)"]
        lines += [f"  group {i}: {g.describe()}"
                  for i, g in enumerate(self.groups)]
        return "\n".join(lines)


def build_plan(specs: Sequence[QuerySpec], cfg,
               sessions: Optional[Mapping[int, object]] = None, *,
               standing: bool = False) -> QueryPlan:
    """Group compatible specs into execution groups.

    ``cfg`` supplies the ``tau``/``theta``/``beta``/``n_max`` defaults
    (any object with those attributes — ``VenusConfig`` in practice).
    Groups are emitted in first-spec-appearance order; within a group,
    sessions run in sorted-sid order and each session's queries keep
    arrival order (the order its PRNG chain is consumed in).

    When ``sessions`` (sid → session state) is provided — the
    ``SessionManager.plan`` path — the planner also validates strategy
    ↔ session compatibility at PLAN time: the ``uniform`` strategy
    draws arbitrary archive frame ids, so against a window-evicting
    session whose ``FrameStore`` has no spill tier it is rejected here
    with a clear error instead of the deep ``IndexError`` the read
    would otherwise hit. With spill enabled the trimmed frames fault
    back from disk, so ``uniform`` is legal again and no check fires.

    ``standing=True`` is the validation mode the standing-query
    registry runs at registration time (``core.standing``): the spec
    must resolve — with the SAME GroupKey resolution as an ad-hoc plan,
    which is what keeps the differential bit-identity claim honest —
    but under the ingest-path evaluation contract: a deterministic
    strategy the fused kernel epilogue computes in-launch (``topk``;
    the stochastic strategies would consume the session PRNG chain
    from inside ingest ticks, silently perturbing every subsequent
    ad-hoc query) and no explicit ``seed`` (standing evaluation never
    draws, so a seed could only signal a misunderstanding).
    """
    specs = list(specs)
    groups: Dict[GroupKey, ExecutionGroup] = {}
    for j, spec in enumerate(specs):
        if spec.text is None and spec.embedding is None:
            raise ValueError(f"spec {j}: needs text or embedding")
        strat = get_strategy(spec.strategy)
        if standing:
            if strat.stochastic or strat.name not in _FUSED_STRATEGIES:
                raise ValueError(
                    f"spec {j}: strategy {strat.name!r} cannot run as a "
                    f"standing query — the ingest-path evaluation is "
                    f"deterministic and resolves inside the fused "
                    f"launch, so only non-stochastic fused strategies "
                    f"('topk') are accepted (stochastic strategies "
                    f"would consume the session PRNG chain per ingest "
                    f"tick)")
            if spec.seed is not None:
                raise ValueError(
                    f"spec {j}: standing queries never draw, so an "
                    f"explicit seed has no effect — pass seed=None")
        if strat.name == "uniform" and sessions is not None:
            st = sessions.get(int(spec.sid))
            policy = (st.memory.eviction.name if st is not None
                      else "none")
            if (st is not None and policy != "none"
                    and not st.frames.spill_enabled):
                raise ValueError(
                    f"spec {j}: strategy 'uniform' draws arbitrary "
                    f"archive frame ids, but session {spec.sid} evicts "
                    f"with policy '{policy}' and has no spill tier — "
                    f"its trimmed frames are deleted, so uniform reads "
                    f"would IndexError in FrameStore.get. Use a "
                    f"members-expanding strategy, keep the session on "
                    f"eviction='none', or set VenusConfig(spill_dir=..."
                    f") so trimmed frames demote to disk and fault "
                    f"back in.")
        key = GroupKey(
            strategy=strat.name,
            budget=int(spec.budget if spec.budget is not None
                       else cfg.n_max),
            tau=float(spec.tau if spec.tau is not None else cfg.tau),
            theta=float(spec.theta if spec.theta is not None
                        else cfg.theta),
            beta=float(spec.beta if spec.beta is not None else cfg.beta))
        g = groups.get(key)
        if g is None:
            g = groups[key] = ExecutionGroup(strategy=strat, key=key)
        g.indices.append(j)
        g.order.setdefault(int(spec.sid), []).append(j)
    return QueryPlan(specs=specs, groups=list(groups.values()))


# ---------------------------------------------------------------------------
# Strategy registry: every retrieval.py selection rule, batched
# ---------------------------------------------------------------------------


class StrategyContext(NamedTuple):
    """Everything a strategy may post-process after the ONE fused scan."""
    sims: jnp.ndarray             # (S, Q, cap) cosine similarities
    probs: jnp.ndarray            # (S, Q, cap) temperature softmax
    valid: jnp.ndarray            # (S, cap) per-session slot validity
    emb: jnp.ndarray              # (S, cap, d) index embedding stack
    keys: Optional[jnp.ndarray]   # (S, Q) PRNG keys (stochastic only)
    total_frames: np.ndarray      # (S,) raw frames seen per session
    key: GroupKey                 # resolved strategy/budget/params
    qcount: np.ndarray            # (S,) real (non-padding) queries


class StrategyOutput(NamedTuple):
    draws: jnp.ndarray            # (S, Q, n) int32 — see strategy.expand
    valid: jnp.ndarray            # (S, Q, n) bool — slot actually drawn
    n_drawn: np.ndarray           # (S, Q) int
    mass: np.ndarray              # (S, Q) float (nan if undefined)


@dataclass(frozen=True)
class RetrievalStrategy:
    """A retrieval rule behind the common batched interface.

    ``run`` post-processes the scan outputs into draws; ``run_expand``
    (members strategies only) fuses selection + reservoir expansion into
    one jit'd device program, returning ``(output, frame_ids, ok)``.
    """
    name: str
    stochastic: bool              # consumes the session PRNG chain
    expand: str                   # "members" | "index" | "raw"
    run: Callable[[StrategyContext], StrategyOutput]
    run_expand: Optional[Callable] = None

    def __post_init__(self):
        assert self.expand in ("members", "index", "raw"), self.expand
        assert (self.run_expand is not None) == (self.expand == "members")


_REGISTRY: Dict[str, RetrievalStrategy] = {}


def register_strategy(strategy: RetrievalStrategy) -> RetrievalStrategy:
    assert strategy.name not in _REGISTRY, strategy.name
    _REGISTRY[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> RetrievalStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown retrieval strategy {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --- Venus sampling / AKR (expand through member reservoirs) ---------------


@functools.partial(jax.jit, static_argnames=("theta", "beta", "n_max"))
def _fused_akr_expand(probs, keys, members, counts, u, *, theta, beta,
                      n_max):
    """probs (S,Q,cap) + keys (S,Q) → AKR draws (S,Q,n_max) → member
    frame ids (S,Q,n_max), all in one program: the reservoir gather runs
    on the device-resident members stack, so nothing round-trips to host
    between sampling and expansion. Each (s, q) lane is bitwise the
    scalar ``akr_progressive`` + ``expand_draws`` chain for that key."""
    akr = jax.vmap(lambda p, k: rt.akr_progressive_batch(
        p, k, theta=theta, beta=beta, n_max=n_max))(probs, keys)
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, akr.draws, akr.valid)
    return akr, fids, ok


@functools.partial(jax.jit, static_argnames=("n",))
def _fused_sample_expand(probs, keys, members, counts, u, *, n):
    """Fixed-budget variant: n draws per lane, every slot valid."""
    draws, _ = jax.vmap(lambda p, k: rt.sampling_retrieve_batch(
        p, k, n))(probs, keys)
    valid = jnp.ones(draws.shape, bool)
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, draws, valid)
    return draws, fids, ok


def _run_sampling(ctx: StrategyContext) -> StrategyOutput:
    n = ctx.key.budget
    draws, _ = jax.vmap(lambda p, k: rt.sampling_retrieve_batch(
        p, k, n))(ctx.probs, ctx.keys)
    sq = draws.shape[:2]
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full(sq, n), np.full(sq, np.nan))


def _run_expand_sampling(ctx: StrategyContext, members, counts, u):
    draws, fids, ok = _fused_sample_expand(ctx.probs, ctx.keys, members,
                                           counts, u, n=ctx.key.budget)
    sq = draws.shape[:2]
    out = StrategyOutput(draws, jnp.ones(draws.shape, bool),
                         np.full(sq, ctx.key.budget), np.full(sq, np.nan))
    return out, fids, ok


def _run_akr(ctx: StrategyContext) -> StrategyOutput:
    k = ctx.key
    akr = jax.vmap(lambda p, kk: rt.akr_progressive_batch(
        p, kk, theta=k.theta, beta=k.beta, n_max=k.budget))(
            ctx.probs, ctx.keys)
    return StrategyOutput(akr.draws, akr.valid, np.asarray(akr.n_drawn),
                          np.asarray(akr.mass))


def _run_expand_akr(ctx: StrategyContext, members, counts, u):
    k = ctx.key
    akr, fids, ok = _fused_akr_expand(ctx.probs, ctx.keys, members,
                                      counts, u, theta=k.theta,
                                      beta=k.beta, n_max=k.budget)
    out = StrategyOutput(akr.draws, akr.valid, np.asarray(akr.n_drawn),
                         np.asarray(akr.mass))
    return out, fids, ok


# --- baselines (expand via the index_frame table, or raw frame ids) --------


def _run_topk(ctx: StrategyContext) -> StrategyOutput:
    k = ctx.key.budget
    draws = rt.topk_retrieve_batch(ctx.sims, ctx.valid, k)
    sq = draws.shape[:2]
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full(sq, k), np.full(sq, np.nan))


def _run_uniform(ctx: StrategyContext) -> StrategyOutput:
    n = ctx.key.budget
    per_s = rt.uniform_retrieve_batch(
        jnp.asarray(ctx.total_frames, jnp.int32), n)      # (S, n)
    s, q = ctx.sims.shape[:2]
    draws = jnp.broadcast_to(per_s[:, None, :], (s, q, n))
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full((s, q), n), np.full((s, q), np.nan))


def _run_bolt(ctx: StrategyContext) -> StrategyOutput:
    n = ctx.key.budget
    draws = rt.bolt_inverse_transform_batch(ctx.sims, ctx.valid, n,
                                            tau=ctx.key.tau)
    sq = draws.shape[:2]
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full(sq, n), np.full(sq, np.nan))


def _run_mdf(ctx: StrategyContext) -> StrategyOutput:
    n = ctx.key.budget
    per_s = rt.mdf_retrieve_batch(ctx.emb, ctx.valid, n)  # (S, n)
    s, q = ctx.sims.shape[:2]
    draws = jnp.broadcast_to(per_s[:, None, :], (s, q, n))
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full((s, q), n), np.full((s, q), np.nan))


def _run_aks(ctx: StrategyContext) -> StrategyOutput:
    """AKS's recursive budget split concretises per-region masses, so
    its post-processing is host-driven — the group still costs only the
    ONE fused scan; padding lanes are skipped entirely."""
    n = ctx.key.budget
    s, q = ctx.sims.shape[:2]
    rows = np.zeros((s, q, n), np.int32)
    for si in range(s):
        for qi in range(int(ctx.qcount[si])):
            rows[si, qi] = np.asarray(rt.aks_retrieve(
                ctx.sims[si, qi], ctx.valid[si], n))
    draws = jnp.asarray(rows)
    return StrategyOutput(draws, jnp.ones(draws.shape, bool),
                          np.full((s, q), n), np.full((s, q), np.nan))


register_strategy(RetrievalStrategy(
    "sampling", stochastic=True, expand="members",
    run=_run_sampling, run_expand=_run_expand_sampling))
register_strategy(RetrievalStrategy(
    "akr", stochastic=True, expand="members",
    run=_run_akr, run_expand=_run_expand_akr))
register_strategy(RetrievalStrategy(
    "topk", stochastic=False, expand="index", run=_run_topk))
register_strategy(RetrievalStrategy(
    "uniform", stochastic=False, expand="raw", run=_run_uniform))
register_strategy(RetrievalStrategy(
    "bolt", stochastic=False, expand="index", run=_run_bolt))
register_strategy(RetrievalStrategy(
    "mdf", stochastic=False, expand="index", run=_run_mdf))
register_strategy(RetrievalStrategy(
    "aks", stochastic=False, expand="index", run=_run_aks))


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclass
class QueryResult:
    frame_ids: np.ndarray          # selected raw-frame ids (deduped for
    #                                members strategies; rank/time order
    #                                preserved for the baselines)
    draws: np.ndarray              # index draws (or frame ids for "raw")
    n_drawn: int
    mass: float
    timings: Dict[str, float]


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


@jax.jit
def _gather_index_frames(table: jnp.ndarray, draws: jnp.ndarray
                         ) -> jnp.ndarray:
    """table (S, cap) index_frame ids; draws (S, Q, n) slots → frame
    ids (S, Q, n), all on device."""
    cap = table.shape[1]
    return jax.vmap(lambda t, d: t[jnp.clip(d, 0, cap - 1)])(table, draws)


# --- fused-epilogue routing -------------------------------------------------
#
# Strategies whose selection rule the fused kernel epilogue computes
# in-launch: sampling and AKR consume the inverse-CDF draws (+ drawn
# probabilities for AKR's stop rule), top-k consumes the running top-k.
# Everything else (BOLT's CDF over ALL lanes, MDF's embedding scan, AKS's
# host-driven region split, uniform's no-scan rule) takes the dense path.
_FUSED_STRATEGIES = ("sampling", "akr", "topk")


@functools.partial(jax.jit, static_argnames=("n",))
def _targets_from_keys(keys: jnp.ndarray, *, n: int) -> jnp.ndarray:
    """keys (S, Q) → inverse-CDF draw targets (S, Q, n). Each lane is
    exactly ``draw_targets(key, n)`` — the one variate block the direct
    ``sampling_retrieve``/``akr_progressive`` call consumes per key, so
    fused and direct draws see identical targets."""
    return jax.vmap(jax.vmap(lambda k: rt.draw_targets(k, n)))(keys)


@jax.jit
def _expand_stack(members, counts, draws, valid, u):
    """Stacked reservoir expansion of already-computed draws (the fused
    path's counterpart of ``_fused_sample_expand`` — sampling happened
    in the kernel, only the gather remains)."""
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, draws, valid)
    return fids, ok


@functools.partial(jax.jit, static_argnames=("theta", "beta", "n_max"))
def _fused_akr_post(draws, drawn_p, p_max, members, counts, u, *, theta,
                    beta, n_max):
    """AKR over the fused kernel's outputs: the Eq. 6/7 stop rule runs
    on the in-launch draw state (draws + crossing-lane probabilities +
    p_max = 1/l) — no re-scoring, no (S, Q, cap) tensor — then the
    reservoir gather expands the surviving draws, all in one program.
    Each (s, q) lane stops bit-identically to ``akr_progressive`` over
    that lane's materialised probabilities."""
    akr = jax.vmap(jax.vmap(lambda d, p, pm: rt.akr_from_draws(
        d, p, pm, theta=theta, beta=beta, n_max=n_max)))(
            draws, drawn_p, p_max)
    fids, ok = jax.vmap(lambda m, c, d, v: expand_gather(m, c, d, v, u))(
        members, counts, akr.draws, akr.valid)
    return akr, fids, ok


def execute_plan(manager, plan: QueryPlan, *, fused: bool = True,
                 coarse: bool = True) -> List[QueryResult]:
    """Run every group of the plan: ONE scan launch per group (the fused
    retrieval scan for sampling/AKR/top-k groups when ``fused``, the
    dense ``similarity_scan_stack`` otherwise), vmapped strategy
    post-processing, device-side expansion. Returns results in the
    plan's spec order.

    ``coarse`` is the two-stage escape hatch: when True (default) and
    the arena's hierarchical tier holds at least one consolidated
    summary row, fused groups run coarse-scan → winner-block-gather →
    candidate-scan (``tiering.two_stage_retrieve``) instead of the flat
    capacity scan. Until the first consolidation — and always with
    ``coarse=False`` — the flat path runs UNCHANGED (bit-identical to a
    coarse-less build); PRNG chains advance identically either way."""
    specs = plan.specs
    results: List[Optional[QueryResult]] = [None] * len(specs)
    t0 = time.perf_counter()
    missing = [j for j, s in enumerate(specs) if s.embedding is None]
    embedded: Dict[int, np.ndarray] = {}
    if missing:
        embs = manager.embedder.embed_queries(
            [specs[j].text for j in missing])
        embedded = {j: np.asarray(embs[i], np.float32)
                    for i, j in enumerate(missing)}
    t_embed = time.perf_counter() - t0
    for group in plan.groups:
        _execute_group(manager, group, specs, embedded, results, t_embed,
                       fused=fused, coarse=coarse)
    return results


def _spec_embedding(spec: QuerySpec, j: int, embedded) -> np.ndarray:
    return (np.asarray(spec.embedding, np.float32)
            if spec.embedding is not None else embedded[j])


def _group_keys(manager, group: ExecutionGroup, specs, qmax, lanes
                ) -> Optional[jnp.ndarray]:
    """Per-lane key rows (L, qmax) over the scan's lane order.
    Chain-policy lanes consume the session PRNG chain in arrival order —
    exactly the subkeys the same queries would have drawn through the
    legacy paths; explicit-seed lanes derive detached keys; padding
    lanes (whole sessions the group doesn't target, and ``None`` hole
    lanes over freed arena slots) get dummy keys and leave their chains
    untouched."""
    if not group.strategy.stochastic:
        return None
    key_rows = []
    for sid in lanes:
        idxs = group.order.get(sid, ())
        n_chain = sum(1 for j in idxs if specs[j].seed is None)
        chain = (manager.sessions[sid].next_keys(n_chain)
                 if n_chain else None)
        ks, ci = [], 0
        for j in idxs:
            if specs[j].seed is None:
                ks.append(chain[ci])
                ci += 1
            else:
                ks.append(jax.random.key(int(specs[j].seed)))
        if len(ks) < qmax:
            ks.extend(list(jax.random.split(jax.random.key(0),
                                            qmax - len(ks))))
        key_rows.append(jnp.stack(ks))
    return jnp.stack(key_rows)


def _execute_group(manager, group: ExecutionGroup, specs, embedded,
                   results, t_embed: float, *, fused: bool = True,
                   coarse: bool = True) -> None:
    cfg = manager.cfg
    strat = group.strategy
    use_fused = fused and strat.name in _FUSED_STRATEGIES
    sids = group.sids
    # scan-lane order: arena mode scans EVERY slot in slot order (the
    # super-buffers are consumed as-is — zero restacks; freed slots are
    # None hole lanes, masked out by their (0, 0) windows); detached
    # mode scans exactly the group's sessions via the version-cached
    # stack
    lanes = manager.scan_lanes(sids)
    lane_of = {sid: si for si, sid in enumerate(lanes)
               if sid is not None}
    ln, qmax = len(lanes), group.qmax
    timings: Dict[str, float] = {"embed_query": t_embed}

    q_stack = np.zeros((ln, qmax, manager.embed_dim), np.float32)
    qcount = np.zeros((ln,), np.int32)
    for sid in sids:
        si = lane_of[sid]
        idxs = group.order[sid]
        qcount[si] = len(idxs)
        for qi, j in enumerate(idxs):
            q_stack[si, qi] = _spec_embedding(specs[j], j, embedded)
    keys = _group_keys(manager, group, specs, qmax, lanes)

    # --- the ONE scan launch for this group ------------------------------
    t0 = time.perf_counter()
    stack = manager.memory_stack(lanes)
    a = stack.arena_view()
    k = group.key
    # two-stage trigger: fused group + arena-backed + the hierarchical
    # tier actually holds consolidated rows (before that the coarse
    # tier adds nothing the flat scan doesn't cover — and skipping it
    # keeps the pre-consolidation path bit-identical to a coarse-less
    # build, which is the `coarse=False` contract too)
    two_stage = (use_fused and coarse and a is not None
                 and a.has_consolidated())
    ts = None
    if use_fused:
        # fused path: draws/top-k resolve inside the launch; dense
        # (S, Q, cap) scores never cross the kernel boundary. Targets
        # derive from the SAME keys in both modes, so session PRNG
        # chains advance identically with or without the coarse tier.
        if strat.stochastic:
            targets = _targets_from_keys(keys, n=k.budget)
        else:           # top-k ignores the draw epilogue: dummy targets
            targets = jnp.zeros((ln, qmax, 1), jnp.float32)
        n_topk = k.budget if strat.name == "topk" else 1
        if two_stage:
            ts = tiering.two_stage_retrieve(
                a, jnp.asarray(q_stack), targets, tau=k.tau,
                n_topk=n_topk, topb=getattr(cfg, "coarse_topb", 4))
            fr = ts.fr
            manager.io_stats["two_stage_groups"] += 1
        else:
            fr = stack.fused_retrieve(
                jnp.asarray(q_stack), targets, tau=k.tau, n_topk=n_topk)
    else:
        sims, probs = stack.search(jnp.asarray(q_stack), tau=k.tau)
    if len(sids) == 1:   # single-session group: legacy per-session accounting
        manager.io_stats["scans"] += 1
        manager.sessions[sids[0]].memory.io_stats["scans"] += 1
    else:
        manager.io_stats["fused_scans"] += 1
    manager.io_stats["group_scans"] += 1
    if a is not None and a.n_shards > 1:
        # this launch fanned out per shard under shard_map (the kernel
        # entries count bytes; this counts launches at the plan level)
        manager.io_stats["sharded_group_scans"] += 1
    timings["similarity"] = time.perf_counter() - t0

    # --- strategy post-processing + expansion ----------------------------
    t0 = time.perf_counter()
    if use_fused:
        if strat.name == "topk":
            draws = fr.topk_i
            sq = draws.shape[:2]
            if ts is not None:
                # candidate-local draws → candidate ifr; k may be
                # clamped to the candidate width, and lanes can hold
                # fewer valid candidates than k (a consolidated winner
                # is ONE candidate), so masked slots — recognisable by
                # their NEG_INF running-top-k score — are dropped
                # rather than surfacing garbage frame ids
                valid_d = fr.topk_v > -1e29
                out = StrategyOutput(
                    draws, valid_d,
                    np.asarray(valid_d.sum(-1)), np.full(sq, np.nan))
                fids_np = np.asarray(tiering.gather_candidate_ifr(
                    ts.cand_ifr, out.draws))
            else:
                out = StrategyOutput(draws, jnp.ones(draws.shape, bool),
                                     np.full(sq, draws.shape[-1]),
                                     np.full(sq, np.nan))
                fids_np = np.asarray(_gather_index_frames(
                    stack.device_index_frames(), out.draws))
            ok_np = np.asarray(out.valid)
        else:
            u = jnp.asarray(VenusMemory.expand_u(cfg.seed, k.budget),
                            jnp.int32)
            if ts is not None:
                # candidate-local expansion: draws index the gathered
                # (S, Q, C) candidate tables, whose member reservoirs
                # came along in the stage-2 gather
                if strat.name == "sampling":
                    valid_d = jnp.ones(fr.draws.shape, bool)
                    fids, ok = tiering.expand_candidates(
                        ts.cand_members, ts.cand_counts, fr.draws,
                        valid_d, u)
                    sq = fr.draws.shape[:2]
                    out = StrategyOutput(fr.draws, valid_d,
                                         np.full(sq, k.budget),
                                         np.full(sq, np.nan))
                else:                                           # akr
                    akr, fids, ok = tiering.akr_post_candidates(
                        fr.draws, fr.drawn_p, fr.p_max[..., 0],
                        ts.cand_members, ts.cand_counts, u,
                        theta=k.theta, beta=k.beta, n_max=k.budget)
                    out = StrategyOutput(akr.draws, akr.valid,
                                         np.asarray(akr.n_drawn),
                                         np.asarray(akr.mass))
            else:
                members, counts = stack.device_members()
                if strat.name == "sampling":
                    valid_d = jnp.ones(fr.draws.shape, bool)
                    fids, ok = _expand_stack(members, counts, fr.draws,
                                             valid_d, u)
                    sq = fr.draws.shape[:2]
                    out = StrategyOutput(fr.draws, valid_d,
                                         np.full(sq, k.budget),
                                         np.full(sq, np.nan))
                else:                                           # akr
                    akr, fids, ok = _fused_akr_post(
                        fr.draws, fr.drawn_p, fr.p_max[..., 0], members,
                        counts, u, theta=k.theta, beta=k.beta,
                        n_max=k.budget)
                    out = StrategyOutput(akr.draws, akr.valid,
                                         np.asarray(akr.n_drawn),
                                         np.asarray(akr.mass))
            manager.io_stats["device_expands"] += 1
            fids_np, ok_np = np.asarray(fids), np.asarray(ok)
    else:
        emb_stack, valid = stack.device_stack()
        ctx = StrategyContext(
            sims=sims, probs=probs, valid=valid, emb=emb_stack, keys=keys,
            total_frames=np.asarray(
                [manager.sessions[s].stats["frames_seen"]
                 if s is not None else 0 for s in lanes], np.int64),
            key=group.key, qcount=qcount)

        if strat.expand == "members":
            members, counts = stack.device_members()
            u = jnp.asarray(VenusMemory.expand_u(cfg.seed, k.budget),
                            jnp.int32)
            out, fids, ok = strat.run_expand(ctx, members, counts, u)
            manager.io_stats["device_expands"] += 1
            fids_np, ok_np = np.asarray(fids), np.asarray(ok)
        else:
            out = strat.run(ctx)
            ok_np = np.asarray(out.valid)
            if strat.expand == "index":
                fids_np = np.asarray(_gather_index_frames(
                    stack.device_index_frames(), out.draws))
            else:                               # raw: draws ARE frame ids
                fids_np = np.asarray(out.draws)
    draws_np = np.asarray(out.draws)
    n_drawn, mass = np.asarray(out.n_drawn), np.asarray(out.mass)
    timings["sample_expand"] = time.perf_counter() - t0

    for sid in sids:
        si = lane_of[sid]
        for qi, j in enumerate(group.order[sid]):
            lane = fids_np[si, qi][ok_np[si, qi]].astype(np.int64)
            if strat.expand == "members":       # reservoir picks: dedup
                lane = np.unique(lane)
            results[j] = QueryResult(
                frame_ids=lane, draws=draws_np[si, qi],
                n_drawn=int(n_drawn[si, qi]), mass=float(mass[si, qi]),
                timings=dict(timings))
