from repro.core.pipeline import (  # noqa: F401
    MEMEmbedder,
    QueryResult,
    VenusConfig,
    VenusSystem,
)
