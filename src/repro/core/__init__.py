from repro.core.pipeline import (  # noqa: F401
    MEMEmbedder,
    QueryResult,
    VenusConfig,
    VenusSystem,
)
from repro.core.session import (  # noqa: F401
    SessionManager,
    SessionState,
)
