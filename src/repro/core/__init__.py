from repro.core.pipeline import (  # noqa: F401
    MEMEmbedder,
    QueryResult,
    VenusConfig,
    VenusSystem,
)
from repro.core.queryplan import (  # noqa: F401
    QueryPlan,
    QuerySpec,
    RetrievalStrategy,
    build_plan,
    execute_plan,
    get_strategy,
    register_strategy,
    strategies,
)
from repro.core.session import (  # noqa: F401
    SessionManager,
    SessionState,
)
