"""Train-step factories: LM training and MEM contrastive training.

``make_train_step(cfg)`` builds the function the train_4k dry-run shape
lowers: (params, opt, batch, step) -> (params, opt, metrics). Activation
rematerialisation (``remat=True``) checkpoints each scanned layer body —
the standard memory/compute trade recorded in §Perf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.mem import MEM
from repro.models.transformer import Transformer
from repro.training.losses import lm_cross_entropy, siglip_loss
from repro.training.optim import adamw_update, cosine_schedule, global_norm


@dataclass(frozen=True)
class TrainHParams:
    base_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: bool = True


def make_train_step(cfg: ModelConfig, hp: TrainHParams = TrainHParams()
                    ) -> Callable:
    """LM train step. batch: {"tokens": (B,S), "labels": (B,S), and for
    vlm/audio families the stub embeddings}."""
    model = Transformer(cfg)

    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["vision_embeds"] = batch["vision_embeds"]
        if cfg.family == "audio":
            kw["encoder_frames"] = batch["encoder_frames"]
        logits, _, aux = model.apply(params, batch["tokens"],
                                     mode="train", remat=hp.remat, **kw)
        if cfg.family == "vlm":
            nv = batch["vision_embeds"].shape[1]
            logits = logits[:, nv:]
        loss, metrics = lm_cross_entropy(logits, batch["labels"],
                                         batch.get("mask"))
        return loss + aux, {**metrics, "moe_aux": aux}

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(step, base_lr=hp.base_lr, warmup=hp.warmup,
                             total=hp.total_steps)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=hp.weight_decay, grad_clip=hp.grad_clip)
        metrics = {**metrics, "loss": loss, "lr": lr,
                   "grad_norm": global_norm(grads)}
        return params, opt_state, metrics

    return train_step


def make_mem_train_step(mem: MEM, hp: TrainHParams = TrainHParams()
                        ) -> Callable:
    """SigLIP contrastive step. batch: {"tokens", "mask", "patches"}."""

    def loss_fn(params, batch):
        txt = mem.encode_text(params, batch["tokens"], batch.get("mask"))
        img = mem.encode_image(params, batch["patches"])
        return siglip_loss(img, txt, params["logit_scale"],
                           params["logit_bias"])

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(step, base_lr=hp.base_lr, warmup=hp.warmup,
                             total=hp.total_steps)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=hp.weight_decay, grad_clip=hp.grad_clip)
        return params, opt_state, {**metrics, "loss": loss, "lr": lr}

    return train_step
