"""Loss functions: LM cross-entropy (+ z-loss) and SigLIP contrastive."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def lm_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                     mask: Optional[jnp.ndarray] = None,
                     z_loss: float = 1e-4
                     ) -> Tuple[jnp.ndarray, dict]:
    """logits: (B,S,V); labels: (B,S) int32. Mean token NLL + z-loss.

    Vocab-sharding friendly (§Perf iter E): the gold logit is selected
    with a one-hot contraction (shard-local + tiny all-reduce) instead of
    take_along_axis, whose gather over a model-sharded vocab dim lowers
    to an all-gather of the full logits."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                       # (B,S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1],
                            dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", onehot, lg)
    nll = lse - gold
    zl = jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    loss = jnp.sum(nll * m) / denom
    total = loss + z_loss * jnp.sum(zl * m) / denom
    acc = jnp.sum((jnp.argmax(lg, -1) == labels) * m) / denom
    return total, {"nll": loss, "accuracy": acc}


def siglip_loss(img_emb: jnp.ndarray, txt_emb: jnp.ndarray,
                logit_scale: jnp.ndarray, logit_bias: jnp.ndarray
                ) -> Tuple[jnp.ndarray, dict]:
    """SigLIP pairwise sigmoid loss over a (B,B) similarity matrix.

    Embeddings must be L2-normalised; matching pairs on the diagonal."""
    b = img_emb.shape[0]
    logits = (img_emb.astype(jnp.float32)
              @ txt_emb.astype(jnp.float32).T) * jnp.exp(logit_scale) \
        + logit_bias
    labels = 2.0 * jnp.eye(b) - 1.0                           # +1 diag, -1 off
    loss = -jnp.mean(jax.nn.log_sigmoid(labels * logits))
    acc = jnp.mean((jnp.argmax(logits, -1) == jnp.arange(b)))
    return loss, {"contrastive_acc": acc}
