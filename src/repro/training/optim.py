"""AdamW + LR schedules, from scratch (no optax offline)."""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(  # noqa: E731
        lambda a: jnp.zeros_like(a, dtype=jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params),
                      zeros(params))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState]:
    count = state.count + 1
    cf = count.astype(jnp.float32)

    if grad_clip and grad_clip > 0:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** cf), mu)
    nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** cf), nu)

    def upd(p, m, v):
        step = m / (jnp.sqrt(v) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
    return new_params, AdamWState(count, mu, nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = base_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
