"""Checkpointing: path-keyed npz snapshots of arbitrary param pytrees.

No orbax offline; the format is a single ``.npz`` whose keys are
``/``-joined tree paths plus a tiny JSON manifest. Works for params,
optimizer states and caches (nested dicts / NamedTuples of arrays).
Restore rebuilds into the *given* target structure, so sharded restores
just pass the abstract target tree.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, metadata: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"keys": sorted(flat), **(metadata or {})}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(meta, f, indent=1)


def restore(path: str, target: Any) -> Any:
    """Restore into the structure of ``target`` (values replaced)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
