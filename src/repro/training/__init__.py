from repro.training.optim import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
)
from repro.training.trainer import (  # noqa: F401
    TrainHParams,
    make_mem_train_step,
    make_train_step,
)
