"""RWKV6 ("Finch") block — attention-free time mixing with data-dependent
decay [arXiv:2404.05892].

Per head (K = V = head_dim) the WKV recurrence is

    y_t[j] = sum_i r_t[i] * (S_t[i, j] + u[i] * k_t[i] * v_t[j])
    S_{t+1}[i, j] = w_t[i] * S_t[i, j] + k_t[i] * v_t[j]

with w_t = exp(-exp(decay_t)) data-dependent via a LoRA on the token-shift
mix. Train/prefill runs a ``lax.scan`` over time carrying S; decode is a
single O(1) step. Sub-quadratic by construction, so the long_500k decode
shape runs natively (state is (H, K, K) per layer, independent of
context length).

State: ``{"wkv": (B, H, K, K) f32, "shift_tm": (B, d), "shift_cm": (B, d)}``
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

_MIX = ("w", "k", "v", "r", "g")


def _heads(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


def rwkv6_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    rc = cfg.rwkv
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 16)
    p = {
        # token-shift base mixes
        "maa_x": jnp.zeros((d,), dtype=dtype),
        "maa": jnp.zeros((5, d), dtype=dtype),
        # data-dependent mix LoRA: d -> 5*gate_lora -> 5*d
        "maa_w1": dense_init(ks[0], d, 5 * rc.gate_lora, dtype=dtype),
        "maa_w2": (jax.random.normal(ks[1], (5, rc.gate_lora, d))
                   * (1.0 / math.sqrt(rc.gate_lora))).astype(dtype),
        # decay: base + LoRA
        "decay_base": jnp.full((d,), -6.0, dtype=dtype),
        "decay_w1": dense_init(ks[2], d, rc.decay_lora, dtype=dtype),
        "decay_w2": dense_init(ks[3], rc.decay_lora, d, dtype=dtype),
        "bonus_u": (jax.random.normal(ks[4], (h, hd)) * 0.1).astype(dtype),
        "wr": dense_init(ks[5], d, d, dtype=dtype),
        "wk": dense_init(ks[6], d, d, dtype=dtype),
        "wv": dense_init(ks[7], d, d, dtype=dtype),
        "wg": dense_init(ks[8], d, d, dtype=dtype),
        "wo": dense_init(ks[9], d, d, dtype=dtype),
        "ln_scale": jnp.ones((h, hd), dtype=dtype),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype=dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype=dtype),
        "cm_wk": dense_init(ks[10], d, cfg.d_ff, dtype=dtype),
        "cm_wv": dense_init(ks[11], cfg.d_ff, d, dtype=dtype),
        "cm_wr": dense_init(ks[12], d, d, dtype=dtype),
    }
    return p


def rwkv6_state_init(cfg: ModelConfig, batch: int) -> dict:
    h, hd = _heads(cfg)
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), jnp.float32),
        "shift_cm": jnp.zeros((batch, d), jnp.float32),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x: (B,S,d) -> previous-timestep tensor (B,S,d)."""
    first = (jnp.zeros_like(x[:, :1]) if prev is None
             else prev[:, None, :].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p, x, sx):
    """Data-dependent token-shift mixes for (w, k, v, r, g)."""
    dt = x.dtype
    xx = x + sx * p["maa_x"].astype(dt)
    lo = jnp.tanh(xx @ p["maa_w1"].astype(dt))           # (B,S,5*r)
    b, s, _ = lo.shape
    lo = lo.reshape(b, s, 5, -1)
    mix = jnp.einsum("bsgr,grd->gbsd", lo, p["maa_w2"].astype(dt))
    out = []
    for i, _ in enumerate(_MIX):
        out.append(x + sx * (p["maa"][i].astype(dt) + mix[i]))
    return out


def _wkv_scan(r, k, v, w, u, init_state):
    """r,k,v,w: (B,S,H,K); u: (H,K). Returns y (B,S,H,K), final (B,H,K,K)."""
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))

    def step(S, inp):
        rt, kt, vt, wt = inp                              # (B,H,K)
        a = kt[..., :, None] * vt[..., None, :]           # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * a)
        S = wt[..., :, None] * S + a
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    final, ys = jax.lax.scan(step, init_state.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), final


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float
                ) -> jnp.ndarray:
    """Per-head normalisation of the WKV output. y: (B,S,H,K)."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps) * scale[None, None]


def rwkv6_time_mix(p, cfg, x, state, mode):
    b, s, d = x.shape
    h, hd = _heads(cfg)
    dt = x.dtype
    prev = state["shift_tm"] if state is not None else None
    sx = (_token_shift(x, prev) - x) if mode != "decode" else (
        prev[:, None, :].astype(dt) - x)
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    r = (xr @ p["wr"].astype(dt)).reshape(b, s, h, hd)
    k = (xk @ p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = (xv @ p["wv"].astype(dt)).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    decay = (p["decay_base"].astype(jnp.float32)
             + (jnp.tanh(xw @ p["decay_w1"].astype(dt))
                @ p["decay_w2"].astype(dt)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay)).reshape(b, s, h, hd)

    init = (state["wkv"] if state is not None
            else jnp.zeros((b, h, hd, hd), jnp.float32))
    u = p["bonus_u"].astype(jnp.float32)

    if mode == "decode":
        a = (k[:, 0].astype(jnp.float32)[..., :, None]
             * v[:, 0].astype(jnp.float32)[..., None, :])
        y = jnp.einsum("bhk,bhkv->bhv", r[:, 0].astype(jnp.float32),
                       init + u[None, :, :, None] * a)
        final = w[:, 0].astype(jnp.float32)[..., :, None] * init + a
        y = y[:, None]                                    # (B,1,H,K)
    else:
        y, final = _wkv_scan(r, k, v, w, u, init)

    y = _group_norm(y, p["ln_scale"].astype(jnp.float32), 64e-5)
    y = y.reshape(b, s, d).astype(dt) * g
    out = y @ p["wo"].astype(dt)
    new_state = {"wkv": final, "shift_tm": x[:, -1].astype(jnp.float32)}
    return out, new_state


def rwkv6_channel_mix(p, cfg, x, state, mode):
    dt = x.dtype
    prev = state["shift_cm"] if state is not None else None
    sx = (_token_shift(x, prev) - x) if mode != "decode" else (
        prev[:, None, :].astype(dt) - x)
    xk = x + sx * p["cm_mu_k"].astype(dt)
    xr = x + sx * p["cm_mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["cm_wr"].astype(dt)) * (
        k @ p["cm_wv"].astype(dt))
    return out, {"shift_cm": x[:, -1].astype(jnp.float32)}
