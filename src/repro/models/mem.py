"""Venus MEM — dual-encoder multimodal embedding model (BGE-VL class).

Text tower encodes token sequences; vision tower encodes precomputed
patch embeddings (frontend stubbed per the assignment carve-out). Both
are mean-pooled, projected into the shared space and L2-normalised, so
cosine similarity between a text query and an indexed frame is Eq. 4 of
the paper. Trained with the SigLIP pairwise sigmoid loss
(``repro.training.losses.siglip_loss``).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.venus_mem import MEMConfig
from repro.models.layers import dense_init
from repro.models.transformer import Transformer, _norm


def _pool(h: jnp.ndarray, mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    if mask is None:
        return jnp.mean(h, axis=1)
    m = mask.astype(h.dtype)[..., None]
    return jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)


def _l2norm(x: jnp.ndarray) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    return (x32 * jax.lax.rsqrt(
        jnp.sum(x32 * x32, -1, keepdims=True) + 1e-12)).astype(x.dtype)


class MEM:
    def __init__(self, cfg: MEMConfig):
        self.cfg = cfg
        self.text_tower = Transformer(cfg.text)
        self.vision_tower = Transformer(cfg.vision)

    def init(self, key) -> Dict:
        ks = jax.random.split(key, 5)
        d = self.cfg.embed_dim
        return {
            "text": self.text_tower.init(ks[0]),
            "vision": self.vision_tower.init(ks[1]),
            "text_proj": dense_init(ks[2], self.cfg.text.d_model, d),
            "vision_proj": dense_init(ks[3], self.cfg.vision.d_model, d),
            "logit_scale": jnp.asarray(2.0, jnp.float32),   # SigLIP t'
            "logit_bias": jnp.asarray(-10.0, jnp.float32),
        }

    def _trunk(self, tower: Transformer, params, x, mask):
        """Run the tower body without the LM head; x already embedded."""
        cfg = tower.cfg
        h, _, _ = self._hidden(tower, params, x)
        h = _norm(cfg, params["final_norm"], h)
        return _pool(h, mask)

    @staticmethod
    def _hidden(tower: Transformer, params, x):
        cfg = tower.cfg
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], x.shape[:2])
        return tower._apply_decoder(params, x, positions, None, None, None,
                                    "train", False)

    def encode_text(self, params, tokens, mask=None) -> jnp.ndarray:
        tower = self.text_tower
        x = params["text"]["embed"].astype(tower.adtype)[tokens]
        pooled = self._trunk(tower, params["text"], x, mask)
        return _l2norm(pooled @ params["text_proj"].astype(pooled.dtype))

    def encode_image(self, params, patch_embeds) -> jnp.ndarray:
        """patch_embeds: (B, P, d_vision) precomputed (frontend stub)."""
        tower = self.vision_tower
        x = patch_embeds.astype(tower.adtype)
        if "pos_embed" in params["vision"]:
            x = x + params["vision"]["pos_embed"].astype(
                tower.adtype)[None, : x.shape[1]]
        pooled = self._trunk(tower, params["vision"], x, None)
        return _l2norm(pooled @ params["vision_proj"].astype(pooled.dtype))
