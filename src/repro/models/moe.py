"""Sparse mixture-of-experts FFN (GShard-style dense dispatch, chunked).

TPU-native design decisions (see DESIGN.md §3):

* **Dense dispatch einsum, not ragged all-to-all** — expert assignment is
  expressed as a one-hot dispatch tensor contracted on the MXU; with
  experts sharded over the ``model`` mesh axis the contraction lowers to a
  single all-to-all-free einsum per chunk.
* **Chunked over the sequence** — the dispatch tensor is (B, n, E, C);
  materialising it for a full 32k sequence would dwarf VMEM/HBM, so
  tokens are processed in fixed ``lax.scan`` chunks of ≤512 tokens. The
  per-chunk capacity C = ceil(chunk·k/E·capacity_factor) bounds the
  intermediate at a few MB per device regardless of sequence length.
* Capacity overflow drops tokens (GShard semantics); the router
  load-balance auxiliary loss (Switch) keeps drop rates low.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import activation_fn, dense_init, mlp_apply, mlp_init

_MAX_CHUNK = 2048


def _chunk_size(s: int) -> int:
    c = 1
    while c * 2 <= min(s, _MAX_CHUNK) and s % (c * 2) == 0:
        c *= 2
    return c


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    mc = cfg.moe
    d, e, ff = cfg.d_model, mc.num_experts, mc.d_ff
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(d)

    def expert_w(k, i, o):
        return (jax.random.normal(k, (e, i, o), dtype=jnp.float32)
                * (1.0 / math.sqrt(i))).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, scale=scale, dtype=dtype),
        "w_gate": expert_w(ks[1], d, ff),
        "w_up": expert_w(ks[2], d, ff),
        "w_down": expert_w(ks[3], ff, d),
    }
    if mc.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, mc.shared_d_ff, gated=True,
                               dtype=dtype)
    return p


def _route_chunk(p: dict, cfg: ModelConfig, x: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, n, d) one chunk of tokens -> (y, aux_loss)."""
    mc = cfg.moe
    b, n, d = x.shape
    e, k = mc.num_experts, mc.experts_per_token
    act = activation_fn(cfg.activation)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,n,E)
    top_w, top_i = jax.lax.top_k(probs, k)                     # (B,n,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    capacity = max(int(math.ceil(n * k / e * mc.capacity_factor)), 1)

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)       # (B,n,k,E)
    # position of each (token, slot) within its expert's buffer
    pos = jnp.cumsum(onehot.reshape(b, n * k, e), axis=1) * onehot.reshape(
        b, n * k, e)                                           # 1-indexed
    pos = (pos - 1.0).reshape(b, n, k, e)
    keep = (pos >= 0) & (pos < capacity)
    pos_clip = jnp.clip(pos, 0, capacity - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(pos_clip, capacity, dtype=jnp.float32)
    # dispatch: (B,n,E,C) — 1 where token goes to (expert, slot)
    dispatch = jnp.einsum("bnke,bnkec->bnec", onehot,
                          slot_oh * keep[..., None].astype(jnp.float32))
    combine = jnp.einsum("bnk,bnke,bnkec->bnec", top_w.astype(jnp.float32),
                         onehot, slot_oh * keep[..., None].astype(
                             jnp.float32))

    xin = jnp.einsum("bnec,bnd->becd", dispatch.astype(x.dtype), x)
    h = act(jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(x.dtype))
    yexp = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("bnec,becd->bnd", combine.astype(x.dtype), yexp)

    # Switch load-balance loss: E * sum_e f_e * P_e
    frac = jnp.mean(onehot.sum(2), axis=(0, 1))                # (E,)
    mean_p = jnp.mean(probs, axis=(0, 1))                      # (E,)
    aux = e * jnp.sum(frac / k * mean_p)
    return y, aux


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Chunked lax.scan over the sequence."""
    mc = cfg.moe
    b, s, d = x.shape
    chunk = _chunk_size(s)
    n_chunks = s // chunk

    def body(_, xc):                                           # (B,chunk,d)
        y, aux = _route_chunk(p, cfg, xc)
        return None, (y, aux)

    from repro.models import transformer as _tf
    xs = jnp.moveaxis(x.reshape(b, n_chunks, chunk, d), 1, 0)
    _, (ys, auxs) = jax.lax.scan(
        body, None, xs,
        unroll=True if _tf.UNROLL_STRUCTURAL_SCANS else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    if mc.num_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg.activation)
    return y, jnp.mean(auxs) * mc.router_aux_coef
