"""Shared neural-net primitives (pure JAX, no framework).

Parameters are plain pytrees (nested dicts of jnp arrays). Initialisers
take an explicit PRNG key. All matmuls accumulate in float32 and cast back
to the activation dtype.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    """(d_in, d_out) variance-scaling (fan-in) weight."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act_name: str) -> jnp.ndarray:
    act = activation_fn(act_name)
    up = x @ p["w_up"].astype(x.dtype)
    if "w_gate" in p:
        h = act(x @ p["w_gate"].astype(x.dtype)) * up
    else:
        h = act(up)
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (RoPE / partial RoPE / M-RoPE)
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jnp.ndarray, rot_dim: int, theta: float
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin of shape (..., rot_dim // 2)."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x1, x2) = (x[..., :half], x[..., half:])."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S). Rotates the leading
    ``fraction`` of D, passes the rest through (GLM-style partial RoPE)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    cos, sin = rope_cos_sin(positions, rot, theta)   # (B, S, rot/2)
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    head, tail = x[..., :rot], x[..., rot:]
    head = _rotate(head, cos, sin)
    return jnp.concatenate([head, tail], axis=-1) if tail.size else head


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, *, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions3: (3, B, S) temporal/height/width position
    ids. ``sections`` partitions the D/2 frequency slots among (t, h, w);
    each frequency slot uses the position id of its section.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id per frequency slot
    sec = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=half)                    # (half,)
    pos = positions3.astype(jnp.float32)[sec]                      # (half,B,S)
    ang = jnp.moveaxis(pos, 0, -1) * freqs                         # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)
