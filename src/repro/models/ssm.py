"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)
recurrent state update for decode.

The chunked form follows the minimal SSD reference (Mamba2 paper, Listing
1): within a chunk the quadratic form runs on the MXU; across chunks a
short ``lax.scan`` carries the (H, P, N) state. ``chunk`` is
hardware-aligned (64) so intra-chunk matmuls hit MXU tiles.

Projections are kept **separate** (z / x / BC / dt and two depthwise
convs) rather than fused as in the CUDA reference: depthwise convolution
is per-channel, so splitting is mathematically identical, and it lets the
``model`` mesh axis shard the head dimension cleanly (x, dt, conv_x and
the SSD einsums all shard over H; B/C are group-shared and replicated) —
the TPU-native TP layout recorded in DESIGN.md §5.

Cache: ``{"ssm": (B, H, P, N) f32, "conv_x": (B, K-1, d_in),
"conv_bc": (B, K-1, 2N)}``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm


def _dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_in = sc.d_inner(cfg.d_model)
    nheads = sc.num_heads(cfg.d_model)
    return sc, d_in, nheads


def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    sc, d_in, nheads = _dims(cfg)
    d = cfg.d_model
    n2 = 2 * sc.state_dim
    ks = jax.random.split(key, 8)
    u = jax.random.uniform(ks[0], (nheads,), minval=math.log(1e-3),
                           maxval=math.log(1e-1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(u)))        # inverse softplus
    return {
        "in_z": dense_init(ks[1], d, d_in, dtype=dtype),
        "in_x": dense_init(ks[2], d, d_in, dtype=dtype),
        "in_bc": dense_init(ks[3], d, n2, dtype=dtype),
        "in_dt": dense_init(ks[4], d, nheads, dtype=dtype),
        "conv_x_w": (jax.random.normal(ks[5], (sc.conv_dim, d_in))
                     / math.sqrt(sc.conv_dim)).astype(dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype=dtype),
        "conv_bc_w": (jax.random.normal(ks[6], (sc.conv_dim, n2))
                      / math.sqrt(sc.conv_dim)).astype(dtype),
        "conv_bc_b": jnp.zeros((n2,), dtype=dtype),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype=dtype),
        "out_proj": dense_init(ks[7], d_in, d, dtype=dtype),
    }


def mamba2_cache_init(cfg: ModelConfig, batch: int) -> dict:
    sc, d_in, nheads = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nheads, sc.head_dim, sc.state_dim),
                         jnp.float32),
        "conv_x": jnp.zeros((batch, sc.conv_dim - 1, d_in), jnp.float32),
        "conv_bc": jnp.zeros((batch, sc.conv_dim - 1, 2 * sc.state_dim),
                             jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv + silu. x: (B, S, ch); w: (K, ch)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, ch)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):].astype(jnp.float32)
    return jax.nn.silu(y + b.astype(x.dtype)), new_state


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., l) -> (..., l, l) with out[i, j] = sum_{k=j+1..i} a_k for
    i >= j, -inf otherwise."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(l)[:, None]
    j = jnp.arange(l)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def _ssd_chunked(xh, dt, A, B, C, chunk, init_state):
    """Chunked SSD scan.

    xh: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n).
    Returns y (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = xh.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk

    f32 = jnp.float32
    xh = xh.astype(f32).reshape(b, c, chunk, h, p)
    dt = dt.astype(f32).reshape(b, c, chunk, h)
    Bm = B.astype(f32).reshape(b, c, chunk, n)
    Cm = C.astype(f32).reshape(b, c, chunk, n)
    xdt = xh * dt[..., None]                              # fold dt into x

    a = dt * A[None, None, None, :]                       # (b,c,l,h)
    a = jnp.moveaxis(a, -1, 2)                            # (b,c,h,l)
    a_cum = jnp.cumsum(a, axis=-1)                        # inclusive

    # intra-chunk (quadratic within chunk, MXU-friendly)
    L = jnp.exp(_segsum(a))                               # (b,c,h,l,l)
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp", Cm, Bm, L, xdt)

    # per-chunk input states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)       # (b,c,h,l)
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bm, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                 # (b,c,h)

    def step(carry, inp):
        st, dec = inp                                     # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                 # emit prior state

    init = init_state.astype(f32) if init_state is not None else jnp.zeros(
        (b, h, p, n), f32)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (b,c,h,p,n)

    state_decay = jnp.exp(a_cum)                          # (b,c,h,l)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", Cm, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def mamba2_apply(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
) -> Tuple[jnp.ndarray, Optional[dict]]:
    sc, d_in, nheads = _dims(cfg)
    b, s, d = x.shape
    dt_ = x.dtype

    z = x @ p["in_z"].astype(dt_)
    xc = x @ p["in_x"].astype(dt_)
    bc = x @ p["in_bc"].astype(dt_)
    dt_raw = x @ p["in_dt"].astype(dt_)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if mode == "decode":
        assert s == 1 and cache is not None
        xs, new_cx = _causal_conv(xc, p["conv_x_w"], p["conv_x_b"],
                                  cache["conv_x"])
        bcs, new_cbc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                    cache["conv_bc"])
        Bv, Cv = jnp.split(bcs, 2, axis=-1)
        xh = xs.reshape(b, nheads, sc.head_dim).astype(jnp.float32)
        dt1 = dt[:, 0]                                    # (b,h)
        dA = jnp.exp(dt1 * A[None, :])                    # (b,h)
        Bv1 = Bv[:, 0].astype(jnp.float32)                # (b,n)
        Cv1 = Cv[:, 0].astype(jnp.float32)
        new_state = (cache["ssm"] * dA[..., None, None]
                     + jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, Bv1))
        y = jnp.einsum("bhpn,bn->bhp", new_state, Cv1)
        y = y + p["D"][None, :, None] * xh
        y = y.reshape(b, 1, d_in).astype(dt_)
        y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
        out = y @ p["out_proj"].astype(dt_)
        return out, {"ssm": new_state, "conv_x": new_cx,
                     "conv_bc": new_cbc}

    # train / prefill -------------------------------------------------------
    xs, new_cx = _causal_conv(xc, p["conv_x_w"], p["conv_x_b"], None)
    bcs, new_cbc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], None)
    Bv, Cv = jnp.split(bcs, 2, axis=-1)
    xh = xs.reshape(b, s, nheads, sc.head_dim)
    chunk = min(sc.chunk, s)
    # pad to a chunk multiple (padded dt=0 ⇒ no state update, no decay)
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bv = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0)))
        Cv = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0)))
    y, final_state = _ssd_chunked(xh, dt, A, Bv, Cv, chunk, None)
    y = y[:, :s]
    y = y + p["D"][None, None, :, None] * xh[:, :s].astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    new_cache = None
    if mode == "prefill" and cache is not None:
        new_cache = {"ssm": final_state, "conv_x": new_cx,
                     "conv_bc": new_cbc}
    return out, new_cache
