"""Composable model builder: every assigned architecture from one config.

Layers are **stacked** (leading layer axis) and applied with ``lax.scan``
— this keeps HLO size and compile time flat in depth (62-layer MiniCPM3
lowers as fast as a 2-layer smoke model), which matters when the dry-run
compiles 40 (arch × shape) × 2 meshes.

Families:
* dense / moe / vlm — decoder-only attention blocks (GQA or MLA), MoE
  blocks where configured (with optional leading dense layers).
* ssm (rwkv) — RWKV6 time-mix + channel-mix blocks.
* hybrid (zamba2) — Mamba2 backbone; one weight-tied shared attention+MLP
  block applied every ``shared_attn_period`` layers.
* audio (whisper) — encoder-decoder; encoder consumes precomputed frame
  embeddings (conv frontend stubbed per the assignment).

``apply`` modes: "train" (full logits), "prefill" (fills caches, returns
last-position logits only — full 32k×152k-vocab logits would be pure
waste), "decode" (one token against the cache).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_init, layer_norm,
                                 mlp_apply, mlp_init, rms_norm)

Params = Dict[str, Any]
Cache = Optional[Dict[str, Any]]

# Dry-run switch: XLA cost_analysis counts a scan body ONCE (not × trip
# count), so the roofline pass unrolls the *structural* scans (layers,
# MoE chunks) to get true HLO FLOPs. Time-dimension scans (RWKV WKV,
# Mamba inter-chunk carry) stay scans — their bodies are negligible
# relative to the projections outside them (documented in EXPERIMENTS.md).
UNROLL_STRUCTURAL_SCANS = False


def _scan(body, init, xs, **kw):
    return jax.lax.scan(body, init, xs,
                        unroll=True if UNROLL_STRUCTURAL_SCANS else 1, **kw)


def _norm_kind(cfg: ModelConfig) -> str:
    return "layernorm" if cfg.family in ("audio",) or cfg.rwkv else "rmsnorm"


def _norm_init(cfg: ModelConfig, d: int, dtype) -> dict:
    p = {"w": jnp.ones((d,), dtype=dtype)}
    if _norm_kind(cfg) == "layernorm":
        p["b"] = jnp.zeros((d,), dtype=dtype)
    return p


def _norm(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "b" in p:
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


def _stack_init(fn, key, n: int):
    """vmap a per-layer init over n layer keys -> stacked params."""
    return jax.vmap(fn)(jax.random.split(key, n))


# ===========================================================================
# Block bodies (single layer; scanned)
# ===========================================================================


def _attn_block_init(key, cfg: ModelConfig, *, d_ff: int, use_moe: bool,
                     cross: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": _norm_init(cfg, d, dtype), "ln2": _norm_init(cfg, d, dtype)}
    p["attn"] = (attn.mla_init(ks[0], cfg, dtype) if cfg.attn_type == "mla"
                 else attn.gqa_init(ks[0], cfg, dtype))
    if cross:
        p["ln_x"] = _norm_init(cfg, d, dtype)
        p["xattn"] = attn.cross_attn_init(ks[1], cfg, dtype)
    if use_moe:
        p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[2], d, d_ff, gated=cfg.gated_mlp, dtype=dtype)
    return p


def _attn_block(p: dict, cfg: ModelConfig, x, *, positions,
                mrope_positions=None, cache=None, cache_pos=None,
                mode="train", enc_out=None, use_moe=False,
                kv_lengths=None):
    """Pre-norm attention block. Returns (x, new_cache, aux)."""
    h = _norm(cfg, p["ln1"], x)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_attention(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_pos=cache_pos, mode=mode, kv_lengths=kv_lengths)
    else:
        a, new_cache = attn.gqa_attention(
            p["attn"], cfg, h, positions=positions,
            mrope_positions=mrope_positions, cache=cache,
            cache_pos=cache_pos, mode=mode, kv_lengths=kv_lengths)
    x = x + a
    if "xattn" in p:
        assert enc_out is not None
        x = x + attn.cross_attention(p["xattn"], cfg,
                                     _norm(cfg, p["ln_x"], x), enc_out)
    h = _norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        m, aux = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        m = mlp_apply(p["mlp"], h, cfg.activation)
    return x + m, new_cache, aux


def _encoder_self_attn(p, cfg, x):
    """Bidirectional self-attention (whisper encoder) reusing GQA weights."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, cfg.num_kv_heads, hd)
    mask = jnp.ones((s, s), dtype=bool)
    ctx = attn._sdpa(q, k, v, mask, 1.0 / (hd ** 0.5), 0.0, cfg.q_per_kv)
    return ctx.reshape(b, s, h * hd) @ p["wo"].astype(dt)


def _enc_block(p: dict, cfg: ModelConfig, x):
    h = _norm(cfg, p["ln1"], x)
    x = x + _encoder_self_attn(p["attn"], cfg, h)
    h = _norm(cfg, p["ln2"], x)
    return x + mlp_apply(p["mlp"], h, cfg.activation)


def _mamba_block_init(key, cfg: ModelConfig, dtype) -> dict:
    return {"ln": _norm_init(cfg, cfg.d_model, dtype),
            "mamba": ssm_mod.mamba2_init(key, cfg, dtype)}


def _mamba_block(p, cfg, x, *, cache=None, mode="train"):
    h = _norm(cfg, p["ln"], x)
    y, new_cache = ssm_mod.mamba2_apply(p["mamba"], cfg, h, cache=cache,
                                        mode=mode)
    return x + y, new_cache


def _rwkv_block_init(key, cfg: ModelConfig, dtype) -> dict:
    return {"ln1": _norm_init(cfg, cfg.d_model, dtype),
            "ln2": _norm_init(cfg, cfg.d_model, dtype),
            "mix": rwkv_mod.rwkv6_init(key, cfg, dtype)}


def _rwkv_block(p, cfg, x, *, state=None, mode="train"):
    h = _norm(cfg, p["ln1"], x)
    y, st_tm = rwkv_mod.rwkv6_time_mix(p["mix"], cfg, h, state, mode)
    x = x + y
    h = _norm(cfg, p["ln2"], x)
    y, st_cm = rwkv_mod.rwkv6_channel_mix(p["mix"], cfg, h, state, mode)
    new_state = {**st_tm, **st_cm} if state is not None else None
    return x + y, new_state


# ===========================================================================
# Transformer
# ===========================================================================


class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.dtype)
        self._kv_lengths = None
        self._mrope_delta = None
        self._cached_mrope_delta = None

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = self.pdtype
        keys = jax.random.split(key, 8)
        p: Params = {"embed": embed_init(keys[0], cfg.vocab_size,
                                         cfg.d_model, dtype)}
        if cfg.pos_type == "learned":
            p["pos_embed"] = embed_init(keys[1], cfg.max_seq_len,
                                        cfg.d_model, dtype)
        p["final_norm"] = _norm_init(cfg, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(keys[2], cfg.d_model, cfg.vocab_size,
                                      dtype=dtype)

        if cfg.family == "audio":
            p["enc_pos_embed"] = embed_init(keys[3], cfg.encoder_seq_len,
                                            cfg.d_model, dtype)
            p["enc_blocks"] = _stack_init(
                lambda k: _attn_block_init(k, cfg, d_ff=cfg.d_ff,
                                           use_moe=False, cross=False,
                                           dtype=dtype),
                keys[4], cfg.num_encoder_layers)
            p["enc_final_norm"] = _norm_init(cfg, cfg.d_model, dtype)
            p["blocks"] = _stack_init(
                lambda k: _attn_block_init(k, cfg, d_ff=cfg.d_ff,
                                           use_moe=False, cross=True,
                                           dtype=dtype),
                keys[5], cfg.num_layers)
            return p

        if cfg.family == "hybrid":
            p["blocks"] = _stack_init(
                lambda k: _mamba_block_init(k, cfg, dtype),
                keys[4], cfg.num_layers)
            p["shared"] = _attn_block_init(keys[5], cfg, d_ff=cfg.d_ff,
                                           use_moe=False, cross=False,
                                           dtype=dtype)
            return p

        if cfg.rwkv is not None:
            p["blocks"] = _stack_init(
                lambda k: _rwkv_block_init(k, cfg, dtype),
                keys[4], cfg.num_layers)
            return p

        # dense / moe / vlm decoder
        n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.num_layers
        n_dense = min(n_dense, cfg.num_layers)
        n_moe = cfg.num_layers - n_dense
        if n_dense:
            d_ff = (cfg.moe.dense_d_ff if (cfg.moe
                                           and cfg.moe.dense_d_ff)
                    else cfg.d_ff)
            p["dense_blocks"] = _stack_init(
                lambda k: _attn_block_init(k, cfg, d_ff=d_ff, use_moe=False,
                                           cross=False, dtype=dtype),
                keys[4], n_dense)
        if n_moe:
            p["moe_blocks"] = _stack_init(
                lambda k: _attn_block_init(k, cfg, d_ff=cfg.d_ff,
                                           use_moe=True, cross=False,
                                           dtype=dtype),
                keys[5], n_moe)
        return p

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg

        def stack(fn, n):
            one = fn()
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one)

        cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
        if cfg.pos_type == "mrope":
            cache["mrope_delta"] = jnp.zeros((batch,), jnp.int32)
        if cfg.family == "audio":
            cache["self"] = stack(
                lambda: attn.gqa_cache_init(cfg, batch, max_len, dtype),
                cfg.num_layers)
            cache["enc_out"] = jnp.zeros(
                (batch, cfg.encoder_seq_len, cfg.d_model), dtype)
            return cache
        if cfg.family == "hybrid":
            cache["mamba"] = stack(
                lambda: ssm_mod.mamba2_cache_init(cfg, batch),
                cfg.num_layers)
            n_shared = cfg.num_layers // cfg.shared_attn_period
            cache["shared"] = stack(
                lambda: attn.gqa_cache_init(cfg, batch, max_len, dtype),
                n_shared)
            return cache
        if cfg.rwkv is not None:
            cache["rwkv"] = stack(
                lambda: rwkv_mod.rwkv6_state_init(cfg, batch),
                cfg.num_layers)
            return cache
        mk = (partial(attn.mla_cache_init, cfg, batch, max_len, dtype)
              if cfg.attn_type == "mla"
              else partial(attn.gqa_cache_init, cfg, batch, max_len, dtype))
        n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.num_layers
        n_dense = min(n_dense, cfg.num_layers)
        if n_dense:
            cache["dense"] = stack(mk, n_dense)
        if cfg.num_layers - n_dense:
            cache["moe"] = stack(mk, cfg.num_layers - n_dense)
        return cache

    # ----------------------------------------------------------------- apply
    def apply(self, params: Params, tokens: jnp.ndarray, *,
              vision_embeds: Optional[jnp.ndarray] = None,
              encoder_frames: Optional[jnp.ndarray] = None,
              cache: Cache = None, mode: str = "train",
              remat: bool = False,
              prompt_lengths: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Cache, jnp.ndarray]:
        """tokens: (B, S_text) int32. Returns (logits, new_cache, aux).

        prompt_lengths (B,): true prompt lengths (incl. vision tokens) for
        right-padded prefill — pad keys are masked, last-token logits and
        cache positions use the true length."""
        cfg = self.cfg
        cache_pos = cache["pos"] if cache is not None else None
        self._kv_lengths = prompt_lengths if mode == "prefill" else None
        self._cached_mrope_delta = (
            cache.get("mrope_delta", jnp.zeros((), jnp.int32))
            if cache is not None else jnp.zeros((), jnp.int32))

        x, positions, mrope_positions = self._embed(
            params, tokens, vision_embeds, cache_pos, mode)
        x = x.astype(self.adtype)

        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {} if cache is not None else None

        if cfg.family == "audio":
            x, nc = self._apply_audio(params, x, positions, encoder_frames,
                                      cache, cache_pos, mode, remat)
            if cache is not None:
                new_cache = nc
        elif cfg.family == "hybrid":
            x, nc = self._apply_hybrid(params, x, positions, cache,
                                       cache_pos, mode, remat)
            if cache is not None:
                new_cache = nc
        elif cfg.rwkv is not None:
            x, nc = self._apply_rwkv(params, x, cache, mode, remat)
            if cache is not None:
                new_cache = nc
        else:
            x, nc, aux = self._apply_decoder(params, x, positions,
                                             mrope_positions, cache,
                                             cache_pos, mode, remat)
            if cache is not None:
                new_cache = nc

        x = _norm(cfg, params["final_norm"], x)
        if mode == "prefill":
            if prompt_lengths is not None:
                idx = (prompt_lengths - 1).astype(jnp.int32)
                x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            else:
                x = x[:, -1:]
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head.astype(x.dtype)
        if cache is not None:
            b = tokens.shape[0]
            if mode == "decode":
                new_cache["pos"] = cache_pos + 1
            elif prompt_lengths is not None:
                new_cache["pos"] = prompt_lengths.astype(jnp.int32)
            else:
                new_cache["pos"] = jnp.full(
                    (b,), self._seq_len(tokens, vision_embeds), jnp.int32)
            if cfg.pos_type == "mrope":
                new_cache["mrope_delta"] = (
                    self._cached_mrope_delta if mode == "decode"
                    else jnp.full((b,), self._mrope_delta, jnp.int32))
        return logits, new_cache, aux

    # ------------------------------------------------------------- internals
    def _seq_len(self, tokens, vision_embeds):
        s = tokens.shape[1]
        if vision_embeds is not None:
            s += vision_embeds.shape[1]
        return s

    def _embed(self, params, tokens, vision_embeds, cache_pos, mode):
        cfg = self.cfg
        b = tokens.shape[0]
        x = params["embed"].astype(self.adtype)[tokens]
        if vision_embeds is not None and mode != "decode":
            x = jnp.concatenate(
                [vision_embeds.astype(self.adtype), x], axis=1)
        s = x.shape[1]
        if mode == "decode":
            positions = cache_pos[:, None]                    # (B, 1)
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        mrope_positions = None
        self._mrope_delta = None
        if cfg.pos_type == "mrope":
            mrope_positions, self._mrope_delta = self._mrope_positions(
                b, s, vision_embeds, cache_pos, mode)
        if cfg.pos_type == "learned":
            x = x + params["pos_embed"].astype(self.adtype)[positions]
        return x, positions, mrope_positions

    def _mrope_positions(self, b, s, vision_embeds, cache_pos, mode):
        """Returns ((3,B,S) position ids, rope delta).

        The delta (g − n_vision) maps absolute cache positions back onto
        the M-RoPE text axis at decode time (Qwen2-VL's rope_delta)."""
        cfg = self.cfg
        nv = vision_embeds.shape[1] if (vision_embeds is not None
                                        and mode != "decode") else 0
        delta = jnp.zeros((), jnp.int32)
        if nv:
            g = int(math.isqrt(nv))
            assert g * g == nv, "vision_tokens must be a square grid"
            vi = jnp.arange(nv)
            vt = jnp.zeros((nv,), jnp.int32)
            vh = (vi // g).astype(jnp.int32)
            vw = (vi % g).astype(jnp.int32)
            tstart = g
            ti = jnp.arange(s - nv) + tstart
            pos3 = jnp.stack([
                jnp.concatenate([vt, ti]),
                jnp.concatenate([vh, ti]),
                jnp.concatenate([vw, ti]),
            ])                                             # (3, S)
            delta = jnp.asarray(g - nv, jnp.int32)
        elif mode == "decode":
            # text continuation on the shifted M-RoPE text axis
            p = cache_pos + self._cached_mrope_delta           # (B,)
            pos3 = jnp.broadcast_to(p[None, :, None], (3, b, s))
            return pos3, delta
        else:
            pos3 = jnp.broadcast_to(jnp.arange(s)[None], (3, s))
        return jnp.broadcast_to(pos3[:, None], (3, b, s)), delta

    def _maybe_remat(self, fn, remat):
        return jax.checkpoint(fn) if remat else fn

    def _apply_decoder(self, params, x, positions, mrope_positions, cache,
                       cache_pos, mode, remat):
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = {}
        if "dense_blocks" in params:
            caches = cache["dense"] if cache is not None else None
            if caches is None:
                x, aux, _ = self._scan_group(
                    params["dense_blocks"], x, None, False, positions,
                    mrope_positions, cache_pos, mode, remat)
            else:
                x, aux, nc = self._scan_group(
                    params["dense_blocks"], x, caches, False, positions,
                    mrope_positions, cache_pos, mode, remat)
                new_cache["dense"] = nc
            aux_total += aux
        if "moe_blocks" in params:
            caches = cache["moe"] if cache is not None else None
            if caches is None:
                x, aux, _ = self._scan_group(
                    params["moe_blocks"], x, None, True, positions,
                    mrope_positions, cache_pos, mode, remat)
            else:
                x, aux, nc = self._scan_group(
                    params["moe_blocks"], x, caches, True, positions,
                    mrope_positions, cache_pos, mode, remat)
                new_cache["moe"] = nc
            aux_total += aux
        return x, new_cache if cache is not None else None, aux_total

    def _scan_group(self, blocks, x, caches, use_moe, positions,
                    mrope_positions, cache_pos, mode, remat):
        cfg = self.cfg

        if caches is None:
            def body(carry, p_l):
                xc, aux = carry
                xc, _, a = _attn_block(
                    p_l, cfg, xc, positions=positions,
                    mrope_positions=mrope_positions, mode=mode,
                    use_moe=use_moe, kv_lengths=self._kv_lengths)
                return (xc, aux + a), None
            body = self._maybe_remat(body, remat)
            (x, aux), _ = _scan(
                body, (x, jnp.zeros((), jnp.float32)), blocks)
            return x, aux, None

        def body(carry, per_layer):
            xc, aux = carry
            p_l, cache_l = per_layer
            xc, nc, a = _attn_block(
                p_l, cfg, xc, positions=positions,
                mrope_positions=mrope_positions, cache=cache_l,
                cache_pos=cache_pos, mode=mode, use_moe=use_moe,
                kv_lengths=self._kv_lengths)
            return (xc, aux + a), nc
        body = self._maybe_remat(body, remat)
        (x, aux), new_caches = _scan(
            body, (x, jnp.zeros((), jnp.float32)), (blocks, caches))
        return x, aux, new_caches

    def _apply_hybrid(self, params, x, positions, cache, cache_pos, mode,
                      remat):
        cfg = self.cfg
        period = cfg.shared_attn_period
        n_super = cfg.num_layers // period

        # reshape stacked mamba params/caches into (n_super, period, ...)
        def regroup(t):
            return jax.tree.map(
                lambda a: a.reshape((n_super, period) + a.shape[1:]), t)

        blocks = regroup(params["blocks"])
        m_caches = regroup(cache["mamba"]) if cache is not None else None
        s_caches = cache["shared"] if cache is not None else None
        shared_p = params["shared"]

        def superstep(carry, per):
            xc = carry
            if cache is not None:
                blk, mc, sc = per
            else:
                blk = per
                mc, sc = None, None

            def inner(c2, per2):
                x2 = c2
                if mc is not None:
                    p_l, cache_l = per2
                    x2, ncl = _mamba_block(p_l, cfg, x2, cache=cache_l,
                                           mode=mode)
                    return x2, (ncl if ncl is not None else cache_l)
                x2, _ = _mamba_block(per2, cfg, x2, mode=mode)
                return x2, None

            if mc is not None:
                xc, new_mc = _scan(inner, xc, (blk, mc))
            else:
                xc, _ = _scan(inner, xc, blk)
                new_mc = None
            # shared attention block after each group of `period` layers
            xc, new_sc, _ = _attn_block(
                shared_p, cfg, xc, positions=positions, cache=sc,
                cache_pos=cache_pos, mode=mode, use_moe=False,
                kv_lengths=self._kv_lengths)
            if cache is not None:
                return xc, (new_mc, new_sc if new_sc is not None else sc)
            return xc, None

        superstep = self._maybe_remat(superstep, remat)
        if cache is not None:
            x, (new_m, new_s) = _scan(
                superstep, x, (blocks, m_caches, s_caches))
            new_cache = {
                "mamba": jax.tree.map(
                    lambda a: a.reshape((n_super * period,) + a.shape[2:]),
                    new_m),
                "shared": new_s,
            }
            return x, new_cache
        x, _ = _scan(superstep, x, blocks)
        return x, None

    def _apply_rwkv(self, params, x, cache, mode, remat):
        cfg = self.cfg
        states = cache["rwkv"] if cache is not None else None

        def body(xc, per):
            if states is not None:
                p_l, st = per
                xc, new_st = _rwkv_block(p_l, cfg, xc, state=st, mode=mode)
                return xc, new_st
            xc, _ = _rwkv_block(per, cfg, xc, mode=mode)
            return xc, None

        body = self._maybe_remat(body, remat)
        if states is not None:
            x, new_states = _scan(body, x, (params["blocks"], states))
            return x, {"rwkv": new_states}
        x, _ = _scan(body, x, params["blocks"])
        return x, None

    def _apply_audio(self, params, x, positions, encoder_frames, cache,
                     cache_pos, mode, remat):
        cfg = self.cfg

        if mode == "decode":
            enc_out = cache["enc_out"].astype(self.adtype)
        else:
            assert encoder_frames is not None, "audio needs encoder_frames"
            e = encoder_frames.astype(self.adtype)
            e = e + params["enc_pos_embed"].astype(self.adtype)[
                None, : e.shape[1]]

            def enc_body(xc, p_l):
                return _enc_block(p_l, cfg, xc), None
            enc_body = self._maybe_remat(enc_body, remat)
            e, _ = _scan(enc_body, e, params["enc_blocks"])
            enc_out = _norm(cfg, params["enc_final_norm"], e)

        def body(xc, per):
            if cache is not None:
                p_l, cache_l = per
                xc, nc, _ = _attn_block(
                    p_l, cfg, xc, positions=positions, cache=cache_l,
                    cache_pos=cache_pos, mode=mode, enc_out=enc_out,
                    kv_lengths=self._kv_lengths)
                return xc, nc
            xc, _, _ = _attn_block(per, cfg, xc, positions=positions,
                                   mode=mode, enc_out=enc_out,
                                   kv_lengths=self._kv_lengths)
            return xc, None

        body = self._maybe_remat(body, remat)
        if cache is not None:
            x, new_self = _scan(body, x, (params["blocks"],
                                          cache["self"]))
            new_cache = {"self": new_self,
                         "enc_out": enc_out.astype(cache["enc_out"].dtype)}
            return x, new_cache
        x, _ = _scan(body, x, params["blocks"])
        return x, None
