"""Attention blocks: GQA (with sliding-window ring cache) and MLA.

Single-layer functional modules; the model builder stacks them over layers
with ``lax.scan``. Cache conventions:

* GQA cache: ``{"k": (B, C, Hkv, Dk), "v": (B, C, Hkv, Dv)}`` where
  ``C = min(max_len, window or max_len)``. Sliding-window caches are ring
  buffers indexed by ``pos % C`` — keys are stored post-RoPE, so slot
  order is irrelevant to the (order-invariant) softmax sum.
* MLA cache: ``{"ckv": (B, C, kv_lora), "krope": (B, C, rope_dim)}`` —
  the compact latent cache (576 B/token for DeepSeek-V2); decode uses the
  matrix-absorbed form so heads are never materialised per cache token.

``mode``: "train" (no cache), "prefill" (fills cache[0:S]), "decode"
(S == 1, attends to the cache at position ``cache_pos``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 rms_norm)

NEG_INF = -1e30


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def causal_window_mask(s_q: int, s_k: int, window: int,
                       offset: int = 0) -> jnp.ndarray:
    """(s_q, s_k) bool mask; query i attends key j iff
    j <= i+offset and (window == 0 or i+offset - j < window)."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    m = kj <= qi
    if window:
        m &= (qi - kj) < window
    return m


# ===========================================================================
# GQA
# ===========================================================================


def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), dtype=dtype)
        p["k_scale"] = jnp.ones((hd,), dtype=dtype)
    return p


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    c = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, c, hkv, hd), dtype),
            "v": jnp.zeros((batch, c, hkv, hd), dtype)}


def _position_embed(cfg: ModelConfig, q, k, positions, mrope_positions):
    if cfg.pos_type == "rope":
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    elif cfg.pos_type == "mrope":
        assert mrope_positions is not None, "mrope needs (3,B,S) positions"
        q = apply_mrope(q, mrope_positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
    # "learned" / "none": positions handled at the embedding layer.
    return q, k


def _sdpa(q, k, v, mask, scale, softcap, q_per_kv):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D'), mask: (Sq,Sk) or (B,Sq,Sk).

    §Perf iteration G: operands stay in the model dtype with f32 MXU
    accumulation (preferred_element_type) instead of materialising f32
    copies of q/k/v — halves attention HBM/ICI traffic in bf16 models
    (the probs are requantised to the model dtype for the value matmul,
    standard flash-attention practice)."""
    b, sq, h, dq = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, sq, hkv, q_per_kv, dq)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return ctx.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# §Perf iteration C (beyond-paper): sequence-parallel attention.
# When num_heads is not divisible by the model-axis size (qwen2-vl: 28,
# minicpm3: 40 on a 16-way axis), GSPMD splits the flattened (H·hd) dim
# *through* head boundaries and turns the score einsum into a partial-sum
# contraction — observed as a 60 GB f32[S,S,heads] all-reduce per layer at
# prefill_32k. Constraining q to be sharded over the *sequence* on the
# model axis (and k/v gathered) makes attention shard-local: the gather is
# S·Hkv·hd bytes (~34 MB/layer) instead. The launcher enables this per
# arch via set_seq_parallel_attn(); off by default (no mesh in tests).
_SEQ_PARALLEL_SPEC = None     # (data_axes, model_axis) or None


def set_seq_parallel_attn(spec):
    """spec: None to disable, or (data_axes tuple, model_axis name)."""
    global _SEQ_PARALLEL_SPEC
    _SEQ_PARALLEL_SPEC = spec


def _seq_shard(q, k, v):
    if _SEQ_PARALLEL_SPEC is None:
        return q, k, v
    from jax.sharding import PartitionSpec as P
    daxes, model = _SEQ_PARALLEL_SPEC
    csp = jax.lax.with_sharding_constraint
    q = csp(q, P(daxes, model, None, None))
    k = csp(k, P(daxes, None, None, None))
    v = csp(v, P(daxes, None, None, None))
    return q, k, v


# §Perf iteration A (beyond-paper): query-chunked causal attention.
# The naive _sdpa materialises the full (Sq, Sk) logits — half of which
# the causal mask throws away — so long-sequence train/prefill is both
# compute-inflated (2×) and memory-inflated (S²·4B live logits). Chunking
# queries into Q_BLK blocks with *static* per-chunk key bounds skips the
# fully-masked key range entirely and bounds live logits at Q_BLK·Sk.
SDPA_Q_CHUNK = 512
CHUNKED_SDPA = True          # flip off to reproduce the naive baseline


def _sdpa_causal_chunked(q, k, v, scale, softcap, q_per_kv, window,
                         kv_lengths):
    """Causal SDPA over query chunks; exact same math as _sdpa with a
    causal(+window)(+kv_lengths) mask."""
    b, sq, h, dq = q.shape
    sk = k.shape[1]
    cq = SDPA_Q_CHUNK
    if sq <= cq or sq % cq != 0 or sq != sk:
        mask = causal_window_mask(sq, sk, window)
        if kv_lengths is not None:
            mask = mask[None] & (jnp.arange(sk)[None, None, :]
                                 < kv_lengths[:, None, None])
        return _sdpa(q, k, v, mask, scale, softcap, q_per_kv)

    outs = []
    for i in range(sq // cq):
        q_lo = i * cq
        # earliest key any query in this chunk can see (chunk-aligned)
        k_lo = 0
        if window:
            k_lo = max(0, ((q_lo - window + 1) // cq) * cq)
        k_hi = q_lo + cq                            # causal bound, static
        qc = q[:, q_lo:q_lo + cq]
        kc = k[:, k_lo:k_hi]
        vc = v[:, k_lo:k_hi]
        mask = causal_window_mask(cq, k_hi - k_lo, window,
                                  offset=q_lo - k_lo)
        if kv_lengths is not None:
            kpos = jnp.arange(k_lo, k_hi)
            mask = mask[None] & (kpos[None, None, :]
                                 < kv_lengths[:, None, None])
        outs.append(_sdpa(qc, kc, vc, mask, scale, softcap, q_per_kv))
    return jnp.concatenate(outs, axis=1)


def gqa_attention(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    mrope_positions: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    mode: str = "train",
    kv_lengths: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_scale"], cfg.norm_eps)
    q, k = _position_embed(cfg, q, k, positions, mrope_positions)
    scale = 1.0 / (hd ** 0.5)

    if mode in ("train", "prefill"):
        q, k, v = _seq_shard(q, k, v)
        if CHUNKED_SDPA:
            ctx = _sdpa_causal_chunked(q, k, v, scale,
                                       cfg.attn_logit_softcap,
                                       cfg.q_per_kv, cfg.sliding_window,
                                       kv_lengths)
        else:
            mask = causal_window_mask(s, s, cfg.sliding_window)
            if kv_lengths is not None:   # right-padded prompts: mask pads
                mask = mask[None] & (jnp.arange(s)[None, None, :]
                                     < kv_lengths[:, None, None])
            ctx = _sdpa(q, k, v, mask, scale, cfg.attn_logit_softcap,
                        cfg.q_per_kv)
        new_cache = None
        if mode == "prefill" and cache is not None:
            c = cache["k"].shape[1]
            if c >= s:
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
                }
            else:
                # sliding-window cache shorter than the prompt: keep the
                # ring-consistent tail (token t lives at slot t % c).
                tail_k, tail_v = k[:, s - c:], v[:, s - c:]
                shift = s % c
                new_cache = {
                    "k": jnp.roll(tail_k, shift, axis=1).astype(
                        cache["k"].dtype),
                    "v": jnp.roll(tail_v, shift, axis=1).astype(
                        cache["v"].dtype),
                }
        return ctx.reshape(b, s, h * hd) @ p["wo"].astype(dt), new_cache

    # ---- decode: s == 1 ---------------------------------------------------
    # cache_pos: (B,) per-slot token counts (continuous batching).
    assert cache is not None and cache_pos is not None
    c = cache["k"].shape[1]
    slot = (cache_pos % c).astype(jnp.int32)                 # (B,)
    upd = jax.vmap(
        lambda buf, new, s: jax.lax.dynamic_update_slice(
            buf, new, (s, 0, 0)))
    k_cache = upd(cache["k"], k.astype(cache["k"].dtype), slot)
    v_cache = upd(cache["v"], v.astype(cache["v"].dtype), slot)
    # valid slots: all written slots; ring buffer is full once pos+1 >= c.
    n_written = jnp.minimum(cache_pos + 1, c)                # (B,)
    valid = jnp.arange(c)[None, :] < n_written[:, None]      # (B, C)

    from repro.kernels import ops as kops
    ctx = kops.decode_attention(
        q, k_cache.astype(dt), v_cache.astype(dt), valid,
        scale=scale, softcap=cfg.attn_logit_softcap, q_per_kv=cfg.q_per_kv)
    out = ctx.reshape(b, 1, h * hd) @ p["wo"].astype(dt)
    return out, {"k": k_cache, "v": v_cache}


# ===========================================================================
# Cross attention (whisper decoder)
# ===========================================================================


def cross_attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, h * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, h * hd, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype),
    }


def cross_attention(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                    enc: jnp.ndarray) -> jnp.ndarray:
    """x: (B, Sq, d) decoder states; enc: (B, Sk, d) encoder output."""
    b, sq, d = x.shape
    sk = enc.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, sq, h, hd)
    k = (enc @ p["wk"].astype(dt)).reshape(b, sk, h, hd)
    v = (enc @ p["wv"].astype(dt)).reshape(b, sk, h, hd)
    mask = jnp.ones((sq, sk), dtype=bool)
    ctx = _sdpa(q, k, v, mask, 1.0 / (hd ** 0.5), 0.0, 1)
    return ctx.reshape(b, sq, h * hd) @ p["wo"].astype(dt)


# ===========================================================================
# MLA (Multi-head Latent Attention)
# ===========================================================================


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype=dtype)
        p["w_uq"] = dense_init(ks[1], m.q_lora_rank, h * m.qk_head_dim,
                               dtype=dtype)
    else:
        p["w_q"] = dense_init(ks[1], d, h * m.qk_head_dim, dtype=dtype)
    p["w_dkv"] = dense_init(ks[2], d, m.kv_lora_rank, dtype=dtype)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,), dtype=dtype)
    p["w_kr"] = dense_init(ks[3], d, m.qk_rope_head_dim, dtype=dtype)
    p["w_uk"] = dense_init(ks[4], m.kv_lora_rank, h * m.qk_nope_head_dim,
                           dtype=dtype)
    p["w_uv"] = dense_init(ks[5], m.kv_lora_rank, h * m.v_head_dim,
                           dtype=dtype)
    p["wo"] = dense_init(ks[6], h * m.v_head_dim, d, dtype=dtype)
    return p


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    c = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {"ckv": jnp.zeros((batch, c, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, c, m.qk_rope_head_dim), dtype)}


def _mla_qkv(p, cfg, x, positions):
    """Shared projection path; returns q_nope, q_rope, ckv, k_rope."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    dt = x.dtype
    if m.q_lora_rank:
        cq = rms_norm(x @ p["w_dq"].astype(dt), p["q_norm"], cfg.norm_eps)
        q = (cq @ p["w_uq"].astype(dt)).reshape(b, s, h, m.qk_head_dim)
    else:
        q = (x @ p["w_q"].astype(dt)).reshape(b, s, h, m.qk_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        theta=cfg.rope_theta)
    ckv = rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope((x @ p["w_kr"].astype(dt))[:, :, None, :],
                        positions, theta=cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_attention(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    mode: str = "train",
    kv_lengths: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    dt = x.dtype
    scale = 1.0 / (m.qk_head_dim ** 0.5)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)

    if mode in ("train", "prefill"):
        # naive (expanded-head) form — optimal for seq-parallel prefill.
        k_nope = (ckv @ p["w_uk"].astype(dt)).reshape(
            b, s, h, m.qk_nope_head_dim)
        v = (ckv @ p["w_uv"].astype(dt)).reshape(b, s, h, m.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, m.qk_rope_head_dim))],
            axis=-1)
        q, k, v = _seq_shard(q, k, v)
        if CHUNKED_SDPA:
            ctx = _sdpa_causal_chunked(q, k, v, scale, 0.0, 1,
                                       cfg.sliding_window, kv_lengths)
        else:
            mask = causal_window_mask(s, s, cfg.sliding_window)
            if kv_lengths is not None:   # right-padded prompts: mask pads
                mask = mask[None] & (jnp.arange(s)[None, None, :]
                                     < kv_lengths[:, None, None])
            ctx = _sdpa(q, k, v, mask, scale, 0.0, 1)
        new_cache = None
        if mode == "prefill" and cache is not None:
            c = cache["ckv"].shape[1]
            if c >= s:
                new_cache = {
                    "ckv": jax.lax.dynamic_update_slice(
                        cache["ckv"], ckv.astype(cache["ckv"].dtype),
                        (0, 0, 0)),
                    "krope": jax.lax.dynamic_update_slice(
                        cache["krope"], k_rope.astype(cache["krope"].dtype),
                        (0, 0, 0)),
                }
            else:
                shift = s % c
                new_cache = {
                    "ckv": jnp.roll(ckv[:, s - c:], shift, axis=1).astype(
                        cache["ckv"].dtype),
                    "krope": jnp.roll(k_rope[:, s - c:], shift,
                                      axis=1).astype(cache["krope"].dtype),
                }
        out = ctx.reshape(b, s, h * m.v_head_dim) @ p["wo"].astype(dt)
        return out, new_cache

    # ---- decode: matrix-absorbed latent attention --------------------------
    # cache_pos: (B,) per-slot token counts (continuous batching).
    assert cache is not None and cache_pos is not None
    c = cache["ckv"].shape[1]
    slot = (cache_pos % c).astype(jnp.int32)                 # (B,)
    upd = jax.vmap(
        lambda buf, new, s: jax.lax.dynamic_update_slice(buf, new, (s, 0)))
    ckv_cache = upd(cache["ckv"], ckv.astype(cache["ckv"].dtype), slot)
    kr_cache = upd(cache["krope"], k_rope.astype(cache["krope"].dtype),
                   slot)
    n_written = jnp.minimum(cache_pos + 1, c)                # (B,)
    valid = jnp.arange(c)[None, :] < n_written[:, None]      # (B, C)

    w_uk = p["w_uk"].astype(dt).reshape(m.kv_lora_rank, h,
                                        m.qk_nope_head_dim)
    # absorb W_uk into the query: (B,1,H,R)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    from repro.kernels import ops as kops
    ctx_lat = kops.mla_decode_attention(
        q_abs, q_rope, ckv_cache.astype(dt), kr_cache.astype(dt), valid,
        scale=scale)                                          # (B,1,H,R)
    w_uv = p["w_uv"].astype(dt).reshape(m.kv_lora_rank, h, m.v_head_dim)
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv)
    out = ctx.reshape(b, 1, h * m.v_head_dim) @ p["wo"].astype(dt)
    return out, {"ckv": ckv_cache, "krope": kr_cache}
