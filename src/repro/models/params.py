"""Parameter accounting (exact, via jax.eval_shape — no allocation).

Used by the roofline analysis: MODEL_FLOPS = 6·N·D with N the
non-embedding parameter count (active count for MoE).
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

_EMBED_KEYS = ("embed", "pos_embed", "enc_pos_embed", "lm_head")
_EXPERT_KEYS = ("w_gate", "w_up", "w_down")


@lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig):
    from repro.models.transformer import Transformer
    model = Transformer(cfg)
    tree = jax.eval_shape(model.init, jax.random.key(0))
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def count_params(cfg: ModelConfig) -> int:
    return sum(math.prod(leaf.shape) for _, leaf in _shapes(cfg))


def count_params_analytic(cfg: ModelConfig) -> int:
    """Non-embedding parameter count."""
    total = 0
    for path, leaf in _shapes(cfg):
        ps = _path_str(path)
        if any(ps.endswith(k) or f"/{k}" in ps for k in _EMBED_KEYS):
            continue
        total += math.prod(leaf.shape)
    return total


def count_active_params_analytic(cfg: ModelConfig) -> int:
    """Non-embedding params active per token (MoE: k/E of expert weights,
    shared experts always on)."""
    if cfg.moe is None:
        return count_params_analytic(cfg)
    frac = cfg.moe.experts_per_token / cfg.moe.num_experts
    total = 0
    for path, leaf in _shapes(cfg):
        ps = _path_str(path)
        if any(ps.endswith(k) or f"/{k}" in ps for k in _EMBED_KEYS):
            continue
        n = math.prod(leaf.shape)
        if "moe" in ps and any(ps.endswith(k) for k in _EXPERT_KEYS):
            n = int(n * frac)
        total += n
    return total
