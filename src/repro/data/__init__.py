from repro.data.video import (  # noqa: F401
    OracleEmbedder,
    Query,
    VideoWorld,
    WorldConfig,
)
