"""Procedural video world with ground-truth events.

Drives every accuracy-shaped experiment: the world emits a frame stream
partitioned into scenes; each scene carries a latent *event* (type id +
object labels + OCR-able text). Queries target event types; a retrieval
is *correct* when the selected frames cover the queried event's scenes
(coverage/recall — the measurable analogue of the paper's VQA accuracy,
since we cannot host LLaVA/Qwen checkpoints offline).

Scenes are visually coherent (static seeded background + a moving sprite
whose colour encodes the event) so Venus's scene segmentation and
clustering see realistic structure: high φ at scene cuts, low within.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_OBJECTS = ["person", "dog", "cat", "car", "cup", "pan", "pill", "book",
            "phone", "ball", "plant", "door", "kettle", "laptop", "broom",
            "remote"]


@dataclass(frozen=True)
class WorldConfig:
    n_scenes: int = 10
    scene_len_min: int = 30
    scene_len_max: int = 90
    resolution: int = 48
    n_event_types: int = 8
    event_repeat_prob: float = 0.35   # chance a scene reuses an event type
    noise: float = 0.01
    seed: int = 0


@dataclass
class Scene:
    scene_id: int
    start: int
    end: int                          # exclusive
    event: int
    objects: List[str]
    text: str
    # the event *moment*: the sprite (the visual evidence) is only
    # visible inside [w_start, w_end) — answering a query about the event
    # requires a frame from the window, not just any scene frame.
    w_start: int = 0
    w_end: int = 0


class VideoWorld:
    def __init__(self, cfg: WorldConfig = WorldConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.scenes: List[Scene] = []
        frames: List[np.ndarray] = []
        t = 0
        used_events: List[int] = []
        for s in range(cfg.n_scenes):
            if used_events and rng.random() < cfg.event_repeat_prob:
                ev = int(rng.choice(used_events))
            else:
                ev = int(rng.integers(cfg.n_event_types))
            used_events.append(ev)
            length = int(rng.integers(cfg.scene_len_min,
                                      cfg.scene_len_max + 1))
            objs = [_OBJECTS[ev % len(_OBJECTS)],
                    _OBJECTS[(ev * 3 + s) % len(_OBJECTS)]]
            text = f"event{ev}"
            # event window: ~30% of the scene, somewhere in the middle
            wlen = max(length // 3, 4)
            woff = int(rng.integers(2, max(length - wlen - 1, 3)))
            self.scenes.append(Scene(s, t, t + length, ev, objs, text,
                                     w_start=t + woff,
                                     w_end=t + woff + wlen))
            frames.append(self._render_scene(rng, s, ev, length,
                                             woff, woff + wlen))
            t += length
        self.frames = np.concatenate(frames, axis=0)      # (T,H,W,3) f32
        self.total_frames = t
        self.scene_of_frame = np.zeros((t,), np.int32)
        for sc in self.scenes:
            self.scene_of_frame[sc.start:sc.end] = sc.scene_id

    # ------------------------------------------------------------- rendering
    def _render_scene(self, rng, scene_id: int, event: int,
                      length: int, w0: int = 0, w1: int = 10**9
                      ) -> np.ndarray:
        r = self.cfg.resolution
        base_rng = np.random.default_rng(self.cfg.seed * 1000 + scene_id)
        # static background: smooth gradient + fixed texture
        gx = np.linspace(0, 1, r)[None, :, None]
        gy = np.linspace(0, 1, r)[:, None, None]
        base_color = base_rng.random((1, 1, 3)) * 0.5 + 0.2
        texture = base_rng.random((r, r, 3)) * 0.08
        bg = np.clip(base_color + 0.25 * gx + 0.15 * gy + texture, 0, 1)

        # sprite colour encodes the event type
        hue = (event / max(self.cfg.n_event_types, 1))
        sprite = np.array([hue, 1.0 - hue, 0.5 + 0.5 * hue])
        size = max(r // 8, 2)
        out = np.empty((length, r, r, 3), np.float32)
        lim = r - size
        cx = int(base_rng.integers(0, lim))
        cy = int(base_rng.integers(0, lim))
        vx, vy = (int(v) for v in base_rng.integers(1, 3, size=2))
        for i in range(length):
            f = bg.copy()
            if w0 <= i < w1:    # sprite visible only during the event
                # bouncing motion (no teleport ⇒ smooth within-scene φ)
                x = cx + vx * i
                y = cy + vy * i
                x = int(lim - abs(lim - (x % (2 * lim))))
                y = int(lim - abs(lim - (y % (2 * lim))))
                f[y:y + size, x:x + size] = sprite
            f += rng.normal(0, self.cfg.noise, f.shape)
            out[i] = np.clip(f, 0, 1)
        return out

    # ------------------------------------------------------------- metadata
    def annotations(self, frame_idx: int) -> Dict:
        sc = self.scenes[int(self.scene_of_frame[frame_idx])]
        vis = sc.w_start <= int(frame_idx) < sc.w_end
        return {"objects": sc.objects if vis else [],
                "text": sc.text if vis else "",
                "event": sc.event, "event_visible": vis}

    def frame_in_window(self, frame_idx: int) -> bool:
        sc = self.scenes[int(self.scene_of_frame[int(frame_idx)])]
        return sc.w_start <= int(frame_idx) < sc.w_end

    def scenes_with_event(self, event: int) -> List[Scene]:
        return [s for s in self.scenes if s.event == event]

    # --------------------------------------------------------------- queries
    def make_queries(self, n: int, seed: int = 1
                     ) -> List["Query"]:
        rng = np.random.default_rng(seed)
        events = sorted({s.event for s in self.scenes})
        out = []
        for i in range(n):
            ev = int(events[rng.integers(len(events))])
            scs = self.scenes_with_event(ev)
            out.append(Query(
                text=f"find event{ev} {_OBJECTS[ev % len(_OBJECTS)]}",
                event=ev,
                relevant_scenes=[s.scene_id for s in scs],
                dispersion=len(scs)))
        return out


@dataclass
class Query:
    text: str
    event: int
    relevant_scenes: List[int]
    dispersion: int               # number of scenes holding the answer


# ---------------------------------------------------------------------------
# Oracle embedder: a "perfect MEM" for isolating retrieval-algorithm
# quality (documented in DESIGN.md; the trained MEM path is exercised by
# examples/train_mem.py + the end-to-end integration test).
# ---------------------------------------------------------------------------


class OracleEmbedder:
    """Embeds frames/queries into an event+scene structured space.

    embedding(frame) = unit(event_basis[ev] + w·scene_basis[scene] + ε).
    embedding(query) = unit(event_basis[ev] + w·scene_basis[anchor] + ε/2)
    where ``anchor`` is one occurrence of the event — reproducing the
    paper's Fig. 5 structure: the query matches one occurrence's frames
    *most* strongly (temporal neighbourhood), other occurrences of the
    same event somewhat less, everything else weakly. Greedy Top-K then
    concentrates on the anchor scene; sampling spreads over all relevant
    scenes.
    """

    def __init__(self, world: VideoWorld, dim: int = 64,
                 noise: float = 0.08, scene_weight: float = 0.45,
                 seed: int = 7):
        self.world = world
        self.dim = dim
        self.noise = noise
        self.scene_weight = scene_weight
        rng = np.random.default_rng(seed)
        self._event_basis = self._unit_rows(rng.normal(
            0, 1, (world.cfg.n_event_types, dim)))
        self._scene_basis = self._unit_rows(rng.normal(
            0, 1, (world.cfg.n_scenes, dim)))
        self._rng = rng

    @staticmethod
    def _unit_rows(x):
        x = np.asarray(x, np.float32)
        return x / np.linalg.norm(x, axis=-1, keepdims=True)

    def embed_frames(self, frames, aux_texts=None,
                     frame_ids: Optional[Sequence[int]] = None
                     ) -> np.ndarray:
        """Pipeline-compatible: identifies frames by id (frame_ids if
        given, else ``frames`` is itself a sequence of ids)."""
        frame_idx = frame_ids if frame_ids is not None else frames
        frame_idx = np.asarray(frame_idx)
        anns = [self.world.annotations(int(i)) for i in frame_idx]
        evs = np.asarray([a["event"] for a in anns])
        vis = np.asarray([a.get("event_visible", True) for a in anns],
                         np.float32)[:, None]
        scs = self.world.scene_of_frame[frame_idx]
        # the MEM only "sees" the event while its evidence is on screen
        e = (self._event_basis[evs] * (0.2 + 0.8 * vis)
             + self.scene_weight * self._scene_basis[scs])
        e = e + self._rng.normal(0, self.noise, e.shape)
        return self._unit_rows(e)

    def embed_query(self, query: Query) -> np.ndarray:
        anchor = query.relevant_scenes[0]
        e = (self._event_basis[query.event]
             + self.scene_weight * self._scene_basis[anchor])
        e = e + self._rng.normal(0, self.noise * 0.5, e.shape)
        return self._unit_rows(e)

    def embed_queries(self, queries: Sequence[Query]) -> np.ndarray:
        return np.stack([self.embed_query(q) for q in queries])


class PixelEmbedder:
    """Deterministic content-only embedder: pooled pixels through a fixed
    random projection, L2-normalised.

    Unlike ``OracleEmbedder`` it looks only at the frames themselves (no
    world metadata keyed by absolute frame id), so it is safe for
    multi-stream ingestion where per-session frame ids collide — and its
    output is a pure function of pixel content, which the session-
    equivalence tests rely on.
    """

    def __init__(self, dim: int = 64, pool: int = 8, seed: int = 13):
        self.dim = dim
        self.pool = pool
        self.seed = seed
        self._proj: Optional[np.ndarray] = None

    def _projection(self, d_in: int) -> np.ndarray:
        if self._proj is None or self._proj.shape[0] != d_in:
            rng = np.random.default_rng(self.seed)
            self._proj = rng.normal(
                0, 1.0 / np.sqrt(d_in), (d_in, self.dim)).astype(np.float32)
        return self._proj

    def embed_frames(self, frames, aux_texts=None, frame_ids=None
                     ) -> np.ndarray:
        from repro.core.clustering import frame_vectors
        import jax.numpy as jnp
        v = np.asarray(frame_vectors(
            jnp.asarray(np.asarray(frames, np.float32)), self.pool))
        proj = self._projection(v.shape[-1])
        # project row-by-row: BLAS batches change the summation order, and
        # the session-equivalence tests need embeddings that are a pure
        # function of each frame, independent of who shares the batch
        e = np.stack([row @ proj for row in v])
        return e / np.linalg.norm(e, axis=-1, keepdims=True)

    def embed_query(self, text: str) -> np.ndarray:
        # crc32, not hash(): Python's str hash is salted per process and
        # would break cross-run reproducibility
        import zlib
        rng = np.random.default_rng(
            (zlib.crc32(str(text).encode()) ^ self.seed) & 0x7FFFFFFF)
        e = rng.normal(0, 1, (self.dim,)).astype(np.float32)
        return e / np.linalg.norm(e)

    def embed_queries(self, texts: Sequence[str]) -> np.ndarray:
        return np.stack([self.embed_query(t) for t in texts])
