"""Byte-pair-free word-hash tokenizer + synthetic LM/contrastive data.

A deterministic hashing tokenizer is all the text substrate the system
needs offline: queries, aux prompts and captions map to stable ids within
the model's vocab.
"""

from __future__ import annotations

import hashlib
from typing import Iterator, List, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_RESERVED = 3


def tokenize(text: str, vocab_size: int, max_len: int,
             add_special: bool = True) -> np.ndarray:
    ids: List[int] = [BOS] if add_special else []
    for w in text.lower().split():
        h = int.from_bytes(hashlib.blake2s(w.encode(),
                                           digest_size=4).digest(), "big")
        ids.append(_RESERVED + (h % (vocab_size - _RESERVED)))
    if add_special:
        ids.append(EOS)
    ids = ids[:max_len]
    out = np.full((max_len,), PAD, np.int32)
    out[: len(ids)] = ids
    return out


def tokenize_batch(texts: List[str], vocab_size: int, max_len: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    toks = np.stack([tokenize(t, vocab_size, max_len) for t in texts])
    mask = toks != PAD
    return toks, mask


# ---------------------------------------------------------------------------
# synthetic LM stream (for train_step substrate + dry-run realism)
# ---------------------------------------------------------------------------


def lm_batches(vocab_size: int, batch: int, seq: int, seed: int = 0
               ) -> Iterator[dict]:
    """Markov-ish synthetic token stream with learnable structure."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(_RESERVED, vocab_size,
                         size=(min(vocab_size, 4096),), dtype=np.int32)
    while True:
        x = np.empty((batch, seq + 1), np.int32)
        x[:, 0] = rng.integers(_RESERVED, vocab_size, size=(batch,))
        for t in range(seq):
            follow = trans[x[:, t] % len(trans)]
            noise = rng.integers(_RESERVED, vocab_size, size=(batch,))
            pick = rng.random(batch) < 0.8
            x[:, t + 1] = np.where(pick, follow, noise)
        yield {"tokens": x[:, :-1], "labels": x[:, 1:]}
