"""Multi-tenant edge box: Venus sessions feeding the serving engine.

The deployment scenario the paper targets (§II): one edge box ingests N
concurrent camera streams and answers real-time queries against any of
them with a (cloud) VLM. This module wires the session layer into the
continuous-batching engine:

  camera chunks ──ingest_tick──▶ SessionManager (per-stream memories)
  user queries  ──query_batch──▶ retrieved keyframe sets per stream
                └─▶ patch-embedded into ``Request.vision_embeds`` and
                    submitted to the ``ServingEngine`` slots.

Queries arriving in the same service tick are grouped by budget ONLY —
not by ``(session, budget)`` — and each group runs through the fused
cross-session query path: one similarity scan over the stacked session
indices answers every query in the group, regardless of how many
sessions it spans, and the VLM answers them under continuous batching.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.pipeline import patchify
from repro.core.session import SessionManager
from repro.serving.engine import Request, ServingEngine


@dataclass
class StreamQuery:
    """A user query against one camera stream."""
    rid: int
    sid: int
    text: str
    prompt_tokens: np.ndarray
    query_emb: Optional[np.ndarray] = None
    budget: Optional[int] = None
    max_new_tokens: int = 12
    # filled by the service
    frame_ids: Optional[np.ndarray] = None


class VenusService:
    """Session manager + serving engine behind one submission API."""

    def __init__(self, manager: SessionManager, engine: ServingEngine, *,
                 max_frames: int = 4, patch: int = 8):
        self.manager = manager
        self.engine = engine
        self.max_frames = max_frames
        self.patch = patch

    # ------------------------------------------------------------- ingestion
    def create_stream(self, sid: Optional[int] = None) -> int:
        return self.manager.create_session(sid)

    def ingest_tick(self, chunks: Mapping[int, np.ndarray]
                    ) -> Dict[str, float]:
        return self.manager.ingest_tick(chunks)

    def flush(self) -> None:
        self.manager.flush()

    # --------------------------------------------------------------- serving
    def _vision_embeds(self, sid: int, frame_ids: np.ndarray) -> np.ndarray:
        """Retrieved raw frames → the VLM's prefix vision tokens."""
        cfg = self.engine.cfg
        st = self.manager[sid]
        if len(frame_ids) == 0:
            return np.zeros((cfg.vision_tokens, cfg.d_model), np.float32)
        frames = st.frames.get(frame_ids[: self.max_frames])
        pe = np.asarray(patchify(frames, self.patch, cfg.d_model))
        pe = pe.reshape(-1, cfg.d_model)[: cfg.vision_tokens]
        if pe.shape[0] < cfg.vision_tokens:
            pe = np.pad(pe, ((0, cfg.vision_tokens - pe.shape[0]), (0, 0)))
        return pe.astype(np.float32)

    def submit(self, queries: Sequence[StreamQuery]) -> List[Request]:
        """Retrieve (ONE fused cross-session scan per budget group, no
        matter how many streams), build the VLM requests, and enqueue
        them on the engine."""
        groups: Dict[Optional[int], List[StreamQuery]] = {}
        for q in queries:
            groups.setdefault(q.budget, []).append(q)
        reqs: List[Request] = []
        for budget, group in groups.items():
            # honour caller-supplied embeddings; embed only the rest
            embs = np.stack([
                q.query_emb if q.query_emb is not None
                else self.manager.embedder.embed_query(q.text)
                for q in group])
            results = self.manager.query_batch_cross(
                [q.sid for q in group], [q.text for q in group],
                query_embs=embs, budget=budget)
            for q, res in zip(group, results):
                q.frame_ids = res.frame_ids
                req = Request(
                    rid=q.rid, tokens=np.asarray(q.prompt_tokens, np.int32),
                    max_new_tokens=q.max_new_tokens,
                    vision_embeds=self._vision_embeds(q.sid, res.frame_ids))
                reqs.append(req)
                self.engine.submit(req)
        return reqs

    def answer(self, queries: Sequence[StreamQuery]) -> List[Request]:
        """Submit and drain: run engine steps until every slot is free."""
        self.submit(queries)
        return self.engine.drain()
