"""Multi-tenant edge box: Venus sessions feeding the serving engine.

The deployment scenario the paper targets (§II): one edge box ingests N
concurrent camera streams and answers real-time queries against any of
them with a (cloud) VLM. This module wires the session layer into the
continuous-batching engine:

  camera chunks ──ingest_tick──▶ SessionManager (per-stream memories)
  user queries  ──query_batch──▶ retrieved keyframe sets per stream
                └─▶ patch-embedded into ``Request.vision_embeds`` and
                    submitted to the ``ServingEngine`` slots.

Queries arriving in the same service tick compile to ONE query plan:
each query becomes a declarative ``QuerySpec`` and the planner groups
compatible specs (same strategy + budget class) into execution groups —
one fused similarity scan answers a whole group regardless of how many
sessions it spans, whatever the strategy mix, and the VLM answers
everything under continuous batching. The scan operand is the session
manager's grow-in-place ``MemoryArena`` (ingest ticks append into the
shared device super-buffers, queries consume them as-is), so a serving
deployment never restacks device memory between ingest and answer —
``VenusService.io_stats()["stack_rebuilds"]`` stays 0.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.pipeline import patchify
from repro.core.queryplan import QueryPlan, QuerySpec
from repro.core.session import SessionManager
from repro.core.standing import Alert
from repro.kernels import ops as kops
from repro.serving.engine import Request, ServingEngine


@dataclass
class StreamQuery:
    """A user query against one camera stream."""
    rid: int
    sid: int
    text: str
    prompt_tokens: np.ndarray
    query_emb: Optional[np.ndarray] = None
    budget: Optional[int] = None
    strategy: str = "akr"          # any registered retrieval strategy
    max_new_tokens: int = 12
    # filled by the service
    frame_ids: Optional[np.ndarray] = None

    def to_spec(self) -> QuerySpec:
        return QuerySpec(sid=self.sid, text=self.text,
                         embedding=self.query_emb,
                         strategy=self.strategy, budget=self.budget)


class VenusService:
    """Session manager + serving engine behind one submission API."""

    def __init__(self, manager: SessionManager, engine: ServingEngine, *,
                 max_frames: int = 4, patch: int = 8):
        self.manager = manager
        self.engine = engine
        self.max_frames = max_frames
        self.patch = patch

    # ------------------------------------------------------------- ingestion
    def create_stream(self, sid: Optional[int] = None, *,
                      eviction: Optional[str] = None) -> int:
        """Open a camera stream (recycles a freed arena slot when one
        exists). ``eviction`` picks this stream's bounded-memory policy
        ("none" | "sliding_window" | "cluster_merge" | "consolidate") —
        24/7 streams should use a window policy so they never stop
        ingesting. "consolidate" additionally folds evictees into the
        manager-wide coarse summary tier (``VenusConfig
        (coarse_capacity=...)``) so long-horizon queries keep answering
        through the two-stage coarse→fine scan after the fine window
        moved on. A stream left on "none" raises a "memory full" error
        from ``ingest_tick`` once its capacity fills."""
        return self.manager.create_session(sid, eviction=eviction)

    def close_stream(self, sid: int) -> Dict[str, int]:
        """End a camera stream: frees its arena slot for the next
        ``create_stream`` (slot recycling — zero device work, zero
        restacks; visible as ``arena_slot_releases``/``sessions_closed``
        in ``io_stats()``). Returns the stream's final ingest stats."""
        return self.manager.close_session(sid)

    def ingest_tick(self, chunks: Mapping[int, np.ndarray]
                    ) -> Dict[str, float]:
        return self.manager.ingest_tick(chunks)

    def flush(self) -> None:
        self.manager.flush()

    # --------------------------------------------------------------- serving
    def _vision_embeds(self, sid: int, frame_ids: np.ndarray) -> np.ndarray:
        """Retrieved raw frames → the VLM's prefix vision tokens."""
        cfg = self.engine.cfg
        st = self.manager[sid]
        if len(frame_ids) == 0:
            return np.zeros((cfg.vision_tokens, cfg.d_model), np.float32)
        frames = st.frames.get(frame_ids[: self.max_frames])
        pe = np.asarray(patchify(frames, self.patch, cfg.d_model))
        pe = pe.reshape(-1, cfg.d_model)[: cfg.vision_tokens]
        if pe.shape[0] < cfg.vision_tokens:
            pe = np.pad(pe, ((0, cfg.vision_tokens - pe.shape[0]), (0, 0)))
        return pe.astype(np.float32)

    def plan(self, queries: Sequence[StreamQuery]) -> QueryPlan:
        """The retrieval plan one service tick compiles to — inspectable
        before anything runs (``plan.n_scans`` == number of execution
        groups == number of fused scans)."""
        return self.manager.plan([q.to_spec() for q in queries])

    def submit(self, queries: Sequence[StreamQuery]) -> List[Request]:
        """Compile the tick's queries into ONE plan (the planner groups
        compatible specs; each group costs one fused cross-session scan
        no matter how many streams it spans), retrieve, build the VLM
        requests, and enqueue them on the engine in arrival order."""
        results = self.manager.execute(self.plan(queries))
        reqs: List[Request] = []
        for q, res in zip(queries, results):
            q.frame_ids = res.frame_ids
            req = Request(
                rid=q.rid, tokens=np.asarray(q.prompt_tokens, np.int32),
                max_new_tokens=q.max_new_tokens,
                vision_embeds=self._vision_embeds(q.sid, res.frame_ids))
            reqs.append(req)
            self.engine.submit(req)
        return reqs

    def answer(self, queries: Sequence[StreamQuery]) -> List[Request]:
        """Submit and drain: run engine steps until every slot is free."""
        self.submit(queries)
        return self.engine.drain()

    # ------------------------------------------------------ standing queries
    def register_standing(self, sid: int, query, *, threshold: float,
                          hysteresis: float = 0.0,
                          cooldown_ticks: int = 0,
                          priority: float = 0.0) -> int:
        """Register a persistent trigger on a stream: evaluated inside
        every ``ingest_tick`` against only that tick's newly committed
        memory rows (one extra slab-sized fused launch — see
        ``kops_standing_scan_bytes``), firing debounced ``Alert``s
        through ``poll_alerts()`` / ``on_alert`` callbacks. ``query``
        is a ``QuerySpec`` or a ``StreamQuery`` (converted via
        ``to_spec``); returns the spec id for
        ``manager.unregister_standing``."""
        spec = query.to_spec() if isinstance(query, StreamQuery) else query
        return self.manager.register_standing(
            sid, spec, threshold=threshold, hysteresis=hysteresis,
            cooldown_ticks=cooldown_ticks, priority=priority)

    def poll_alerts(self, max_alerts: Optional[int] = None
                    ) -> List[Alert]:
        """Drain pending standing-query alerts, priority-ordered
        (priority desc, then score desc, then tick/firing order) —
        the pull half of the delivery surface."""
        return self.manager.poll_alerts(max_alerts)

    def on_alert(self, callback) -> None:
        """Push half of the delivery surface: ``callback(alert)`` runs
        once per fired alert, in priority order within each ingest
        tick, immediately after the tick's standing evaluation. Alerts
        remain pollable regardless — callbacks observe the stream,
        ``poll_alerts`` drains it."""
        self.manager.standing.on_alert(callback)

    # ------------------------------------------------------------ monitoring
    def io_stats(self) -> Dict[str, int]:
        """One monitoring surface over the whole service: the manager's
        scan/restack/lifecycle counters, the arena's
        grow/append/slot-recycling counters (``arena_*``), and the
        per-memory transfer/eviction counters summed over live AND
        closed sessions (``mem_*`` — the manager folds a closing
        stream's counters into ``closed_mem_stats``, so the sums stay
        monotonic across churn). The production invariants to alert on:
        ``stack_rebuilds == 0`` (arena mode), ``mem_full_uploads`` flat
        after warm-up, and ``arena_grows`` flat under churn (slot
        recycling — churned streams must reuse slots, not grow the
        arena). For 24/7 streams, ``mem_evicted_rows`` rising at the
        ingest rate is HEALTHY steady-state; see the counter glossary in
        ARCHITECTURE.md.

        The ``kops_*`` counters come from the kernel dispatch layer
        (``repro.kernels.ops.scan_counts`` — process-global, shared by
        every manager in the process): ``kops_scan_bytes`` is the index
        bytes streamed by all similarity scans (int8 indices count 1
        byte/element — the quantisation lever),
        ``kops_fused_draw_launches`` counts scans resolved in the fused
        epilogue (no dense score tensor), ``kops_dense_score_launches``
        counts scans that DID materialise (S, Q, cap) scores (the
        BOLT/MDF/AKS fallback and legacy ``search`` calls).

        Hierarchical-tier deployments (``eviction="consolidate"``) add
        the two-stage counters: ``kops_coarse_scan_bytes`` (the subset
        of ``kops_scan_bytes`` streamed by stage-1 scans over the
        summary tier), ``kops_fine_gather_rows`` (candidate fine rows
        gathered into stage-2 operands), ``kops_two_stage_scans`` /
        ``two_stage_groups`` (kernel- and plan-level counts of
        completed coarse→fine retrievals), and ``mem_consolidated_rows``
        / ``arena_coarse_appends`` (evictees folded into summary rows,
        and the deferred scatters that pushed them to the device tier).
        The bandwidth invariant to alert on: per query group,
        ``kops_coarse_scan_bytes`` plus the gathered candidate bytes
        stay below one flat capacity×dim scan.

        Sharded deployments additionally surface ``arena_shards`` (the
        mesh ``model``-axis size the arena slot axis is slabbed over),
        ``sharded_group_scans`` (plan-level launches that fanned out
        under shard_map), ``kops_sharded_stack_launches`` (kernel-level
        count of the same), and ``kops_shard_gather_bytes`` — the bytes
        of per-shard scan OUTPUTS crossing shard boundaries at the
        candidate gather: O(S·Q·(T+K)) fused, no O(S·Q·capacity) term,
        which is the whole point of scanning shard-locally.
        ``archive_trimmed_frames`` counts host frames the bounded
        ``FrameStore`` dropped below the live eviction windows.

        Spill-tier deployments (``VenusConfig(spill_dir=...)``) add the
        storage-tier counters, summed over live AND closed sessions
        (closes fold into ``closed_frame_stats`` like the ``mem_*``
        sums): ``spilled_frames`` / ``spilled_bytes`` (demotions the
        host tier wrote to disk segments), ``spill_faults`` (segment
        loads a ``get`` of a spilled id paid), ``spill_cache_hits``
        (spilled reads served from the LRU segment cache), and the
        gauge ``spill_disk_bytes`` (bytes currently in live sessions'
        segment files — returns to baseline when streams close, which
        is the disk-leak invariant to alert on).

        Standing-query deployments add ``standing_specs`` (gauge: live
        registered specs), ``alerts_fired`` / ``alerts_suppressed``
        (debounced trigger outcomes, from the manager counters), and
        ``kops_standing_scan_bytes`` — the index bytes streamed by the
        per-tick new-row slab launches. The invariant to alert on:
        ``kops_standing_scan_bytes`` grows O(new_rows · dim) per tick,
        NEVER O(capacity · dim) — standing evaluation must ride the
        ingest path, not re-scan history."""
        out: Dict[str, int] = dict(self.manager.io_stats)
        out["standing_specs"] = self.manager.standing.n_specs
        for k, v in kops.scan_counts().items():
            out[f"kops_{k}"] = v
        if self.manager.arena is not None:
            for k, v in self.manager.arena.io_stats.items():
                out[f"arena_{k}"] = v
            out["arena_shards"] = self.manager.arena.n_shards
        mem_sums = dict(self.manager.closed_mem_stats)
        for st in self.manager.sessions.values():
            for k, v in st.memory.io_stats.items():
                mem_sums[k] = mem_sums.get(k, 0) + v
        for k, v in mem_sums.items():
            out[f"mem_{k}"] = v
        frame_sums = dict(self.manager.closed_frame_stats)
        disk_bytes = 0
        for st in self.manager.sessions.values():
            for k, v in st.frames.io_stats.items():
                frame_sums[k] = frame_sums.get(k, 0) + v
            disk_bytes += st.frames.disk_bytes
        out.update(frame_sums)
        out["spill_disk_bytes"] = disk_bytes
        return out
