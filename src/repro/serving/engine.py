"""Serving engine: prefill/decode with continuous batching.

Design (vLLM-style, TPU-native):

* Fixed ``batch_slots`` decode batch; each slot holds one in-flight
  request's KV cache region. Caches are per-slot positional (``pos`` is
  (B,)), so slots advance independently — a finished request frees its
  slot and a pending one is admitted without stalling the others.
* Prefill runs at batch 1 over power-of-two padded prompt buckets (bounds
  jit cache size), then the resulting cache is scattered into the slot
  with a single jit'd ``dynamic_update_slice`` per leaf.
* The batch axis of every cache leaf is discovered *structurally* (by
  diffing ``init_cache(2)`` vs ``init_cache(3)`` shapes), so the engine
  is agnostic to cache layouts across families (GQA / MLA / Mamba2 /
  RWKV6 / enc-dec).
* ``serve_step`` — the function the decode dry-run shapes lower — is one
  decode token for the full slot batch against a ``seq_len`` cache.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import Transformer
from repro.util import pow2_bucket

PAD = 0
EOS = 2


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                       # (S,) prompt
    max_new_tokens: int = 16
    vision_embeds: Optional[np.ndarray] = None
    encoder_frames: Optional[np.ndarray] = None
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 1024, temperature: float = 0.0,
                 cache_dtype=jnp.bfloat16, seed: int = 0):
        self.cfg = cfg
        self.model = Transformer(cfg)
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self._key = jax.random.key(seed)

        self.cache = self.model.init_cache(batch_slots, max_len,
                                           dtype=cache_dtype)
        self._batch_axes = self._discover_batch_axes(cache_dtype)
        self._slot_req: List[Optional[Request]] = [None] * batch_slots
        self._pending: List[Request] = []
        self._done: List[Request] = []

        self._decode_fn = jax.jit(self._decode_impl)
        self._prefill_fn = jax.jit(self._prefill_impl,
                                   static_argnames=("with_vision",
                                                    "with_audio"))
        self._insert_fn = jax.jit(self._insert_impl)
        self._cache_dtype = cache_dtype

    # ----------------------------------------------------------- structural
    def _discover_batch_axes(self, cache_dtype) -> Any:
        c2 = jax.eval_shape(lambda: self.model.init_cache(2, 32,
                                                          cache_dtype))
        c3 = jax.eval_shape(lambda: self.model.init_cache(3, 32,
                                                          cache_dtype))

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return None                     # no batch axis (shouldn't occur)
        return jax.tree.map(axis, c2, c3)

    # ----------------------------------------------------------------- jits
    def _decode_impl(self, params, cache, tokens, key):
        logits, new_cache, _ = self.model.apply(params, tokens,
                                                cache=cache, mode="decode")
        lg = logits[:, -1].astype(jnp.float32)
        if self.temperature > 0:
            nxt = jax.random.categorical(key, lg / self.temperature, -1)
        else:
            nxt = jnp.argmax(lg, -1)
        return nxt.astype(jnp.int32), new_cache

    def _prefill_impl(self, params, tokens, lengths, vision_embeds,
                      encoder_frames, *, with_vision: bool,
                      with_audio: bool):
        cache = self.model.init_cache(tokens.shape[0], self.max_len,
                                      dtype=self._cache_dtype)
        kw = {}
        if with_vision:
            kw["vision_embeds"] = vision_embeds
        if with_audio:
            kw["encoder_frames"] = encoder_frames
        logits, cache, _ = self.model.apply(params, tokens, cache=cache,
                                            mode="prefill",
                                            prompt_lengths=lengths, **kw)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)
        return nxt.astype(jnp.int32), cache

    def _insert_impl(self, batch_cache, one_cache, slot):
        def ins(buf, new, ax):
            if ax is None:
                return buf
            return jax.lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), slot, axis=ax)
        return jax.tree.map(ins, batch_cache, one_cache, self._batch_axes)

    # ------------------------------------------------------------------ api
    def submit(self, req: Request) -> None:
        req.submitted_at = time.perf_counter()
        self._pending.append(req)

    def _admit(self) -> None:
        for slot in range(self.batch_slots):
            if self._slot_req[slot] is not None or not self._pending:
                continue
            req = self._pending.pop(0)
            toks = np.asarray(req.tokens[-self.max_len:], np.int32)
            s = len(toks)
            recurrent = (self.cfg.family in ("ssm", "hybrid")
                         or self.cfg.rwkv is not None)
            # Attention archs: right-pad prompts into power-of-two buckets
            # (bounds jit specialisations); the pad keys are masked via
            # prompt_lengths and overwritten as decode advances. Recurrent
            # archs (SSM/RWKV/hybrid) would fold pads into their state, so
            # they prefill at exact length.
            bucket = s if recurrent else pow2_bucket(s, lo=16)
            buf = np.full((bucket,), PAD, np.int32)
            buf[:s] = toks              # right-pad
            nv = (req.vision_embeds.shape[0]
                  if req.vision_embeds is not None else 0)
            lengths = jnp.asarray([s + nv], jnp.int32)
            nxt, one_cache = self._prefill_fn(
                self.params, jnp.asarray(buf)[None], lengths,
                (jnp.asarray(req.vision_embeds)[None]
                 if req.vision_embeds is not None else None),
                (jnp.asarray(req.encoder_frames)[None]
                 if req.encoder_frames is not None else None),
                with_vision=req.vision_embeds is not None,
                with_audio=req.encoder_frames is not None)
            self.cache = self._insert_fn(self.cache, one_cache,
                                         jnp.asarray(slot))
            req.generated.append(int(nxt[0]))
            req.first_token_at = time.perf_counter()
            self._slot_req[slot] = req

    def step(self) -> int:
        """Admit pending requests, run one decode step. Returns number of
        active slots."""
        self._admit()
        active = [i for i, r in enumerate(self._slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.full((self.batch_slots, 1), PAD, np.int32)
        for i, r in enumerate(self._slot_req):
            if r is not None:
                tokens[i, 0] = r.generated[-1]
        self._key, sub = jax.random.split(self._key)
        nxt, self.cache = self._decode_fn(self.params, self.cache,
                                          jnp.asarray(tokens), sub)
        nxt = np.asarray(nxt)
        for i in active:
            r = self._slot_req[i]
            r.generated.append(int(nxt[i]))
            done = (len(r.generated) >= r.max_new_tokens
                    or int(nxt[i]) == EOS)
            if done:
                r.finished_at = time.perf_counter()
                self._done.append(r)
                self._slot_req[i] = None
        return len(active)

    def drain(self) -> List[Request]:
        """Step until every pending/in-flight request finishes."""
        while self._pending or any(r is not None for r in self._slot_req):
            self.step()
        done, self._done = self._done, []
        return sorted(done, key=lambda r: r.rid)

    def run(self, requests: List[Request]) -> List[Request]:
        for r in requests:
            self.submit(r)
        return self.drain()


# ---------------------------------------------------------------------------
# serve_step: the decode-shape entry point the multi-pod dry-run lowers.
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, tokens (B,1), cache) -> (next (B,),
    cache) — one new token against a seq_len KV cache."""
    model = Transformer(cfg)

    def serve_step(params, tokens, cache):
        logits, new_cache, _ = model.apply(params, tokens, cache=cache,
                                           mode="decode")
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt.astype(jnp.int32), new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """Returns prefill(params, batch) -> (last-token logits, cache)."""
    model = Transformer(cfg)

    def prefill_step(params, tokens, vision_embeds=None,
                     encoder_frames=None):
        cache = model.init_cache(tokens.shape[0], max_len,
                                 dtype=jnp.bfloat16)
        kw = {}
        if cfg.family == "vlm":
            kw["vision_embeds"] = vision_embeds
        if cfg.family == "audio":
            kw["encoder_frames"] = encoder_frames
        logits, cache, _ = model.apply(params, tokens, cache=cache,
                                       mode="prefill", **kw)
        return logits, cache

    return prefill_step
