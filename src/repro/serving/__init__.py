from repro.serving.engine import (  # noqa: F401
    Request,
    ServingEngine,
    make_prefill_step,
    make_serve_step,
)
from repro.serving.venus_service import (  # noqa: F401
    StreamQuery,
    VenusService,
)
