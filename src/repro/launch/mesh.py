"""Production mesh construction.

Target: TPU v5e, 256 chips/pod. Single-pod mesh is (data=16, model=16);
multi-pod adds a leading "pod" axis: (pod=2, data=16, model=16) = 512
chips. A *function* (not a module constant) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import AbstractMesh


def make_abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-free mesh for sharding-rule tests / dry runs.

    ``AbstractMesh`` changed signature across JAX versions: newer ones
    take ``(axis_sizes, axis_names)``, older ones (≤0.4.x) a single
    tuple of ``(name, size)`` pairs. Try the new form first so the
    compat cost disappears once the old API is gone.
    """
    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh on whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_memory_mesh(shards: int = 0):
    """The mesh a sharded ``MemoryArena`` / ``DistributedVenusMemory``
    wants: all ``shards`` devices on the ``model`` axis (the slot/row
    slab axis), data=1. ``shards=0`` means every visible device. Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` (set BEFORE
    jax initialises — the multi-device CI lane exports it as a job env
    var) this gives K host-platform shards for equivalence testing."""
    n = len(jax.devices())
    return make_host_mesh(model=n if shards <= 0 else min(shards, n))


def data_axes(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


HARDWARE = {
    "name": "TPU v5e",
    "peak_bf16_flops": 197e12,        # per chip
    "hbm_bw": 819e9,                  # bytes/s per chip
    "ici_bw": 50e9,                   # bytes/s per link (~3 links usable)
    "hbm_bytes": 16e9,
    "chips_per_pod": 256,
}
