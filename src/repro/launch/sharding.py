"""Sharding rules: pytree paths → PartitionSpecs.

One rule table covers every architecture because param names are uniform
across families (DESIGN.md §5):

* **TP (model axis)** — attention heads (wq/wk/wv out, wo in), FFN hidden
  (w_up/w_gate out, w_down in), MoE experts (leading E dim), MLA
  up-projections, Mamba2 head-dim projections (in_x/in_dt/conv_x,
  out_proj in), RWKV head projections, vocab (embed rows / lm_head cols).
* **FSDP (data axes, train mode only)** — the remaining large dim of each
  weight is sharded over ("pod",)+("data",); serving replicates weights
  over data (no optimizer state; keeps all-gathers off the decode path).
* **Caches** — batch over data; KV heads over model when divisible, else
  the cache *sequence* over model (glm4's kv=2 < 16; also the long_500k
  context-parallel path). SSM/RWKV states shard heads over model.
* Any dim not divisible by its axis size falls back to replication
  (sanitiser), so odd vocabs (whisper 51865, minicpm3 73448) still lower.

``logical`` specs are right-aligned: stacked layer dims (leading L) are
padded with None automatically.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# One shard_map resolution for the whole repo: the memory-arena scan
# fan-out (kernels/ops.py) and the pod-level DistributedVenusMemory
# (core/distributed_memory.py) import THIS symbol, so the two sharded
# retrieval paths cannot drift across jax versions.
try:                                   # jax ≥0.5 re-exports at top level
    shard_map = jax.shard_map
except AttributeError:                 # jax ≤0.4.x
    from jax.experimental.shard_map import shard_map

# sentinel for "the FSDP axis" — resolved per mode/mesh
FSDP = "__fsdp__"
MODEL = "model"


def mesh_axis_size(mesh, axis: str = MODEL) -> int:
    """Shard count of ``axis`` on ``mesh`` (1 when mesh is None or the
    axis is absent) — the K every sharded-memory path branches on."""
    if mesh is None:
        return 1
    return dict(mesh.shape).get(axis, 1)


def memory_sharding(mesh, ndim: int, axis: str = MODEL) -> NamedSharding:
    """Placement of a ``(S, …)`` memory super-buffer: the leading slot
    axis is split into contiguous per-device slabs over ``axis``, every
    trailing dim replicated. The arena places its ``(S, capacity, ·)``
    buffers with this, and the shard_map scan entries consume the same
    spec — slot slabs never move between placement and scan."""
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))

# (path regex, right-aligned logical spec)
_PARAM_RULES: Sequence[Tuple[str, Tuple]] = (
    # embeddings / heads — vocab-parallel with REPLICATED d (§Perf iter E):
    # sharding d over data makes the (tied) LM head a partial-sum
    # contraction, all-reducing full f32 (B,S,V) logits (observed 38 GB
    # per step on minicpm3). Replicating d keeps the head matmul local
    # with logits sharded over model; the optimizer-state cost is only
    # V·d/|model| per device.
    (r"embed$", (MODEL, None)),
    (r"pos_embed$", (None, FSDP)),
    (r"lm_head$", (None, MODEL)),
    # MoE experts: (E, d, ff) — expert parallel over model
    (r"moe/w_(gate|up)$", (MODEL, FSDP, None)),
    (r"moe/w_down$", (MODEL, None, FSDP)),
    (r"moe/router$", (FSDP, None)),
    (r"moe/shared/w_(gate|up)$", (FSDP, MODEL)),
    (r"moe/shared/w_down$", (MODEL, FSDP)),
    # MLA
    (r"w_dq$", (FSDP, None)),
    (r"w_dkv$", (FSDP, None)),
    (r"w_kr$", (FSDP, None)),
    (r"w_uq$", (FSDP, MODEL)),
    (r"w_uk$", (FSDP, MODEL)),
    (r"w_uv$", (FSDP, MODEL)),
    # attention + generic MLP (also whisper cross-attn)
    (r"(wq|wk|wv)$", (FSDP, MODEL)),
    (r"wo$", (MODEL, FSDP)),
    (r"w_(gate|up)$", (FSDP, MODEL)),
    (r"w_down$", (MODEL, FSDP)),
    # Mamba2
    (r"in_(z|x)$", (FSDP, MODEL)),
    (r"in_dt$", (FSDP, MODEL)),
    (r"in_bc$", (FSDP, None)),
    (r"conv_x_w$", (None, MODEL)),
    (r"conv_x_b$", (MODEL,)),
    (r"out_proj$", (MODEL, FSDP)),
    # RWKV6
    (r"(wr|wg)$", (FSDP, MODEL)),
    (r"cm_wk$", (FSDP, MODEL)),
    (r"cm_wv$", (MODEL, FSDP)),
    (r"cm_wr$", (FSDP, None)),
    (r"decay_w1$", (FSDP, None)),
    (r"decay_w2$", (None, MODEL)),
    (r"maa_w1$", (FSDP, None)),
    (r"ln_scale$", (MODEL, None)),
    (r"bonus_u$", (MODEL, None)),
)

_CACHE_RULES: Sequence[Tuple[str, Tuple]] = (
    # decided dynamically for k/v/ckv/krope (head vs sequence sharding)
    (r"(^|/)pos$", ("__batch__",)),
    (r"mrope_delta$", ("__batch__",)),
    (r"enc_out$", ("__batch__", None, None)),
    (r"ssm$", ("__batch__", MODEL, None, None)),
    (r"conv_x$", ("__batch__", None, MODEL)),
    (r"conv_bc$", ("__batch__", None, None)),
    (r"wkv$", ("__batch__", MODEL, None, None)),
    (r"shift_(tm|cm)$", ("__batch__", None)),
)


def path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _sanitize(spec: Tuple, shape: Tuple[int, ...], mesh) -> P:
    """Right-align the logical spec to the shape's rank and drop axes that
    do not divide the dim size."""
    spec = tuple(spec)
    pad = len(shape) - len(spec)
    full = (None,) * pad + spec
    sizes = dict(mesh.shape)
    out = []
    for dim, ax in zip(shape, full):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([sizes[a] for a in axes]))
        out.append(ax if dim % n == 0 and dim >= n else None)
    return P(*out)


def _resolve(spec: Tuple, fsdp_axes: Optional[Tuple[str, ...]],
             batch_axes: Tuple[str, ...]) -> Tuple:
    def one(s):
        if s == FSDP:
            return tuple(fsdp_axes) if fsdp_axes else None
        if s == "__batch__":
            return tuple(batch_axes) if batch_axes else None
        if isinstance(s, tuple):       # combined axes, e.g. (FSDP, MODEL)
            flat = []
            for t in s:
                r = one(t)
                if r is None:
                    continue
                flat.extend(r if isinstance(r, tuple) else (r,))
            return tuple(flat) if flat else None
        return s
    return tuple(one(s) for s in spec)


# §Perf iteration F: ZeRO-3-style FSDP placement. The base rules put the
# FSDP axes on the weights' contraction dim, which GSPMD resolves as
# partial-sum ALL-REDUCES of full activations (observed ~8 GB/layer f32 on
# minicpm3 train). Co-sharding FSDP *with* the model axis on the already-
# TP-sharded dim turns that into small per-use weight all-gathers
# (37 MB/layer) — the classic ZeRO-3 trade. Enabled via mode="train_zero3".
_ZERO3_OVERRIDES: Sequence[Tuple[str, Tuple]] = (
    (r"moe/", None),                      # keep expert-parallel rules
    (r"(wq|wk|wv|wr|wg)$", (None, (FSDP, MODEL))),
    (r"w_(gate|up)$", (None, (FSDP, MODEL))),
    (r"w_u(q|k|v)$", (None, (FSDP, MODEL))),
    (r"in_(z|x)$", (None, (FSDP, MODEL))),
    (r"in_dt$", (None, (FSDP, MODEL))),
    (r"cm_wk$", (None, (FSDP, MODEL))),
    (r"decay_w2$", (None, (FSDP, MODEL))),
    (r"wo$", ((MODEL, FSDP), None)),
    (r"w_down$", ((MODEL, FSDP), None)),
    (r"out_proj$", ((MODEL, FSDP), None)),
    (r"cm_wv$", ((MODEL, FSDP), None)),
)


def param_specs(params_shape, mesh, *, mode: str) -> Any:
    """mode: "train" (FSDP×TP), "train_zero3" (iter F), or "serve"
    (TP only, replicated over data)."""
    from repro.launch.mesh import data_axes
    daxes = data_axes(mesh)
    fsdp = daxes if mode.startswith("train") else None
    zero3 = mode == "train_zero3"

    def one(path, leaf):
        ps = path_str(path)
        if zero3:
            for pat, spec in _ZERO3_OVERRIDES:
                if re.search(pat, ps):
                    if spec is None:
                        break            # fall through to base rules
                    return NamedSharding(
                        mesh, _sanitize(_resolve(spec, fsdp, daxes),
                                        leaf.shape, mesh))
        for pat, spec in _PARAM_RULES:
            if re.search(pat, ps):
                return NamedSharding(
                    mesh, _sanitize(_resolve(spec, fsdp, daxes),
                                    leaf.shape, mesh))
        return NamedSharding(mesh, P())          # norms, scalars: replicate

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cache_shape, mesh) -> Any:
    """Decode caches: batch over data; KV heads over model if divisible,
    else sequence over model (context parallelism)."""
    from repro.launch.mesh import data_axes
    daxes = data_axes(mesh)
    msize = dict(mesh.shape)[MODEL]

    def one(path, leaf):
        ps = path_str(path)
        for pat, spec in _CACHE_RULES:
            if re.search(pat, ps):
                return NamedSharding(
                    mesh, _sanitize(_resolve(spec, None, daxes),
                                    leaf.shape, mesh))
        if re.search(r"(^|/)(k|v)$", ps):
            # (L, B, C, Hkv, D)
            hkv = leaf.shape[-2]
            if hkv % msize == 0:
                spec = (None, daxes, None, MODEL, None)
            else:
                spec = (None, daxes, MODEL, None, None)  # seq sharding
            return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))
        if re.search(r"(ckv|krope)$", ps):
            # (L, B, C, R): latent cache — shard the sequence
            spec = (None, daxes, MODEL, None)
            return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(batch_shape, mesh) -> Any:
    """Input batches: leading batch dim over data axes."""
    from repro.launch.mesh import data_axes
    daxes = data_axes(mesh)

    def one(path, leaf):
        spec = (tuple(daxes),) + (None,) * (len(leaf.shape) - 1)
        if len(leaf.shape) == 0:
            spec = ()
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def opt_specs(opt_shape, pspecs) -> Any:
    """AdamW state: count replicated; mu/nu follow the param specs."""
    mesh = jax.tree.leaves(pspecs)[0].mesh
    return type(opt_shape)(
        NamedSharding(mesh, P()), pspecs, pspecs)
