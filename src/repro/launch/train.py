"""Training launcher: ``--arch <id>`` selects any assigned architecture.

On this host it runs a reduced (smoke) variant end-to-end; on a real pod
the same code path takes the full config + production mesh (the dry-run
proves those lower). Checkpoints via repro.training.checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
      --steps 20 --batch 4 --seq 128 [--full] [--ckpt out/ck]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.text import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import batch_specs, opt_specs, param_specs
from repro.models.transformer import Transformer
from repro.training import TrainHParams, adamw_init, make_train_step
from repro.training import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; default: smoke)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(
        args.arch)
    print(f"[train] arch={cfg.name} layers={cfg.num_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    hp = TrainHParams(base_lr=args.lr, warmup=max(args.steps // 10, 1),
                      total_steps=args.steps, remat=args.remat)

    mesh = make_host_mesh()
    pspec = param_specs(jax.eval_shape(lambda: params), mesh, mode="train")
    step_fn = jax.jit(make_train_step(cfg, hp),
                      in_shardings=(pspec, opt_specs(
                          jax.eval_shape(lambda: opt), pspec), None, None))

    it = lm_batches(cfg.vocab_size, args.batch, args.seq)
    with mesh:
        for i in range(args.steps):
            raw = next(it)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (args.batch, cfg.vision_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.family == "audio":
                batch["encoder_frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq_len, cfg.d_model),
                    jnp.bfloat16)
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(params, opt, batch,
                                           jnp.asarray(i))
            loss = float(metrics["loss"])
            print(f"step {i:4d} loss {loss:.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"({time.perf_counter() - t0:.2f}s)")
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params}, {"arch": args.arch})
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
