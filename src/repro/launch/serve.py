"""Serving launcher: batched requests through the continuous-batching
engine for any ``--arch``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-7b \
      --requests 8 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.transformer import Transformer
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-vl-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(
        args.arch)
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=args.slots,
                        max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(rid=i,
                    tokens=rng.integers(3, cfg.vocab_size,
                                        size=int(rng.integers(8, 64))),
                    max_new_tokens=args.max_new)
        if cfg.family == "vlm":
            r.vision_embeds = rng.normal(
                0, 0.02, (cfg.vision_tokens, cfg.d_model)).astype(
                    np.float32)
        if cfg.family == "audio":
            r.encoder_frames = rng.normal(
                0, 0.02, (cfg.encoder_seq_len, cfg.d_model)).astype(
                    np.float32)
        reqs.append(r)

    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in done)
    for r in done:
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        print(f"req {r.rid}: prompt {len(r.tokens):3d} tok, "
              f"generated {len(r.generated):3d}, ttft {ttft:.0f} ms")
    print(f"[serve] {len(done)} requests, {total_new} tokens in "
          f"{wall:.2f}s ({total_new / wall:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
