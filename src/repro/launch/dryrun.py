import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and extract roofline inputs from the compiled artifact.

MUST be the first jax touch in the process: the XLA_FLAGS line above runs
before any other import so 512 host devices exist when jax initialises.

Per combo:
  1. ``adapt_config`` (long-context policy) + abstract params/inputs.
  2. Build the step fn (train_step / prefill_step / serve_step).
  3. jit with explicit in_shardings from repro.launch.sharding,
     ``.lower()`` + ``.compile()`` under the mesh.
  4. Record memory_analysis, cost_analysis, and per-device collective
     bytes parsed from the partitioned HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, combo_is_skipped, get_config
from repro.configs.base import get_shape
from repro.launch import sharding as shd
from repro.launch.mesh import HARDWARE, make_production_mesh
from repro.launch.specs import adapt_config, input_specs, params_shape
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.optim import adamw_init
from repro.training.trainer import TrainHParams, make_train_step

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ring-algorithm traffic factor per output byte
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic from the partitioned HLO, by op."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|"
                        r"all-to-all|collective-permute)(?:-start)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        typestr = rhs[: opm.start()]
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(typestr):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes * _COLL_FACTOR[op]
    return out


def build_step(cfg, shape):
    if shape.kind == "train":
        step = make_train_step(cfg, TrainHParams(remat=True))

        def fn(params, opt_state, batch, stepno):
            return step(params, opt_state, batch, stepno)
        return fn
    if shape.kind == "prefill":
        return make_prefill_step(cfg, max_len=shape.seq_len)
    return make_serve_step(cfg)


def depth_variants(cfg):
    """Two reduced-depth configs (a, b) and the extrapolation scale s such
    that any depth-additive compiled metric extrapolates exactly:
    metric(full) = metric(a) + (metric(b) - metric(a)) · s.

    Depths start at 2 (not 1): GSPMD sharding propagation is unstable on
    1-layer modules (observed: a 1-layer qwen2-vl train step lowered with
    5× the collectives of the 2-layer one), while L≥2 layer bodies lower
    identically — verified by the positive, plausible deltas."""
    if cfg.family == "audio":
        assert cfg.num_layers == cfg.num_encoder_layers
        a = cfg.replace(num_layers=2, num_encoder_layers=2)
        b = cfg.replace(num_layers=3, num_encoder_layers=3)
        return a, b, cfg.num_layers - 2
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        pat = "M" * (p - 1) + "A"
        a = cfg.replace(num_layers=2 * p, layer_pattern=pat * 2)
        b = cfg.replace(num_layers=3 * p, layer_pattern=pat * 3)
        return a, b, cfg.num_layers // p - 2
    fd = cfg.moe.first_dense_layers if cfg.moe else 0
    a = cfg.replace(num_layers=fd + 2)
    b = cfg.replace(num_layers=fd + 3)
    return a, b, cfg.num_layers - fd - 2


TRAIN_SHARDING_MODE = "train"   # or "train_zero3" (§Perf iter F)


def _lower_one(cfg, shape, mesh, *, compile_only: bool):
    """Lower+compile one step function; returns (compiled, seconds)."""
    pshape = params_shape(cfg)
    specs = input_specs(cfg, shape)
    pspec = shd.param_specs(
        pshape, mesh,
        mode=TRAIN_SHARDING_MODE if shape.kind == "train" else "serve")
    with mesh:
        step = build_step(cfg, shape)
        if shape.kind == "train":
            oshape = jax.eval_shape(adamw_init, pshape)
            ospec = shd.opt_specs(oshape, pspec)
            bspec = shd.batch_specs(specs["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(pspec, ospec, bspec, None))
            lowered = jitted.lower(pshape, oshape, specs["batch"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            # fixed positional order: (params, tokens, vision, audio)
            vis = specs.get("vision_embeds")
            aud = specs.get("encoder_frames")
            in_sh = (pspec,
                     shd.batch_specs(specs["tokens"], mesh),
                     shd.batch_specs(vis, mesh) if vis is not None
                     else None,
                     shd.batch_specs(aud, mesh) if aud is not None
                     else None)
            jitted = jax.jit(step, in_shardings=in_sh)
            lowered = jitted.lower(pshape, specs["tokens"], vis, aud)
        else:
            cspec = shd.cache_specs(specs["cache"], mesh)
            tspec = shd.batch_specs(specs["tokens"], mesh)
            jitted = jax.jit(step, in_shardings=(pspec, tspec, cspec))
            lowered = jitted.lower(pshape, specs["tokens"], specs["cache"])
        t0 = time.perf_counter()
        compiled = lowered.compile()
        return compiled, time.perf_counter() - t0


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": coll}


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True) -> Dict[str, Any]:
    from repro.models import transformer as _tf
    shape = get_shape(shape_name)
    skip = combo_is_skipped(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": skip}
    cfg = adapt_config(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)

    # --- phase 1: the compile proof — FULL model, scanned layers ----------
    _tf.UNROLL_STRUCTURAL_SCANS = False
    compiled, t_compile = _lower_one(cfg, shape, mesh, compile_only=True)
    mem = compiled.memory_analysis()

    # --- phase 2: exact roofline metrics — two reduced-depth UNROLLED
    # lowers (XLA cost_analysis counts a scan body once, so metrics from
    # the scanned module undercount by the trip count; depth-additive
    # metrics extrapolate exactly from two shallow unrolled compiles).
    _tf.UNROLL_STRUCTURAL_SCANS = True
    cfg_a, cfg_b, scale = depth_variants(cfg)
    ca, ta = _lower_one(cfg_a, shape, mesh, compile_only=True)
    cb, tb = _lower_one(cfg_b, shape, mesh, compile_only=True)
    _tf.UNROLL_STRUCTURAL_SCANS = False
    ma, mb = _cost_of(ca), _cost_of(cb)

    def extrap(xa, xb):
        return xa + (xb - xa) * scale

    flops = extrap(ma["flops"], mb["flops"])
    nbytes = extrap(ma["bytes"], mb["bytes"])
    coll = {k: extrap(ma["coll"][k], mb["coll"][k]) for k in ma["coll"]}

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "n_chips": int(mesh.devices.size),
        "status": "ok",
        "compile_s": round(t_compile, 2),
        "variant_compile_s": [round(ta, 2), round(tb, 2)],
        "depth_extrapolation_scale": scale,
        "flops_per_device": flops,
        "bytes_accessed_per_device": nbytes,
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
        "sliding_window": cfg.sliding_window,
    }
    if verbose:
        print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in ARCH_IDS for s in sorted(INPUT_SHAPES)])
    failures = []
    for arch, shape in combos:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag}")
        try:
            res = lower_combo(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            res = {"arch": arch, "shape": shape, "status": "error",
                   "error": str(e)[:2000]}
            failures.append(tag)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        jax.clear_caches()          # keep sweep memory bounded
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
