"""ShapeDtypeStruct stand-ins for every (architecture × input shape).

``input_specs(cfg, shape)`` returns the exact abstract inputs the step
function lowers against — weak-type-correct, shardable, zero allocation.

Shape semantics (assignment):
* train_4k / prefill_32k — ``seq_len`` is the TOTAL sequence; for the VLM
  the stubbed vision embeddings take ``vision_tokens`` of it and tokens
  cover the rest; whisper adds the fixed 1500-frame encoder input.
* decode shapes — one new token against a ``seq_len`` KV cache.
* long_500k — sub-quadratic context required: native for SSM/hybrid;
  full-attention archs get the sliding-window variant (window 8192),
  EXCEPT MLA archs whose compact latent cache (576 B/token) holds the
  full 524k context sharded over the mesh — the stronger, paper-relevant
  configuration (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, get_shape
from repro.models.transformer import Transformer

SDS = jax.ShapeDtypeStruct
LONG_CONTEXT_WINDOW = 8192


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Per-shape config adaptation (long-context attention policy)."""
    if shape.name == "long_500k":
        if cfg.attn_type == "gqa" and cfg.sliding_window == 0 \
                and cfg.family not in ("ssm",):
            cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
        # MLA archs keep the full latent cache (no window): 576 B/token
        # × 524k fits sharded. SSM archs are natively O(1).
    if cfg.max_seq_len < shape.seq_len:
        cfg = cfg.replace(max_seq_len=shape.seq_len)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the step function of this shape's kind."""
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16

    if shape.kind == "train":
        s_text = s - (cfg.vision_tokens if cfg.family == "vlm" else 0)
        batch = {"tokens": SDS((b, s_text), i32),
                 "labels": SDS((b, s_text), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = SDS((b, cfg.vision_tokens,
                                          cfg.d_model), bf16)
        if cfg.family == "audio":
            batch["encoder_frames"] = SDS((b, cfg.encoder_seq_len,
                                           cfg.d_model), bf16)
        return {"batch": batch}

    if shape.kind == "prefill":
        s_text = s - (cfg.vision_tokens if cfg.family == "vlm" else 0)
        out = {"tokens": SDS((b, s_text), i32)}
        if cfg.family == "vlm":
            out["vision_embeds"] = SDS((b, cfg.vision_tokens,
                                        cfg.d_model), bf16)
        if cfg.family == "audio":
            out["encoder_frames"] = SDS((b, cfg.encoder_seq_len,
                                         cfg.d_model), bf16)
        return out

    assert shape.kind == "decode"
    model = Transformer(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s, jnp.bfloat16))
    return {"tokens": SDS((b, 1), i32), "cache": cache}


def params_shape(cfg: ModelConfig) -> Any:
    model = Transformer(cfg)
    return jax.eval_shape(model.init, jax.random.key(0))
