"""Paper Table II: Venus vs query-RELEVANT baselines (AKS, BOLT) under
Cloud-Only and Edge-Cloud deployments — accuracy + total response latency.

Latency terms follow DESIGN.md §3: edge compute measured on this host,
communication and cloud VLM inference from the paper's analytic model
(100 Mbps link, token-proportional VLM cost). The edge-device compute for
frame-wise baselines is measured per frame here and scaled; the paper's
Jetson numbers are ~20–40× slower, so our speedups are conservative."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from benchmarks.scenario import build_scenario, coverage, \
    per_frame_embeddings
from repro.core import retrieval as rt
from repro.core.costmodel import (CloudVLMModel, FrameFormat, LinkModel,
                                  cloud_only_latency, edge_cloud_latency,
                                  venus_query_latency)


def run() -> None:
    sc = build_scenario(n_scenes=24, seed=9)
    world, oracle, system = sc.world, sc.oracle, sc.system
    queries = world.make_queries(12, seed=13)
    n = 32

    # frame-wise index the baselines need (AKS/BOLT embed every frame)
    t0 = time.perf_counter()
    ids, embs = per_frame_embeddings(world, oracle, stride=1)
    embed_all_s = time.perf_counter() - t0
    valid = jnp.ones((len(ids),), bool)

    rows = {}
    for name in ("aks_cloud", "aks_edge", "bolt_cloud", "bolt_edge",
                 "vanilla", "venus", "venus_akr"):
        rows[name] = {"cov": [], "lat": []}

    for q in queries:
        qe = oracle.embed_query(q)
        sims = jnp.asarray(embs @ qe)

        pick_aks = np.asarray(rt.aks_retrieve(sims, valid, n))
        pick_bolt = np.asarray(rt.bolt_inverse_transform(sims, valid, n))
        cov_aks = coverage(world, q, ids[pick_aks])
        cov_bolt = coverage(world, q, ids[pick_bolt])

        # --- latency assembly ------------------------------------------
        select_s = 0.02  # measured selection cost (tiny vs embed)
        rows["aks_cloud"]["cov"].append(cov_aks)
        rows["aks_cloud"]["lat"].append(cloud_only_latency(
            video_frames=world.total_frames, selected_frames=n,
            select_algo_s=select_s).total)
        rows["bolt_cloud"]["cov"].append(cov_bolt)
        rows["bolt_cloud"]["lat"].append(cloud_only_latency(
            video_frames=world.total_frames, selected_frames=n,
            select_algo_s=select_s).total)
        # edge-cloud: frame-wise embedding runs on the edge
        rows["aks_edge"]["cov"].append(cov_aks)
        rows["aks_edge"]["lat"].append(edge_cloud_latency(
            edge_select_s=embed_all_s + select_s, selected_frames=n).total)
        rows["bolt_edge"]["cov"].append(cov_bolt)
        rows["bolt_edge"]["lat"].append(edge_cloud_latency(
            edge_select_s=embed_all_s + select_s, selected_frames=n).total)

        # vanilla: naive arch (per-frame index, greedy top-k on edge)
        t0 = time.perf_counter()
        pick_v = np.asarray(rt.topk_retrieve(sims, valid, n))
        van_sel = time.perf_counter() - t0
        rows["vanilla"]["cov"].append(coverage(world, q, ids[pick_v]))
        rows["vanilla"]["lat"].append(edge_cloud_latency(
            edge_select_s=embed_all_s + van_sel, selected_frames=n).total)

        # venus (fixed budget; AKR variant separately)
        res = system.query(q.text, budget=n, use_akr=False, query_emb=qe)
        rows["venus"]["cov"].append(coverage(world, q, res.frame_ids))
        rows["venus"]["lat"].append(venus_query_latency(
            measured_edge_s=res.timings,
            n_frames_uploaded=len(res.frame_ids)).total)

        res = system.query(q.text, query_emb=qe)       # AKR
        rows["venus_akr"]["cov"].append(coverage(world, q, res.frame_ids))
        rows["venus_akr"]["lat"].append(venus_query_latency(
            measured_edge_s=res.timings,
            n_frames_uploaded=len(res.frame_ids)).total)

    base = np.mean(rows["venus"]["lat"])
    for k, v in rows.items():
        lat = float(np.mean(v["lat"]))
        emit(f"table2/{k}", lat,
             {"coverage": f"{np.mean(v['cov']):.3f}",
              "latency_s": f"{lat:.2f}",
              "speedup_vs_venus": f"{lat / base:.1f}x"})


if __name__ == "__main__":
    run()
