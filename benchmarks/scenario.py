"""Shared benchmark scenario: synthetic world + ingested Venus system.

All accuracy-shaped benchmarks (Tables I/II, Figs 10/11/12) run on the
same procedural world with ground-truth events; "accuracy" is event/scene
coverage of the retrieved frame set (the measurable analogue of VQA
accuracy — a cloud VLM answers correctly iff the relevant scenes are in
the frames it receives; see DESIGN.md §1)."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.pipeline import VenusConfig, VenusSystem
from repro.data.video import OracleEmbedder, Query, VideoWorld, WorldConfig


@dataclass
class Scenario:
    world: VideoWorld
    oracle: OracleEmbedder
    system: VenusSystem
    ingest_seconds: float
    ingest_timings: Dict[str, float]


_CACHE = {}


def build_scenario(n_scenes: int = 10, seed: int = 3,
                   cfg: VenusConfig = VenusConfig(),
                   chunk: int = 64) -> Scenario:
    key = (n_scenes, seed, cfg)
    if key in _CACHE:
        return _CACHE[key]
    world = VideoWorld(WorldConfig(n_scenes=n_scenes, seed=seed))
    oracle = OracleEmbedder(world, dim=64)
    system = VenusSystem(cfg, oracle, embed_dim=64)
    t0 = time.perf_counter()
    agg: Dict[str, float] = {}
    for i in range(0, world.total_frames, chunk):
        t = system.ingest(world.frames[i:i + chunk])
        for k, v in t.items():
            agg[k] = agg.get(k, 0.0) + v
    system.flush()
    out = Scenario(world, oracle, system, time.perf_counter() - t0, agg)
    _CACHE[key] = out
    return out


def coverage(world: VideoWorld, q: Query, frame_ids) -> float:
    """Fraction of relevant scenes whose *event window* was hit — the
    VLM can only answer if the evidence frames are in the upload."""
    hit = {int(world.scene_of_frame[int(f)]) for f in frame_ids
           if world.frame_in_window(int(f))}
    rel = set(q.relevant_scenes)
    return len(rel & hit) / max(len(rel), 1)


def per_frame_embeddings(world: VideoWorld, oracle: OracleEmbedder,
                         stride: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Vanilla baseline index: every (strided) frame embedded."""
    ids = np.arange(0, world.total_frames, stride)
    embs = oracle.embed_frames(None, frame_ids=ids)
    return ids, embs
