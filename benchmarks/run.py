"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (bench_akr_scaling, bench_fig10, bench_fig11,
                        bench_fig12, bench_ingestion, bench_kernels,
                        bench_multistream, bench_table1, bench_table2,
                        roofline)

SUITES = {
    "fig4": bench_ingestion.run,       # embedding latency vs FPS
    "table1": bench_table1.run,        # query-irrelevant baselines
    "table2": bench_table2.run,        # query-relevant baselines + latency
    "fig10": bench_fig10.run,          # top-k vs sampling diversity
    "fig11": bench_fig11.run,          # AKR ablation
    "fig12": bench_fig12.run,          # latency breakdown
    "akr_scaling": bench_akr_scaling.run,  # beyond-paper: tau/theta sweep
    "kernels": bench_kernels.run,      # kernel microbench
    "roofline": roofline.run,          # dry-run roofline terms
    "multistream": bench_multistream.run,  # sessions×queries throughput
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for n in names:
        try:
            SUITES[n]()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(n)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
