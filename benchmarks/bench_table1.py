"""Paper Table I: Venus vs query-IRRELEVANT baselines at N = 16/32.

Baselines: uniform sampling, MDF (dominant-frame filtering), Video-RAG
proxy (uniform + aux-text index). Metric: mean scene coverage of the
selected frames over ground-truth queries (accuracy proxy)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from benchmarks.scenario import build_scenario, coverage, \
    per_frame_embeddings
from repro.core import retrieval as rt


def run() -> None:
    sc = build_scenario(n_scenes=24, seed=7)
    world, oracle, system = sc.world, sc.oracle, sc.system
    queries = world.make_queries(16, seed=11)
    ids, embs = per_frame_embeddings(world, oracle, stride=2)
    valid = jnp.ones((len(ids),), bool)

    for n in (16, 32):
        covs = {"uniform": [], "mdf": [], "video_rag": [], "venus": []}
        for qi, q in enumerate(queries):
            qe = oracle.embed_query(q)
            # uniform
            pick = rt.uniform_retrieve(world.total_frames, n)
            covs["uniform"].append(coverage(world, q, np.asarray(pick)))
            # MDF: query-agnostic dominant frames over the strided index
            pick = rt.mdf_retrieve(jnp.asarray(embs), valid, n)
            covs["mdf"].append(coverage(world, q, ids[np.asarray(pick)]))
            # Video-RAG proxy: uniform frames + query-matched aux text
            # (here: rerank the uniform set by similarity, keep top n)
            upick = np.asarray(rt.uniform_retrieve(len(ids), 2 * n))
            sims = embs[upick] @ qe
            keep = upick[np.argsort(-sims)[:n]]
            covs["video_rag"].append(coverage(world, q, ids[keep]))
            # Venus (fixed budget, sampling)
            res = system.query(q.text, budget=n, use_akr=False,
                               query_emb=qe)
            covs["venus"].append(coverage(world, q, res.frame_ids))
        for k, v in covs.items():
            emit(f"table1/{k}_n{n}", 0.0,
                 {"coverage": f"{np.mean(v):.3f}"})


if __name__ == "__main__":
    run()
