"""Multi-session Venus: batched multi-stream ingest + batched querying.

The edge box serves N concurrent camera streams with real-time queries
(the ROADMAP's multi-tenant scenario). This bench measures, on CPU:

* **ingest** — N sessions driven tick-by-tick through the
  ``SessionManager`` (ONE batched MEM call per tick across all streams)
  vs N independent single-stream ``VenusSystem`` instances ingested
  sequentially (per-partition embed calls — the seed path).
* **query** — Q queries per session through ``query_batch`` (one
  similarity scan + vmapped AKR) vs Q sequential ``query`` calls.
* **post-ingest query latency** — the device-resident incrementally
  updated index vs the seed behaviour (every insert invalidates the
  device cache, forcing a full ``(capacity, dim)`` host→device
  re-upload before the next scan).
* **cross-session fused query** — one ``query_batch_cross`` scan over
  ALL sessions' stacked indices vs one ``query_batch`` scan per session
  vs fully sequential ``query`` calls, with scans-per-tick and
  host↔device transfer counters from ``io_stats``.
* **arena vs restack** (``--arena``) — interleaved ingest-tick/query
  rounds where every session grows every tick: the grow-in-place
  ``MemoryArena`` (zero restacks, donated appends) vs the PR-2/3
  detached path (device stack rebuilt every round), with restacks/tick
  and append bandwidth from the counters.
* **session-lifecycle churn** (``--churn``) — rounds of create →
  ingest ⇄ query → close → recreate with a small ``memory_capacity``
  and sliding-window eviction: steady-state slot count (no monotonic
  arena growth under churn), slot reuses, evictions/tick, and
  restacks/tick (asserted 0).

* **sharded arena** (``--shards``) — identical tick/query workloads on
  a 1-shard vs K-shard (``model`` axis) arena mesh: scans/tick,
  per-shard fused launches, candidate-gather bytes vs the dense leak
  bound, and the double-buffered ingest/query overlap. The K>1 arms
  need ``XLA_FLAGS=--xla_force_host_platform_device_count``.

* **disk spill tier** (``--spill``) — ``eviction="none"`` sessions
  under a ``host_retain`` budget, ingesting ≥ 4× their budget:
  demotion throughput (host frames → npy segments) and fault-in
  throughput (cold sweep from disk vs LRU-cached re-reads), with the
  bounded-host invariant (``retained ≤ host_retain``), bit-identical
  round-trips, and full demotion/fault accounting asserted in-harness.
  The spill directory is a tmpdir, removed in a ``finally``.

* **hierarchical tier** (``--tiered``) — a session holding 4× its fine
  capacity of consolidated history answers the same top-k plan via the
  flat 1×-capacity scan (``coarse=False``) vs the two-stage
  coarse→fine retrieval: per-plan scanned bytes from the ``kops``
  counters (two-stage asserted below flat), effective capacity,
  restacks (asserted 0), plus the recall-vs-compression-ratio curve
  from ``bench_fig10.recall_vs_compression``.

``--json`` additionally writes every emitted row (plus run metadata) to
``BENCH_multistream.json`` so CI can upload a machine-readable perf
artifact per commit; the ``trajectory`` key accumulates a compact
summary of every past run (the artifact is re-read before rewriting).

Usage:  PYTHONPATH=src python -m benchmarks.run --only multistream
   (or  PYTHONPATH=src python benchmarks/bench_multistream.py)
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time
from typing import Dict

if __package__ in (None, ""):               # direct-script invocation
    sys.path.insert(0, ".")
    sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core.memory import VenusMemory
from repro.core.pipeline import VenusConfig, VenusSystem
from repro.core.session import SessionManager
from repro.data.video import (OracleEmbedder, PixelEmbedder, VideoWorld,
                              WorldConfig)


def _bench_ingest(n_sessions: int, chunk: int = 64):
    """Batched multi-stream ingest vs sequential single-stream ingest.

    Uses the REAL dual-tower MEM (the paper's ingestion hot spot): the
    win comes from one jit'd MEM call per tick over every stream's
    closed centroids instead of one call per partition per stream."""
    import jax
    from repro.configs.venus_mem import small_config
    from repro.models.mem import MEM

    worlds = [VideoWorld(WorldConfig(n_scenes=4, seed=20 + s))
              for s in range(n_sessions)]
    n_frames = min(w.total_frames for w in worlds)
    cfg = VenusConfig()
    mem_cfg = small_config()
    mem = MEM(mem_cfg)
    from repro.core.pipeline import MEMEmbedder
    embedder = MEMEmbedder(mem, mem.init(jax.random.key(0)))
    dim = mem_cfg.embed_dim

    def run_batched():
        mgr = SessionManager(cfg, embedder, embed_dim=dim)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        agg: Dict[str, float] = {}
        for i in range(0, n_frames, chunk):
            t = mgr.ingest_tick({sid: w.frames[i:i + chunk]
                                 for sid, w in zip(sids, worlds)})
            for k, v in t.items():
                agg[k] = agg.get(k, 0.0) + v
        mgr.flush()
        return agg

    def run_sequential():
        systems = [VenusSystem(cfg, embedder, embed_dim=dim)
                   for _ in range(n_sessions)]
        for sys_, w in zip(systems, worlds):
            for i in range(0, n_frames, chunk):
                sys_.ingest(w.frames[i:i + chunk])
            sys_.flush()

    run_batched()           # warm the jit caches (scene/cluster/embed)
    run_sequential()        # the seed path shares most of them
    t0 = time.perf_counter()
    agg = run_batched()
    batched_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_sequential()
    sequential_s = time.perf_counter() - t0

    total = n_frames * n_sessions
    emit("multistream/ingest_batched", batched_s,
         {"sessions": n_sessions, "frames": total,
          "fps": f"{total / batched_s:.0f}",
          "segment_s": f"{agg.get('segment', 0):.3f}",
          "cluster_s": f"{agg.get('cluster', 0):.3f}",
          "embed_insert_s": f"{agg.get('embed_insert', 0):.3f}"})
    emit("multistream/ingest_sequential", sequential_s,
         {"sessions": n_sessions, "fps": f"{total / sequential_s:.0f}",
          "speedup": f"{sequential_s / batched_s:.2f}x"})


def _bench_query(n_sessions: int, n_queries: int, chunk: int = 64):
    """Batched query path vs sequential, same keys → same results."""
    worlds = [VideoWorld(WorldConfig(n_scenes=6, seed=20 + s))
              for s in range(n_sessions)]
    n_frames = min(w.total_frames for w in worlds)
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sids = [mgr.create_session() for _ in range(n_sessions)]
    for i in range(0, n_frames, chunk):
        mgr.ingest_tick({sid: w.frames[i:i + chunk]
                         for sid, w in zip(sids, worlds)})
    mgr.flush()

    oracle_qs = {sid: OracleEmbedder(w, dim=64).embed_queries(
        w.make_queries(n_queries, seed=31))
        for sid, w in zip(sids, worlds)}

    # warm both query paths (vmapped AKR + scalar AKR compiles)
    mgr.query_batch(sids[0], query_embs=oracle_qs[sids[0]])
    mgr.query(sids[0], "", query_emb=oracle_qs[sids[0]][0])

    t0 = time.perf_counter()
    n_frames_batched = 0
    timings: Dict[str, float] = {}
    for sid in sids:
        results = mgr.query_batch(sid, query_embs=oracle_qs[sid])
        n_frames_batched += sum(len(r.frame_ids) for r in results)
        for k, v in results[0].timings.items():
            timings[k] = timings.get(k, 0.0) + v
    batched_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for sid in sids:
        for qe in oracle_qs[sid]:
            mgr.query(sid, "", query_emb=qe)
    sequential_s = time.perf_counter() - t0

    nq = len(sids) * n_queries
    emit("multistream/query_batched", batched_s,
         {"sessions": len(sids), "queries": nq,
          "qps": f"{nq / batched_s:.1f}",
          "frames_retrieved": n_frames_batched,
          **{f"{k}_s": f"{v:.4f}" for k, v in timings.items()}})
    emit("multistream/query_sequential", sequential_s,
         {"qps": f"{nq / sequential_s:.1f}",
          "speedup": f"{sequential_s / batched_s:.2f}x"})


def _bench_query_plan(n_sessions: int, n_queries: int, chunk: int = 64,
                      ticks: int = 5, n_scenes: int = 6):
    """Mixed-strategy service ticks through the declarative planner.

    Each tick answers ``n_queries`` queries per session with a strategy
    mix (AKR / top-k / BOLT). The planner must fuse the tick into one
    execution group per strategy — ``group_scans`` counts exactly
    ``len(strategies)`` scans per tick no matter how many sessions or
    queries the tick spans."""
    from repro.core.queryplan import QuerySpec

    mix = ("akr", "topk", "bolt")
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]
    n_frames = min(w.total_frames for w in worlds)
    mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                         embed_dim=64)
    sids = [mgr.create_session() for _ in range(n_sessions)]
    for i in range(0, n_frames, chunk):
        mgr.ingest_tick({sid: w.frames[i:i + chunk]
                         for sid, w in zip(sids, worlds)})
    mgr.flush()

    def tick_specs(t):
        specs = []
        for si, (sid, w) in enumerate(zip(sids, worlds)):
            qes = OracleEmbedder(w, dim=64).embed_queries(
                w.make_queries(n_queries, seed=131 + 7 * t))
            specs += [QuerySpec(sid=sid, embedding=qes[qi],
                                strategy=mix[(si + qi) % len(mix)],
                                budget=8)
                      for qi in range(n_queries)]
        return specs

    # specs (incl. embeddings) precomputed so the timed loop measures the
    # planner/executor only — comparable to the cross bench's qe_by_tick
    specs_by_tick = [tick_specs(t) for t in range(ticks)]
    plan = mgr.plan(specs_by_tick[0])
    assert plan.n_scans == len(mix), plan.describe()
    mgr.execute(plan)                                   # warm
    base = dict(mgr.io_stats)
    t0 = time.perf_counter()
    for specs in specs_by_tick:
        mgr.query_specs(specs)
    plan_s = time.perf_counter() - t0
    scans_per_tick = (mgr.io_stats["group_scans"]
                      - base["group_scans"]) / ticks
    assert scans_per_tick == len(mix), scans_per_tick
    emit("multistream/query_plan_mixed", plan_s,
         {"sessions": n_sessions, "queries_per_tick": len(sids) * n_queries,
          "strategies": len(mix), "ticks": ticks,
          "scans_per_tick": f"{scans_per_tick:.1f}"})


def _bench_query_cross(n_sessions: int, n_queries: int, chunk: int = 64,
                       ticks: int = 5, n_scenes: int = 6):
    """Cross-session fused query path vs per-session vs sequential.

    Each "tick" answers ``n_queries`` queries per session (the service
    scenario: queries spread over every stream arriving together). The
    fused path must issue ONE scan per tick regardless of S; the
    per-session path issues S; sequential issues S×Q. Transfer counters
    come straight from the memory/manager io_stats."""
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]
    n_frames = min(w.total_frames for w in worlds)

    def build():
        mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                             embed_dim=64)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        for i in range(0, n_frames, chunk):
            mgr.ingest_tick({sid: w.frames[i:i + chunk]
                             for sid, w in zip(sids, worlds)})
        mgr.flush()
        return mgr, sids

    # per tick: qsids repeats each session n_queries times, embeddings
    # packed (S * n_queries, d) in the same order
    qe_by_tick = [np.concatenate([OracleEmbedder(w, dim=64).embed_queries(
        w.make_queries(n_queries, seed=31 + 7 * t)) for w in worlds])
        for t in range(ticks)]

    def transfers(mgr, sids):
        return {
            "full_uploads": sum(mgr[s].memory.io_stats["full_uploads"]
                                for s in sids),
            "appended_rows": sum(mgr[s].memory.io_stats["appended_rows"]
                                 for s in sids),
            "host_expand_gathers": sum(
                mgr[s].memory.io_stats["host_expand_gathers"]
                for s in sids),
        }

    qsids = [sid for sid in range(n_sessions) for _ in range(n_queries)]

    # --- fused: one scan over the whole stack per tick
    mgr, sids = build()
    tick_sids = [sids[s] for s in qsids]
    mgr.query_batch_cross(tick_sids, query_embs=qe_by_tick[0])   # warm
    base_scans = dict(mgr.io_stats)
    t0 = time.perf_counter()
    for t in range(ticks):
        mgr.query_batch_cross(tick_sids, query_embs=qe_by_tick[t])
    fused_s = time.perf_counter() - t0
    scans_per_tick = (mgr.io_stats["fused_scans"]
                      - base_scans["fused_scans"]) / ticks
    emit("multistream/query_cross_fused", fused_s,
         {"sessions": n_sessions, "queries_per_tick": len(qsids),
          "ticks": ticks, "scans_per_tick": f"{scans_per_tick:.1f}",
          **transfers(mgr, sids)})

    # --- per-session batched: one scan per session per tick
    mgr, sids = build()
    mgr.query_batch(sids[0], query_embs=qe_by_tick[0][:n_queries])  # warm
    base_scans = dict(mgr.io_stats)
    t0 = time.perf_counter()
    for t in range(ticks):
        for si, sid in enumerate(sids):
            lo = si * n_queries
            mgr.query_batch(sid,
                            query_embs=qe_by_tick[t][lo:lo + n_queries])
    per_session_s = time.perf_counter() - t0
    emit("multistream/query_cross_per_session", per_session_s,
         {"scans_per_tick":
          f"{(mgr.io_stats['scans'] - base_scans['scans']) / ticks:.1f}",
          "speedup_vs_fused": f"{per_session_s / fused_s:.2f}x",
          **transfers(mgr, sids)})

    # --- sequential scalar queries
    mgr, sids = build()
    mgr.query(sids[0], "", query_emb=qe_by_tick[0][0])         # warm
    t0 = time.perf_counter()
    for t in range(ticks):
        for j, s in enumerate(qsids):
            mgr.query(sids[s], "", query_emb=qe_by_tick[t][j])
    sequential_s = time.perf_counter() - t0
    emit("multistream/query_cross_sequential", sequential_s,
         {"speedup_vs_fused": f"{sequential_s / fused_s:.2f}x",
          **transfers(mgr, sids)})


def _bench_arena(n_sessions: int, n_queries: int, chunk: int = 64,
                 ticks: int = 5, n_scenes: int = 6):
    """Grow-in-place arena vs the PR-2/3 restack path.

    The adversarial schedule for a version-cached stack: every tick
    grows EVERY session (``max_partition_len`` < chunk forces ≥ 1
    partition close per tick), then a query plan runs — the detached
    path must restack the grown sessions' device buffers before each
    scan, the arena path consumes its super-buffers as-is. Reports
    wall time split into ingest/query, restacks per tick, and append
    bandwidth (rows moved per second of ingest)."""
    cfg = VenusConfig(max_partition_len=min(48, chunk - 16))
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]

    def chunk_at(w, t):
        lo = (t * chunk) % max(w.total_frames - chunk, 1)
        return w.frames[lo:lo + chunk]

    qe_by_tick = [np.concatenate([OracleEmbedder(w, dim=64).embed_queries(
        w.make_queries(n_queries, seed=31 + 7 * t)) for w in worlds])
        for t in range(ticks)]
    qsids = [s for s in range(n_sessions) for _ in range(n_queries)]

    def run_mode(use_arena: bool):
        mgr = SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64,
                             use_arena=use_arena)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        tick_sids = [sids[s] for s in qsids]
        # warm-up: compile ingest + append + scan + expansion paths
        mgr.ingest_tick({sid: chunk_at(w, 0)
                         for sid, w in zip(sids, worlds)})
        mgr.query_batch_cross(tick_sids, query_embs=qe_by_tick[0])
        mgr.reset_io_stats()
        rows0 = sum(mgr[s].memory.size for s in sids)

        t_ingest = t_query = 0.0
        for t in range(1, ticks + 1):
            t0 = time.perf_counter()
            mgr.ingest_tick({sid: chunk_at(w, t)
                             for sid, w in zip(sids, worlds)})
            t_ingest += time.perf_counter() - t0
            t0 = time.perf_counter()
            mgr.query_batch_cross(tick_sids,
                                  query_embs=qe_by_tick[t % ticks])
            t_query += time.perf_counter() - t0
        # rows actually indexed over the timed window — identical units
        # for both modes (io_stats appended_rows counts raw rows on the
        # deferred arena path but bucket-padded rows on the detached
        # path, so it cannot be compared across modes)
        rows = sum(mgr[s].memory.size for s in sids) - rows0
        return mgr, sids, t_ingest, t_query, rows

    # a full untimed pass per mode first: the clustering stage's eager
    # ops compile per partition-length, and those caches are GLOBAL —
    # without this, whichever mode runs first pays every compile and
    # the comparison measures compiler order, not the memory paths
    for use_arena in (True, False):
        run_mode(use_arena)

    out = {}
    for name, use_arena in (("arena", True), ("restack", False)):
        mgr, sids, t_ingest, t_query, rows = run_mode(use_arena)
        restacks_per_tick = mgr.io_stats["stack_rebuilds"] / ticks
        out[name] = {"total": t_ingest + t_query, "query": t_query,
                     "restacks_per_tick": restacks_per_tick}
        emit(f"multistream/arena_{name}", t_ingest + t_query,
             {"sessions": n_sessions, "ticks": ticks,
              "queries_per_tick": len(qsids),
              "ingest_s": f"{t_ingest:.4f}",
              "query_s": f"{t_query:.4f}",
              "restacks_per_tick": restacks_per_tick,
              "indexed_rows": rows,
              "append_rows_per_s": f"{rows / max(t_ingest, 1e-9):.0f}"})

    # the tentpole invariant, asserted where CI runs it: the arena never
    # restacks, the detached path restacks every round it grew
    assert out["arena"]["restacks_per_tick"] == 0.0, out["arena"]
    assert out["restack"]["restacks_per_tick"] >= 1.0, out["restack"]
    emit("multistream/arena_speedup", 0.0,
         {"query_speedup":
          f"{out['restack']['query'] / out['arena']['query']:.2f}x",
          "total_speedup":
          f"{out['restack']['total'] / out['arena']['total']:.2f}x"},
         value=out["restack"]["query"] / out["arena"]["query"])


def _bench_churn(n_sessions: int, n_queries: int, chunk: int = 64,
                 rounds: int = 3, ticks: int = 4, n_scenes: int = 6):
    """24/7 churn workload: create → ingest ⇄ query → close → recreate.

    One stream churns every round (closed, then recreated — its arena
    slot must be RECYCLED from the free-list, not grown) while the rest
    run long enough to overflow ``memory_capacity`` and evict under the
    sliding-window policy. Reports wall time, steady-state slot count
    (must equal the live-stream count — no monotonic growth), slot
    reuses, evictions per tick, and restacks per tick (must be 0): the
    production invariants ``tests/test_lifecycle.py`` pins, measured on
    the full workload."""
    cfg = VenusConfig(max_partition_len=32, memory_capacity=24,
                      eviction="sliding_window")
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]
    mgr = SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64)
    stable = [mgr.create_session() for _ in range(n_sessions - 1)]
    churn_sid = mgr.create_session()
    steady = mgr.arena.n_sessions

    def chunk_at(w, t):
        lo = (t * chunk) % max(w.total_frames - chunk, 1)
        return w.frames[lo:lo + chunk]

    def stream_map():
        m = {sid: worlds[i] for i, sid in enumerate(stable)}
        m[churn_sid] = worlds[-1]
        return m

    # per-(round, tick) query embeddings, precomputed so the timed loop
    # measures the lifecycle paths, not the oracle embedder
    qe_by_step = [np.concatenate([
        OracleEmbedder(w, dim=64).embed_queries(
            w.make_queries(n_queries, seed=31 + 13 * step))
        for w in worlds])
        for step in range(rounds * ticks)]

    # warm-up: one tick + one query round compiles ingest/scan/expand
    mgr.ingest_tick({sid: chunk_at(w, 0)
                     for sid, w in stream_map().items()})
    qsids = [s for s in range(n_sessions) for _ in range(n_queries)]
    mgr.query_batch_cross([list(stream_map())[s] for s in qsids],
                          query_embs=qe_by_step[0])
    mgr.reset_io_stats()          # zeroes every memory's counters too

    t0 = time.perf_counter()
    total_ticks = 0
    for r in range(rounds):
        mgr.close_session(churn_sid)
        churn_sid = mgr.create_session()        # must recycle the slot
        for t in range(ticks):
            step = r * ticks + t
            smap = stream_map()
            mgr.ingest_tick({sid: chunk_at(w, 1 + step)
                             for sid, w in smap.items()})
            sids_now = list(smap)
            mgr.query_batch_cross([sids_now[s] for s in qsids],
                                  query_embs=qe_by_step[step])
            total_ticks += 1
    churn_s = time.perf_counter() - t0

    # closed_mem_stats keeps churned tenants' counters — summing live
    # sessions alone would drop every closed round's evictions
    evictions = mgr.closed_mem_stats.get("evicted_rows", 0) + sum(
        mgr[s].memory.io_stats["evicted_rows"] for s in mgr.sessions)
    restacks_per_tick = mgr.io_stats["stack_rebuilds"] / total_ticks
    evictions_per_tick = evictions / total_ticks
    # the lifecycle invariants, asserted where CI runs them: slots hold
    # at the steady-state maximum, churned slots are reused not grown,
    # and nothing ever restacks
    assert mgr.arena.n_sessions == steady, mgr.arena.n_sessions
    assert mgr.arena.io_stats["grows"] == 0, mgr.arena.io_stats
    assert mgr.arena.io_stats["slot_reuses"] == rounds, mgr.arena.io_stats
    assert restacks_per_tick == 0.0, restacks_per_tick
    assert evictions > 0, "churn workload never reached capacity"
    emit("multistream/churn", churn_s,
         {"sessions": n_sessions, "rounds": rounds,
          "ticks_per_round": ticks,
          "queries_per_tick": len(qsids),
          "steady_state_slots": steady,
          "slot_reuses": mgr.arena.io_stats["slot_reuses"],
          "grows_after_warmup": mgr.arena.io_stats["grows"],
          "evictions_per_tick": f"{evictions_per_tick:.1f}",
          "restacks_per_tick": restacks_per_tick,
          "sessions_closed": mgr.io_stats["sessions_closed"]})


def _bench_spill(n_sessions: int, chunk: int = 64, ticks: int = 8,
                 n_scenes: int = 4, host_retain: int = 64,
                 segment_frames: int = 16):
    """Disk spill tier: demote/fault throughput on bounded-host
    ``eviction="none"`` sessions.

    N keep-everything streams ingest ``ticks`` chunks each (≥ 4× the
    ``host_retain`` budget), so ``_trim_archives`` demotes their cold
    frames into npy segments every tick. Measures demotion throughput
    (inside the ingest ticks), cold fault-in throughput (full-history
    sweep with an empty LRU cache), and warm re-read throughput (the
    same sweep again, served by the cache). The production invariants
    are asserted in-harness: host ``retained ≤ host_retain`` on every
    stream, every demotion and fault accounted by the counters,
    bit-identical round-trips against the ingested chunks, zero
    restacks, and the spill tmpdir is removed in a ``finally``."""
    assert ticks * chunk >= 4 * host_retain, (ticks, chunk, host_retain)
    tmp = tempfile.mkdtemp(prefix="venus-spill-bench-")
    try:
        cfg = VenusConfig(max_partition_len=32, spill_dir=tmp,
                          host_retain=host_retain,
                          spill_segment_frames=segment_frames)
        worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=40 + s))
                  for s in range(n_sessions)]
        mgr = SessionManager(cfg, PixelEmbedder(dim=64), embed_dim=64)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        twins = {sid: [] for sid in sids}

        def chunk_at(w, t):
            lo = (t * chunk) % max(w.total_frames - chunk, 1)
            return np.asarray(w.frames[lo:lo + chunk], np.float32)

        # warm-up tick compiles segment/embed paths before timing
        mgr.ingest_tick({sid: chunk_at(w, 0)
                         for sid, w in zip(sids, worlds)})
        for sid, w in zip(sids, worlds):
            twins[sid].extend(chunk_at(w, 0))

        t0 = time.perf_counter()
        for t in range(1, ticks):
            mgr.ingest_tick({sid: chunk_at(w, t)
                             for sid, w in zip(sids, worlds)})
            for sid, w in zip(sids, worlds):
                twins[sid].extend(chunk_at(w, t))
        ingest_s = time.perf_counter() - t0

        spilled_frames = spilled_bytes = 0
        for sid in sids:
            fs = mgr[sid].frames
            # the bounded-host invariant, where CI runs it
            assert fs.retained <= host_retain, (fs.retained, host_retain)
            assert fs.io_stats["spilled_frames"] == fs.trimmed > 0
            spilled_frames += fs.io_stats["spilled_frames"]
            spilled_bytes += fs.io_stats["spilled_bytes"]

        # cold sweep: every historical id of every stream faults its
        # segment from disk (caches are empty — nothing was read yet)
        t0 = time.perf_counter()
        for sid in sids:
            fs = mgr[sid].frames
            got = fs.get(list(range(len(fs))))
            assert got.tobytes() == np.stack(twins[sid]).tobytes()
        cold_s = time.perf_counter() - t0
        faults = sum(mgr[sid].frames.io_stats["spill_faults"]
                     for sid in sids)
        assert faults > 0, "cold sweep never touched disk"

        # warm sweep: identical reads — the LRU cache absorbs re-reads
        # of the most recent segments (small cache ⇒ partial hits only)
        t0 = time.perf_counter()
        for sid in sids:
            mgr[sid].frames.get(list(range(len(mgr[sid].frames))))
        warm_s = time.perf_counter() - t0
        hits = sum(mgr[sid].frames.io_stats["spill_cache_hits"]
                   for sid in sids)
        # every spilled read was either a fault or a cache hit
        reads = 2 * spilled_frames
        total_faults = sum(mgr[sid].frames.io_stats["spill_faults"]
                           for sid in sids)
        assert total_faults + hits == reads, (total_faults, hits, reads)
        assert mgr.io_stats["stack_rebuilds"] == 0
        total_frames = sum(len(mgr[sid].frames) for sid in sids)
        emit("multistream/spill", ingest_s,
             {"sessions": n_sessions, "ticks": ticks,
              "host_retain": host_retain,
              "frames_total": total_frames,
              "spilled_frames": spilled_frames,
              "spilled_mb": f"{spilled_bytes / 2**20:.1f}",
              "demote_frames_per_s":
                  f"{spilled_frames / max(ingest_s, 1e-9):.0f}",
              "cold_fault_frames_per_s":
                  f"{total_frames / max(cold_s, 1e-9):.0f}",
              "warm_read_frames_per_s":
                  f"{total_frames / max(warm_s, 1e-9):.0f}",
              "spill_faults": total_faults,
              "spill_cache_hits": hits,
              "restacks": mgr.io_stats["stack_rebuilds"]})
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _bench_fused(n_sessions: int, n_queries: int, chunk: int = 64,
                 ticks: int = 5, n_scenes: int = 6,
                 index_dtype: str = "int8"):
    """One-launch fused retrieval + quantised index vs the dense path.

    Three arms over identical worlds and identical query plans (all
    strategies fused-eligible):

    * ``dense_fp32``  — ``execute(plan, fused=False)``: every group
      materialises the (S, Q, cap) score/probability tensors, then
      draws/top-ks in separate launches (the PR-3..5 path).
    * ``fused_fp32``  — the fused epilogue: draws + drawn probabilities
      + top-k leave the scan launch directly; nothing O(cap) per query
      crosses the launch boundary.
    * ``fused_<dt>``  — fused epilogue over the quantised arena
      (``VenusConfig(index_dtype=...)``): the scan streams 1-byte index
      rows (per-row scales cancel under the kernel's row
      normalisation), cutting scanned bytes 4× on top.

    Reports per-tick wall time, scanned index bytes per tick
    (``kops_scan_bytes`` deltas), fused vs dense launch counts, and the
    peak live index bytes (arena super-buffer + scales). The reduction
    row asserts the headline ≥ 2× scanned-bytes cut."""
    from repro.core.queryplan import QuerySpec
    from repro.kernels import ops as kops

    mix = ("akr", "topk", "sampling")
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]
    n_frames = min(w.total_frames for w in worlds)

    # per-(tick, session, query) embeddings precomputed; sids are fresh
    # 0..S-1 for every build() so specs transfer across managers
    qe_by_tick = [[OracleEmbedder(w, dim=64).embed_queries(
        w.make_queries(n_queries, seed=31 + 7 * t)) for w in worlds]
        for t in range(ticks)]

    def tick_specs(t):
        return [QuerySpec(sid=s, embedding=qe_by_tick[t][s][qi],
                          strategy=mix[(s + qi) % len(mix)], budget=8)
                for s in range(n_sessions) for qi in range(n_queries)]

    def build(dtype):
        mgr = SessionManager(VenusConfig(index_dtype=dtype),
                             PixelEmbedder(dim=64), embed_dim=64)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        assert sids == list(range(n_sessions))
        for i in range(0, n_frames, chunk):
            mgr.ingest_tick({sid: w.frames[i:i + chunk]
                             for sid, w in zip(sids, worlds)})
        mgr.flush()
        return mgr

    def peak_index_bytes(mgr):
        a = mgr.arena
        b = a.emb.size * a.emb.dtype.itemsize
        if a.emb_scale is not None:
            b += a.emb_scale.size * a.emb_scale.dtype.itemsize
        return int(b)

    out = {}
    arms = (("dense_fp32", "float32", False),
            ("fused_fp32", "float32", True),
            (f"fused_{index_dtype}", index_dtype, True))
    for name, dtype, fused in arms:
        mgr = build(dtype)
        plans = [mgr.plan(tick_specs(t)) for t in range(ticks)]
        mgr.execute(plans[0], fused=fused)                  # warm
        kops.reset_scan_counts()
        t0 = time.perf_counter()
        for plan in plans:
            mgr.execute(plan, fused=fused)
        dt = time.perf_counter() - t0
        c = kops.scan_counts()
        out[name] = c["scan_bytes"] / ticks
        emit(f"multistream/fused_retrieval_{name}", dt,
             {"sessions": n_sessions, "ticks": ticks,
              "queries_per_tick": n_sessions * n_queries,
              "index_dtype": dtype,
              "scan_bytes_per_tick": int(out[name]),
              "fused_launches": c["fused_draw_launches"],
              "dense_launches": c["dense_score_launches"],
              "peak_index_bytes": peak_index_bytes(mgr)})

    # the headline: dense fp32 scan traffic vs fused + quantised
    reduction = out["dense_fp32"] / max(out[f"fused_{index_dtype}"], 1)
    assert reduction >= 2.0, out
    emit("multistream/fused_scan_bytes_reduction", 0.0,
         {"scan_bytes_reduction": f"{reduction:.2f}x",
          "fused_fp32_vs_dense":
          f"{out['dense_fp32'] / max(out['fused_fp32'], 1):.2f}x"},
         value=reduction)


def _bench_shards(n_sessions: int, n_queries: int, chunk: int = 64,
                  ticks: int = 4, n_scenes: int = 6):
    """Sharded-arena fan-out: a 1-shard vs a K-shard (K ≤ 4) mesh.

    Same worlds, same ticks, same queries through managers whose arena
    super-buffers live on a ``model=1`` vs ``model=K`` mesh
    (``make_memory_mesh``). Reports wall time per tick, group scans per
    tick, per-shard fused launches, and the bytes the candidate
    all_gather moves across shard boundaries
    (``kops_shard_gather_bytes``) against the dense O(S·Q·capacity)
    leak bound — the gather is O(S·Q·(T+K)) outputs only, so the
    counter must come in far below one (S, Q, cap) f32 tensor. Both
    arms assert ``stack_rebuilds == 0``. The K-shard arm additionally
    runs with double buffering off to price the ingest/query overlap
    (the donated append scatter lands on the trailing buffer set while
    the front set serves the fused scan).

    With one visible device (no
    ``XLA_FLAGS=--xla_force_host_platform_device_count``) only the
    1-shard arm runs; the row still lands so CI diffs stay aligned."""
    import jax
    from repro.kernels import ops as kops
    from repro.launch.mesh import make_memory_mesh

    k = min(4, len(jax.devices()))
    worlds = [VideoWorld(WorldConfig(n_scenes=n_scenes, seed=20 + s))
              for s in range(n_sessions)]
    qsids = [s for s in range(n_sessions) for _ in range(n_queries)]
    qe_by_tick = [np.concatenate([
        OracleEmbedder(w, dim=64).embed_queries(
            w.make_queries(n_queries, seed=31 + 13 * t))
        for w in worlds]) for t in range(ticks)]

    def chunk_at(w, t):
        lo = (t * chunk) % max(w.total_frames - chunk, 1)
        return w.frames[lo:lo + chunk]

    def drive(shards, double_buffer):
        mgr = SessionManager(VenusConfig(), PixelEmbedder(dim=64),
                             embed_dim=64,
                             mesh=make_memory_mesh(shards=shards),
                             double_buffer=double_buffer)
        sids = [mgr.create_session() for _ in range(n_sessions)]
        # warm: compile ingest + the (sharded) fused scan once
        mgr.ingest_tick({sid: chunk_at(w, 0)
                         for sid, w in zip(sids, worlds)})
        mgr.query_batch_cross([sids[s] for s in qsids],
                              query_embs=qe_by_tick[0])
        mgr.reset_io_stats()
        kops.reset_scan_counts()
        t0 = time.perf_counter()
        for t in range(ticks):
            mgr.ingest_tick({sid: chunk_at(w, 1 + t)
                             for sid, w in zip(sids, worlds)})
            mgr.query_batch_cross([sids[s] for s in qsids],
                                  query_embs=qe_by_tick[t])
        return mgr, time.perf_counter() - t0, kops.scan_counts()

    overlap = {}
    for shards in (1,) + ((k,) if k > 1 else ()):
        mgr, dt, c = drive(shards, double_buffer=True)
        a = mgr.arena
        assert a.n_shards == shards, a.n_shards
        assert mgr.io_stats["stack_rebuilds"] == 0, mgr.io_stats
        if shards > 1:
            # the sharded lane actually ran, and its cross-shard
            # traffic stayed candidate-sized (no dense-score leak)
            assert c["sharded_stack_launches"] > 0, c
            dense = a.n_sessions * len(qsids) * a.capacity * 4
            assert 0 < c["shard_gather_bytes"] < dense * ticks, c
            _, dt_nodb, _ = drive(shards, double_buffer=False)
            overlap["ingest_query_overlap"] = \
                f"{dt_nodb / max(dt, 1e-9):.2f}x"
        emit(f"multistream/sharded_{shards}shard", dt,
             {"sessions": n_sessions, "ticks": ticks,
              "queries_per_tick": len(qsids),
              "arena_shards": a.n_shards,
              "scans_per_tick": mgr.io_stats["group_scans"] / ticks,
              "sharded_group_scans": mgr.io_stats["sharded_group_scans"],
              "sharded_stack_launches": c["sharded_stack_launches"],
              "shard_gather_bytes_per_tick":
                  c["shard_gather_bytes"] // ticks,
              "stack_rebuilds": mgr.io_stats["stack_rebuilds"],
              "double_flushes": a.io_stats["double_flushes"],
              **overlap})


def _bench_tiered(n_queries: int = 2, smoke: bool = False):
    """Hierarchical consolidation tier: flat scan vs two-stage retrieval.

    One session ingests 4× its fine capacity of clustered rows under
    ``eviction="consolidate"`` (evictees fold into the coarse summary
    tier), then answers the SAME top-k plan two ways:

    * ``flat`` — ``execute(plan, coarse=False)``: the escape hatch, one
      1×-capacity fused scan (the tier is ignored);
    * ``two_stage`` — coarse scan over the summary tier → top-B winner
      blocks → gathered fine candidates → second fused scan.

    Reports wall time, per-plan scanned index bytes from the ``kops``
    counters (coarse + gathered fine vs the flat scan — the bandwidth
    claim, asserted), the effective capacity ratio (reachable history ÷
    rows streamed per query), and ``stack_rebuilds`` (asserted 0 — the
    tier rides the arena, nothing restacks). The recall-vs-compression
    curve from ``bench_fig10.recall_vs_compression`` runs last so its
    rows land in the same JSON artifact."""
    from benchmarks.bench_fig10 import recall_vs_compression
    from repro.core.queryplan import QuerySpec
    from repro.kernels import ops as kops

    dim, capacity, n_clusters = 32, 512, 8
    cfg = VenusConfig(memory_capacity=capacity, member_cap=8,
                      eviction="consolidate", coarse_capacity=64,
                      coarse_block=32, coarse_topb=4)

    class _DirectEmbedder:
        def embed_queries(self, texts):
            raise AssertionError("bench passes explicit embeddings")

        def embed_frames(self, frames, aux=None, frame_ids=None):
            raise AssertionError("bench inserts rows directly")

    def _unit(rows):
        rows = np.asarray(rows, np.float32)
        return rows / (np.linalg.norm(rows, axis=-1, keepdims=True)
                       + 1e-12)

    rng = np.random.default_rng(7)
    cen = _unit(rng.normal(size=(n_clusters, dim)))
    total = 4 * capacity
    labels = rng.integers(0, n_clusters, size=total)
    rows = _unit(cen[labels] + 0.05 * rng.normal(size=(total, dim)))

    mgr = SessionManager(cfg, _DirectEmbedder(), embed_dim=dim)
    sid = mgr.create_session()
    mem = mgr.sessions[sid].memory
    t0 = time.perf_counter()
    for lo in range(0, total, 64):
        batch = rows[lo:lo + 64]
        fids = np.arange(lo, lo + len(batch))
        with mgr.arena.deferred_appends():
            mem.insert_batch(batch, scene_ids=[0] * len(batch),
                             index_frames=fids,
                             member_lists=[[int(f)] for f in fids])
    ingest_s = time.perf_counter() - t0
    a = mgr.arena
    assert a.has_consolidated()

    specs = [QuerySpec(sid=sid, embedding=cen[qi % n_clusters],
                       strategy="topk", budget=8)
             for qi in range(n_queries)]
    plan = mgr.plan(specs)
    mgr.execute(plan, coarse=False)                # warm both paths
    mgr.execute(plan)
    reps = 2 if smoke else 10
    out = {}
    for name, coarse in (("flat", False), ("two_stage", True)):
        kops.reset_scan_counts()
        t0 = time.perf_counter()
        for _ in range(reps):
            mgr.execute(plan, coarse=coarse)
        dt = time.perf_counter() - t0
        c = kops.scan_counts()
        out[name] = c["scan_bytes"] / reps          # bytes per plan
        derived = {"queries": n_queries, "reps": reps,
                   "fine_capacity": capacity, "ingested_rows": total,
                   "scan_bytes_per_plan": int(out[name]),
                   "stack_rebuilds": mgr.io_stats["stack_rebuilds"],
                   "ingest_s": f"{ingest_s:.3f}"}
        if coarse:
            per_query_rows = (a.n_coarse
                              + c["fine_gather_rows"] // (reps
                                                          * n_queries))
            derived.update(
                {"coarse_scan_bytes_per_plan":
                     c["coarse_scan_bytes"] // reps,
                 "fine_gather_rows_per_query":
                     c["fine_gather_rows"] // (reps * n_queries),
                 "two_stage_scans": c["two_stage_scans"],
                 "scanned_rows_per_query": per_query_rows,
                 "effective_capacity":
                     f"{total / per_query_rows:.1f}x"})
        emit(f"multistream/tiered_{name}", dt, derived)

    # the tentpole invariants, asserted where CI runs them: the tier
    # never restacks and the two-stage scan undercuts the flat one
    assert mgr.io_stats["stack_rebuilds"] == 0, mgr.io_stats
    assert out["two_stage"] < out["flat"], out
    reduction = out["flat"] / max(out["two_stage"], 1)
    # the recorded headline must be a real reduction, smoke included —
    # a 0.0 here means the smoke run never actually consolidated
    assert reduction > 1.0, out
    emit("multistream/tiered_scan_bytes_reduction", 0.0,
         {"scan_bytes_reduction": f"{reduction:.2f}x",
          "history_vs_flat_reach":
          f"{total / capacity:.0f}x"},
         value=reduction)

    # recall-vs-compression-ratio curve (fig10 accuracy harness) — the
    # rows land in this bench's JSON sink / trajectory
    recall_vs_compression(ratios=(1, 4) if smoke else (1, 2, 4, 8),
                          prefix="multistream/tiered_recall")


def _bench_incremental_index(capacity: int = 16384, dim: int = 256,
                             rounds: int = 20):
    """Post-ingest query latency: incremental append vs full re-upload."""
    rng = np.random.default_rng(0)
    base = rng.normal(0, 1, (capacity // 4, dim)).astype(np.float32)
    q = rng.normal(0, 1, (1, dim)).astype(np.float32)

    out = {}
    for name, incremental in (("incremental", True), ("seed_reupload",
                                                      False)):
        mem = VenusMemory(capacity, dim, member_cap=8,
                          incremental=incremental)
        mem.insert_batch(base, scene_ids=[0] * len(base),
                         index_frames=list(range(len(base))),
                         member_lists=[[i] for i in range(len(base))])
        mem.search(jnp.asarray(q), tau=0.1)      # warm: index on device

        def step(r):
            rows = rng.normal(0, 1, (8, dim)).astype(np.float32)
            lo = mem.size
            mem.insert_batch(rows, scene_ids=[1] * 8,
                             index_frames=list(range(lo, lo + 8)),
                             member_lists=[[i] for i in range(lo, lo + 8)])
            _, p = mem.search(jnp.asarray(q), tau=0.1)
            np.asarray(p)                         # block
        step(-1)                                  # warm the append jit
        t0 = time.perf_counter()
        for r in range(rounds):
            step(r)
        out[name] = (time.perf_counter() - t0) / rounds
        emit(f"multistream/post_ingest_query_{name}", out[name],
             {"full_uploads": mem.io_stats["full_uploads"],
              "appended_rows": mem.io_stats["appended_rows"]})
    emit("multistream/post_ingest_query_speedup", 0.0,
         {"speedup": f"{out['seed_reupload'] / out['incremental']:.2f}x"},
         value=out["seed_reupload"] / out["incremental"])


def _bench_standing(n_sessions: int = 4, smoke: bool = False):
    """Standing queries on the ingest path (``repro.core.standing``).

    ``n_sessions`` direct-insert streams each carry standing top-k
    specs keyed to known cluster centroids; every tick commits a batch
    of rows per stream and runs the ONE extra fused launch over the
    ``(S, max_new, d)`` new-row slab. Reports the evaluate() wall time
    per tick, alerts+suppressions per tick, and the headline bytes
    claim — ``standing_scan_bytes`` per tick vs the full-capacity
    re-scan the slab replaces — asserted in-harness: the slab stays
    within the 2× pow2-padding envelope of ``new_rows · d`` and far
    under the capacity bound, with ``stack_rebuilds == 0``."""
    from repro.core.queryplan import QuerySpec
    from repro.kernels import ops as kops

    dim, capacity, rows_per_tick = 32, 4096, 16
    ticks = 4 if smoke else 20
    cfg = VenusConfig(memory_capacity=capacity, member_cap=8)

    class _DirectEmbedder:
        def embed_queries(self, texts):
            raise AssertionError("bench passes explicit embeddings")

        def embed_frames(self, frames, aux=None, frame_ids=None):
            raise AssertionError("bench inserts rows directly")

    def _unit(rows):
        rows = np.asarray(rows, np.float32)
        return rows / (np.linalg.norm(rows, axis=-1, keepdims=True)
                       + 1e-12)

    rng = np.random.default_rng(11)
    cen = _unit(rng.normal(size=(n_sessions, dim)))
    mgr = SessionManager(cfg, _DirectEmbedder(), embed_dim=dim)
    sids = [mgr.create_session() for _ in range(n_sessions)]
    for s, sid in enumerate(sids):
        mgr.register_standing(
            sid, QuerySpec(sid=sid, embedding=cen[s], strategy="topk",
                           budget=4),
            threshold=0.8, hysteresis=0.1)

    def _tick(t):
        phys = {}
        for s, sid in enumerate(sids):
            # ~half the ticks carry a near-centroid row -> live alert
            # traffic through the trigger machine, not a dead registry
            hit = (t + s) % 2 == 0
            rows = _unit(rng.normal(size=(rows_per_tick, dim)))
            if hit:
                rows[0] = _unit(cen[s]
                                + 0.05 * rng.normal(size=dim))
            mem = mgr.sessions[sid].memory
            fids = np.arange(t * rows_per_tick,
                             (t + 1) * rows_per_tick)
            with mgr.arena.deferred_appends():
                p = mem.insert_batch(
                    rows, scene_ids=[0] * len(rows),
                    index_frames=fids,
                    member_lists=[[int(f)] for f in fids])
            phys[sid] = [p]
        return phys

    first = _tick(0)                       # warm the slab-shape jits
    mgr.standing.evaluate(mgr.sessions, first, mgr.io_stats)
    mgr.poll_alerts()
    kops.reset_scan_counts()
    fired = supp0 = 0
    supp0 = mgr.io_stats["alerts_suppressed"]
    t0 = time.perf_counter()
    eval_s = 0.0
    for t in range(1, ticks + 1):
        phys = _tick(t)
        te = time.perf_counter()
        fired += len(mgr.standing.evaluate(mgr.sessions, phys,
                                           mgr.io_stats))
        eval_s += time.perf_counter() - te
    total_s = time.perf_counter() - t0
    bytes_per_tick = kops.scan_counts()["standing_scan_bytes"] / ticks
    full_scan_bound = n_sessions * capacity * dim * 4
    # the O(new_rows · d) claim, asserted where CI runs it
    assert bytes_per_tick <= 2 * n_sessions * rows_per_tick * dim * 4, \
        bytes_per_tick
    assert bytes_per_tick < full_scan_bound / 16, bytes_per_tick
    assert mgr.io_stats["stack_rebuilds"] == 0, mgr.io_stats
    assert fired > 0, "bench must exercise live alert traffic"
    emit("multistream/standing_tick", eval_s / ticks,
         {"sessions": n_sessions, "specs": mgr.standing.n_specs,
          "ticks": ticks, "rows_per_tick": rows_per_tick,
          "alerts_per_tick": f"{fired / ticks:.2f}",
          "suppressed":
              mgr.io_stats["alerts_suppressed"] - supp0,
          "scan_bytes_per_tick": int(bytes_per_tick),
          "ingest_plus_eval_s": f"{total_s:.4f}",
          "stack_rebuilds": mgr.io_stats["stack_rebuilds"]})
    emit("multistream/standing_scan_bytes_reduction", 0.0,
         {"vs_full_rescan":
          f"{full_scan_bound / max(bytes_per_tick, 1):.0f}x",
          "full_rescan_bytes_per_tick": full_scan_bound},
         value=full_scan_bound / max(bytes_per_tick, 1))


ALL_PARTS = ("ingest", "query", "cross", "plan", "arena", "churn",
             "fused", "shards", "tiered", "spill", "standing",
             "incremental")
JSON_PATH = "BENCH_multistream.json"


def write_json_artifact(json_path: str, rows: list, meta: dict) -> dict:
    """Merge one run's rows into the cross-run JSON artifact.

    The ``trajectory`` key accumulates ACROSS runs: the previous
    artifact at ``json_path`` is re-read and this run's compact summary
    appended — a bare mode-"w" ``json.dump`` would wipe the history
    every run and leave the trajectory perpetually length-1. A missing
    or corrupt previous artifact starts a fresh trajectory. NOTE for
    CI: the artifact is gitignored, so accumulation only works if the
    workflow RESTORES the previous run's file into the workspace before
    the bench runs (ci.yml does this with ``actions/cache``) — uploads
    alone never land back in the next run's tree. Returns the payload
    it wrote (pinned by ``tests/test_bench_artifact.py``)."""
    try:
        with open(json_path) as f:
            trajectory = json.load(f).get("trajectory", [])
    except (OSError, ValueError):
        trajectory = []
    trajectory.append(
        {"timestamp": meta["timestamp"], "parts": meta["parts"],
         "smoke": meta["smoke"],
         # metric rows (seconds=0.0, headline scalar in "value") track
         # their VALUE across runs; timing rows track seconds
         "rows": {r["name"]: round(r.get("value", r["seconds"]), 6)
                  for r in rows}})
    payload = {"meta": meta, "benchmarks": rows,
               "trajectory": trajectory}
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench_multistream] wrote {json_path} "
          f"({len(rows)} rows, {len(trajectory)} runs in trajectory)")
    return payload


def run(n_sessions: int = 4, n_queries: int = 8, *,
        cross_only: bool = False, smoke: bool = False,
        parts=None, json_path: str | None = None,
        index_dtype: str = "int8") -> None:
    assert n_sessions >= 4, "multi-tenant scenario needs ≥4 sessions"
    if parts is None:
        parts = ("cross", "plan", "arena") if cross_only else ALL_PARTS
    rows: list = []
    common.set_sink(rows)
    # smoke: tiny worlds / few ticks — CI exercises the fused cross
    # path, the planner path, and the arena-vs-restack comparison
    # end-to-end in ~a minute
    ticks = 2 if smoke else 5
    n_scenes = 3 if smoke else 6
    if smoke:
        n_queries = min(n_queries, 2)
    try:
        if "ingest" in parts:
            _bench_ingest(n_sessions)
        if "query" in parts:
            _bench_query(n_sessions, n_queries)
        if "cross" in parts:
            _bench_query_cross(n_sessions, n_queries, ticks=ticks,
                               n_scenes=n_scenes)
        if "plan" in parts:
            _bench_query_plan(n_sessions, n_queries, ticks=ticks,
                              n_scenes=n_scenes)
        if "arena" in parts:
            _bench_arena(n_sessions, n_queries, ticks=ticks,
                         n_scenes=n_scenes)
        if "churn" in parts:
            _bench_churn(n_sessions, n_queries, ticks=ticks,
                         n_scenes=n_scenes)
        if "fused" in parts:
            _bench_fused(n_sessions, n_queries, ticks=ticks,
                         n_scenes=n_scenes, index_dtype=index_dtype)
        if "shards" in parts:
            _bench_shards(n_sessions, n_queries, ticks=ticks,
                          n_scenes=n_scenes)
        if "tiered" in parts:
            _bench_tiered(smoke=smoke)
        if "spill" in parts:
            _bench_spill(n_sessions, ticks=5 if smoke else 8,
                         n_scenes=n_scenes,
                         host_retain=32 if smoke else 64)
        if "standing" in parts:
            _bench_standing(n_sessions, smoke=smoke)
        if "incremental" in parts:
            _bench_incremental_index()
    finally:
        # the JSON artifact is written in the finally so a crashed part
        # still leaves every completed row on disk for CI to compare
        common.set_sink(None)
        if json_path:
            write_json_artifact(
                json_path, rows,
                {"bench": "multistream", "sessions": n_sessions,
                 "queries": n_queries, "smoke": smoke,
                 "parts": list(parts), "index_dtype": index_dtype,
                 "timestamp": time.time()})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--cross", action="store_true",
                    help="the cross-session fused query benches "
                         "(query_batch_cross shim + mixed-strategy plan)")
    ap.add_argument("--arena", action="store_true",
                    help="the grow-in-place arena vs restack bench")
    ap.add_argument("--churn", action="store_true",
                    help="the session-lifecycle churn bench "
                         "(create/ingest/query/close; slot recycling + "
                         "sliding-window eviction)")
    ap.add_argument("--fused", action="store_true",
                    help="the one-launch fused retrieval bench "
                         "(fused epilogue + quantised index vs the "
                         "dense score path)")
    ap.add_argument("--shards", action="store_true",
                    help="the sharded-arena fan-out bench (1 vs K "
                         "host devices: scans/tick, candidate-gather "
                         "bytes, ingest/query overlap; K>1 arms need "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count)")
    ap.add_argument("--tiered", action="store_true",
                    help="the hierarchical consolidation-tier bench "
                         "(flat vs two-stage scanned bytes, effective "
                         "capacity, restacks==0) + the recall-vs-"
                         "compression-ratio curve from bench_fig10")
    ap.add_argument("--spill", action="store_true",
                    help="the disk spill-tier bench (host_retain-"
                         "bounded eviction='none' streams: demotion + "
                         "cold-fault + warm-read throughput; bounded "
                         "host / bit-identity / counter accounting "
                         "asserted in-harness; tmpdir-scoped)")
    ap.add_argument("--standing", action="store_true",
                    help="the standing-query bench (per-tick trigger "
                         "evaluation over the new-row slab: alerts/"
                         "tick, standing_scan_bytes vs the full-scan "
                         "bound it replaces — asserted in-harness)")
    ap.add_argument("--index-dtype", choices=("float32", "int8"),
                    default="int8",
                    help="index dtype for the fused bench's quantised "
                         "arm (default int8)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny worlds / few ticks for CI")
    ap.add_argument("--json", action="store_true",
                    help=f"also write every emitted row to {JSON_PATH}")
    args = ap.parse_args()
    parts = None
    if args.cross or args.arena or args.churn or args.fused or \
            args.shards or args.tiered or args.spill or args.standing:
        parts = (("cross", "plan") if args.cross else ()) + \
                (("arena",) if args.arena else ()) + \
                (("churn",) if args.churn else ()) + \
                (("fused",) if args.fused else ()) + \
                (("shards",) if args.shards else ()) + \
                (("tiered",) if args.tiered else ()) + \
                (("spill",) if args.spill else ()) + \
                (("standing",) if args.standing else ())
    run(args.sessions, args.queries, smoke=args.smoke, parts=parts,
        json_path=JSON_PATH if args.json else None,
        index_dtype=args.index_dtype)
