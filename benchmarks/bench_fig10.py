"""Paper Fig. 10 / Fig. 5: greedy Top-K vs sampling-based retrieval —
diversity and multi-region coverage at a fixed 8-frame budget."""

from __future__ import annotations

import jax
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from benchmarks.scenario import (build_scenario, coverage,
                                 per_frame_embeddings)
from repro.core import retrieval as rt


def run() -> None:
    sc = build_scenario(n_scenes=24, seed=21)
    world, oracle, system = sc.world, sc.oracle, sc.system
    # dispersed queries only (event appears in >1 scene) — Fig 10's case
    queries = [q for q in world.make_queries(24, seed=23)
               if q.dispersion > 1]
    if not queries:
        queries = world.make_queries(8, seed=23)
    budget = 8
    # greedy Top-K runs on the vanilla per-frame index (as in Fig. 5b:
    # a dense DB of near-duplicates concentrates Top-K on one region)
    ids, embs = per_frame_embeddings(world, oracle, stride=2)
    valid = jnp.ones((len(ids),), bool)
    cov_tk, cov_s, spread_tk, spread_s = [], [], [], []
    for q in queries:
        qe = oracle.embed_query(q)
        pick = np.asarray(rt.topk_retrieve(jnp.asarray(embs @ qe), valid,
                                           budget))
        tk = ids[pick]
        cov_tk.append(coverage(world, q, tk))
        spread_tk.append(len({int(world.scene_of_frame[f]) for f in tk}))
        res = system.query(q.text, budget=budget, use_akr=False,
                           query_emb=qe)
        cov_s.append(coverage(world, q, res.frame_ids))
        spread_s.append(len({int(world.scene_of_frame[f])
                             for f in res.frame_ids}))
    emit("fig10/topk", 0.0,
         {"coverage": f"{np.mean(cov_tk):.3f}",
          "scene_spread": f"{np.mean(spread_tk):.2f}"})
    emit("fig10/sampling", 0.0,
         {"coverage": f"{np.mean(cov_s):.3f}",
          "scene_spread": f"{np.mean(spread_s):.2f}"})


if __name__ == "__main__":
    run()
