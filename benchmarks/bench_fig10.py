"""Paper Fig. 10 / Fig. 5: greedy Top-K vs sampling-based retrieval —
diversity and multi-region coverage at a fixed 8-frame budget.

Also home to the accuracy harness for the hierarchical consolidation
tier: ``recall_vs_compression`` sweeps the compression ratio (ingested
history ÷ fine capacity) and measures top-k recall of the two-stage
tiered build against an unbounded-capacity oracle on the same stream —
the curve behind the "≥ 4× history at ≥ 0.8 recall" claim. The
multistream bench's ``--tiered`` arm calls it with the JSON sink
installed so the curve lands in ``BENCH_multistream.json``.
"""

from __future__ import annotations

import jax
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from benchmarks.scenario import (build_scenario, coverage,
                                 per_frame_embeddings)
from repro.core import retrieval as rt


def recall_vs_compression(ratios=(1, 2, 4, 8), *, capacity: int = 128,
                          dim: int = 32, n_clusters: int = 8,
                          budget: int = 8, seed: int = 11,
                          prefix: str = "fig10/consolidation"):
    """Top-k recall vs compression ratio for the consolidation tier.

    For each ratio r, a tiered session (``eviction="consolidate"``,
    fine capacity ``capacity``) ingests ``r × capacity`` clustered rows
    while an oracle session holds ALL of them (capacity = r × capacity,
    no eviction). Recall is cluster identity: the fraction of returned
    frames belonging to the query's cluster — the oracle scores 1.0 by
    construction on this workload. r = 1 never evicts, so its row
    anchors the curve at the flat scan's own recall; every later point
    prices what folding (r-1)× capacity of history into the summary
    tier costs. Returns {ratio: (recall, oracle_recall)}."""
    from repro.core.queryplan import QuerySpec
    from repro.core.session import SessionManager, VenusConfig

    class _DirectEmbedder:
        """Planner stub for sessions fed by direct insert_batch."""

        def embed_queries(self, texts):
            raise AssertionError("bench passes explicit embeddings")

        def embed_frames(self, frames, aux=None, frame_ids=None):
            raise AssertionError("bench inserts rows directly")

    def _unit(rows):
        rows = np.asarray(rows, np.float32)
        return rows / (np.linalg.norm(rows, axis=-1, keepdims=True)
                       + 1e-12)

    rng = np.random.default_rng(seed)
    cen = _unit(rng.normal(size=(n_clusters, dim)))

    def build(cfg, rows):
        mgr = SessionManager(cfg, _DirectEmbedder(), embed_dim=dim)
        sid = mgr.create_session()
        mem = mgr.sessions[sid].memory
        for lo in range(0, len(rows), 16):
            batch = rows[lo:lo + 16]
            fids = np.arange(lo, lo + len(batch))
            with mgr.arena.deferred_appends():
                mem.insert_batch(batch, scene_ids=[0] * len(batch),
                                 index_frames=fids,
                                 member_lists=[[int(f)] for f in fids])
        return mgr, sid

    curve = {}
    for ratio in ratios:
        total = ratio * capacity
        labels = rng.integers(0, n_clusters, size=total)
        rows = _unit(cen[labels]
                     + 0.05 * rng.normal(size=(total, dim)))
        tiered, tsid = build(
            VenusConfig(memory_capacity=capacity, member_cap=8,
                        eviction="consolidate",
                        coarse_capacity=max(capacity // 4, 8),
                        coarse_block=16, coarse_topb=4), rows)
        oracle, osid = build(
            VenusConfig(memory_capacity=total, member_cap=8), rows)
        rec, orec = [], []
        for q in range(n_clusters):
            got = tiered.execute(tiered.plan([QuerySpec(
                sid=tsid, embedding=cen[q], strategy="topk",
                budget=budget)]))[0]
            want = oracle.execute(oracle.plan([QuerySpec(
                sid=osid, embedding=cen[q], strategy="topk",
                budget=budget)]))[0]
            rec.append(np.mean(labels[got.frame_ids] == q))
            orec.append(np.mean(labels[want.frame_ids] == q))
        curve[ratio] = (float(np.mean(rec)), float(np.mean(orec)))
        emit(f"{prefix}/recall_ratio_{ratio}x", 0.0,
             {"compression_ratio": f"{ratio}x",
              "ingested_rows": total, "fine_capacity": capacity,
              "recall": f"{curve[ratio][0]:.3f}",
              "oracle_recall": f"{curve[ratio][1]:.3f}"},
             value=curve[ratio][0])
    # the paper-facing claim, asserted wherever the curve runs: ≥ 4×
    # capacity of history stays useful through the summary tier — and
    # every recorded recall is a real measurement, never 0.0 (a zero in
    # the trajectory means the harness didn't actually retrieve)
    for ratio, (rec, orec) in curve.items():
        assert orec == 1.0, (ratio, orec)       # workload sanity
        assert rec > 0.0, (ratio, curve)
        if ratio >= 4:
            assert rec >= 0.8, (ratio, curve)
    return curve


def run() -> None:
    sc = build_scenario(n_scenes=24, seed=21)
    world, oracle, system = sc.world, sc.oracle, sc.system
    # dispersed queries only (event appears in >1 scene) — Fig 10's case
    queries = [q for q in world.make_queries(24, seed=23)
               if q.dispersion > 1]
    if not queries:
        queries = world.make_queries(8, seed=23)
    budget = 8
    # greedy Top-K runs on the vanilla per-frame index (as in Fig. 5b:
    # a dense DB of near-duplicates concentrates Top-K on one region)
    ids, embs = per_frame_embeddings(world, oracle, stride=2)
    valid = jnp.ones((len(ids),), bool)
    cov_tk, cov_s, spread_tk, spread_s = [], [], [], []
    for q in queries:
        qe = oracle.embed_query(q)
        pick = np.asarray(rt.topk_retrieve(jnp.asarray(embs @ qe), valid,
                                           budget))
        tk = ids[pick]
        cov_tk.append(coverage(world, q, tk))
        spread_tk.append(len({int(world.scene_of_frame[f]) for f in tk}))
        res = system.query(q.text, budget=budget, use_akr=False,
                           query_emb=qe)
        cov_s.append(coverage(world, q, res.frame_ids))
        spread_s.append(len({int(world.scene_of_frame[f])
                             for f in res.frame_ids}))
    emit("fig10/topk", 0.0,
         {"coverage": f"{np.mean(cov_tk):.3f}",
          "scene_spread": f"{np.mean(spread_tk):.2f}"})
    emit("fig10/sampling", 0.0,
         {"coverage": f"{np.mean(cov_s):.3f}",
          "scene_spread": f"{np.mean(spread_s):.2f}"})
    recall_vs_compression()


if __name__ == "__main__":
    run()
