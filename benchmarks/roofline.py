"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod mesh:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_bw_effective

ICI_bw_effective = links_used × 50 GB/s. On a v5e 2D torus each chip has
~4 usable links; collectives on one mesh axis use 2 (bidirectional ring).
We charge 2 links (documented, conservative).

Also reports MODEL_FLOPS = 6·N·D (train; N = non-embedding params, active
for MoE) or 2·N·D (inference forward) and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundant compute).

HLO-FLOPs caveats (EXPERIMENTS.md §Roofline): metrics come from two
reduced-depth *unrolled* lowers extrapolated linearly in depth (exact for
depth-additive modules); XLA counts the RWKV time-scan body once —
undercounting its WKV flops, which are <2% of that arch's projections.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.base import get_shape
from repro.launch.mesh import HARDWARE
from repro.launch.specs import adapt_config
from repro.models.params import (count_active_params_analytic,
                                 count_params_analytic)

PEAK = HARDWARE["peak_bf16_flops"]
HBM = HARDWARE["hbm_bw"]
ICI = 2 * HARDWARE["ici_bw"]        # 2 links per chip charged

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                          "experiments", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step, global (all chips)."""
    shape = get_shape(shape_name)
    cfg = adapt_config(get_config(arch), shape)
    n_active = count_active_params_analytic(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def analyse(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_chips"]
    t_c = rec["flops_per_device"] / PEAK
    # HBM traffic estimate: every allocated byte is written+read at least
    # once (args+outputs once, temps twice). XLA's "bytes accessed" is a
    # fusion-blind per-op upper bound — reported separately as bytes_upper.
    mem = rec["memory"]
    traffic = (mem["argument_bytes"] + mem["output_bytes"]
               + 2 * mem["temp_bytes"])
    t_m = traffic / HBM
    coll = sum(rec["collective_bytes_per_device"].values())
    t_x = coll / ICI
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["flops_per_device"] * n
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dom,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": round(mf / hlo_global, 4) if hlo_global else 0.0,
        "roofline_step_s": round(max(terms.values()), 6),
        "bytes_upper_s": round(
            rec["bytes_accessed_per_device"] / HBM, 4),
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
    }


def load_all(mesh: str = "16x16") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse(rec)
        if row:
            out.append(row)
    return out


def run() -> None:
    from benchmarks.common import emit
    rows = load_all()
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}",
             r["roofline_step_s"],
             {"dominant": r["dominant"],
              "compute_s": f"{r['compute']:.4f}",
              "memory_s": f"{r['memory']:.4f}",
              "collective_s": f"{r['collective']:.4f}",
              "useful_ratio": r["useful_ratio"]})
    if not rows:
        emit("roofline/no_dryrun_artifacts", 0.0,
             {"hint": "run python -m repro.launch.dryrun --all first"})


def markdown_table(mesh: str = "16x16") -> str:
    rows = load_all(mesh)
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio |\n|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute']:.4f} | "
            f"{r['memory']:.4f} | {r['collective']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
