"""§Perf hillclimb driver: lower one (arch × shape) with the CURRENT code
and compare its roofline terms against the baseline dry-run artifact.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch minicpm3-4b \
      --shape train_4k --tag chunked_attn

Writes experiments/perf/<arch>_<shape>_<tag>.json and prints the
before/after table used in EXPERIMENTS.md §Perf.
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json

from repro.launch.dryrun import lower_combo  # noqa: E402  (sets flags)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    ap.add_argument("--no-chunked", action="store_true",
                    help="disable query-chunked causal attention (iter A)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel attention constraint (iter C)")
    ap.add_argument("--q-chunk", type=int, default=0,
                    help="override SDPA_Q_CHUNK (shard-aligned chunking)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 weights for serving shapes (iter D)")
    ap.add_argument("--zero3", action="store_true",
                    help="ZeRO-3 FSDP placement (iter F)")
    args = ap.parse_args()

    from repro.models import attention as A
    if args.zero3:
        import repro.launch.dryrun as DR
        DR.TRAIN_SHARDING_MODE = "train_zero3"
    A.CHUNKED_SDPA = not args.no_chunked
    if args.q_chunk:
        A.SDPA_Q_CHUNK = args.q_chunk
    if args.seq_parallel:
        A.set_seq_parallel_attn((("data",), "model"))
    if args.serve_bf16:
        import repro.launch.dryrun as DR
        from repro.launch.specs import adapt_config as _ac
        import repro.launch.specs as SP
        _orig = SP.adapt_config
        def patched(cfg, shape):
            cfg = _orig(cfg, shape)
            if shape.kind in ("decode", "prefill"):
                cfg = cfg.replace(param_dtype="bfloat16")
            return cfg
        SP.adapt_config = patched
        DR.adapt_config = patched

    res = lower_combo(args.arch, args.shape, multi_pod=False,
                      verbose=False)
    os.makedirs("experiments/perf", exist_ok=True)
    out = f"experiments/perf/{args.arch}_{args.shape}_{args.tag}.json"
    with open(out, "w") as f:
        json.dump(res, f, indent=1)

    base_path = os.path.join(args.baseline_dir,
                             f"{args.arch}_{args.shape}_16x16.json")
    from benchmarks.roofline import analyse
    new = analyse(res)
    print(f"== {args.arch} × {args.shape} [{args.tag}] ==")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = analyse(json.load(f))
        for k in ("compute", "memory", "collective", "useful_ratio"):
            b, n = base[k], new[k]
            delta = (n - b) / b * 100 if b else float("nan")
            print(f"  {k:12s} {b:12.4f} -> {n:12.4f}  ({delta:+.1f}%)")
        print(f"  dominant     {base['dominant']} -> {new['dominant']}")
    else:
        print(json.dumps(new, indent=1))


if __name__ == "__main__":
    main()
