"""Paper Fig. 11: AKR ablation — adaptive budget vs fixed 32/64.

Reports mean frames selected, coverage, and the modeled inference+comm
cost reduction, overall and on a narrow-query subset (the paper's curated
60-query Video-MME subset analogue: queries whose event lives in exactly
one scene)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from benchmarks.scenario import build_scenario, coverage
from repro.core.costmodel import CloudVLMModel, FrameFormat, LinkModel


def _cost_s(n_frames: int) -> float:
    link, vlm, fmt = LinkModel(), CloudVLMModel(), FrameFormat()
    return (link.transfer_s(n_frames * fmt.bytes_per_frame_jpeg)
            + vlm.infer_s(n_frames))


def run() -> None:
    sc = build_scenario(n_scenes=24, seed=31)
    world, oracle, system = sc.world, sc.oracle, sc.system
    queries = world.make_queries(20, seed=33)
    narrow = [q for q in queries if q.dispersion == 1]

    for subset, qs in (("all", queries), ("narrow_subset", narrow)):
        rows = {}
        for mode in ("fixed64", "fixed32", "akr"):
            covs, nsel = [], []
            for q in qs:
                qe = oracle.embed_query(q)
                if mode == "akr":
                    res = system.query(q.text, query_emb=qe)
                    n = len(res.frame_ids)
                else:
                    budget = 64 if mode == "fixed64" else 32
                    res = system.query(q.text, budget=budget,
                                       use_akr=False, query_emb=qe)
                    n = len(res.frame_ids)
                covs.append(coverage(world, q, res.frame_ids))
                nsel.append(n)
            rows[mode] = (np.mean(covs), np.mean(nsel),
                          _cost_s(int(np.mean(nsel))))
        base64 = rows["fixed64"][2]
        base32 = rows["fixed32"][2]
        for mode, (cov, n, cost) in rows.items():
            emit(f"fig11/{subset}/{mode}", cost,
                 {"coverage": f"{cov:.3f}", "mean_frames": f"{n:.1f}",
                  "cost_s": f"{cost:.2f}",
                  "reduction_vs64": f"{base64 / cost:.1f}x",
                  "reduction_vs32": f"{base32 / cost:.1f}x"})


if __name__ == "__main__":
    run()
