"""Paper Fig. 4: embedding latency vs FPS — real-time ingestion.

The paper shows frame-wise MEM embedding cannot keep up with camera FPS
on edge devices (≤1.8 FPS on AGX Orin), while Venus only embeds sparse
cluster centroids. We measure, on this host: (a) the per-frame cost of
the frame-wise baseline (embed every frame), (b) Venus's per-frame
ingestion cost (scene seg + clustering + centroid-only embedding), and
derive the maximum sustainable FPS of each and the embedded fraction."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.venus_mem import small_config
from repro.core.pipeline import MEMEmbedder, VenusConfig, VenusSystem
from repro.data.video import VideoWorld, WorldConfig
from repro.models.mem import MEM


def run() -> None:
    """Uses the REAL MEM model (not the oracle): the paper's Fig. 4 point
    is that transformer embedding dominates per-frame cost."""
    world = VideoWorld(WorldConfig(n_scenes=6, seed=5))
    t = world.total_frames
    mem_cfg = small_config()
    mem = MEM(mem_cfg)
    params = mem.init(jax.random.key(0))
    embedder = MEMEmbedder(mem, params)

    # (a) frame-wise baseline: MEM-embed EVERY frame (batched by 32)
    embedder.embed_frames(world.frames[:8])      # warm up / compile
    t0 = time.perf_counter()
    for i in range(0, min(t, 64), 32):
        embedder.embed_frames(world.frames[i:i + 32])
    per_frame_baseline = (time.perf_counter() - t0) / min(t, 64)

    # (b) Venus ingestion: scene seg + clustering + centroid-only embeds
    system = VenusSystem(VenusConfig(), embedder,
                         embed_dim=mem_cfg.embed_dim)
    t0 = time.perf_counter()
    for i in range(0, t, 64):
        system.ingest(world.frames[i:i + 64])
    system.flush()
    per_frame_venus = (time.perf_counter() - t0) / t
    frac = system.stats["frames_embedded"] / t

    emit("fig4/framewise_baseline", per_frame_baseline,
         {"max_fps": f"{1.0 / max(per_frame_baseline, 1e-9):.1f}"})
    emit("fig4/venus_ingest", per_frame_venus,
         {"max_fps": f"{1.0 / max(per_frame_venus, 1e-9):.1f}",
          "embedded_fraction": f"{frac:.3f}",
          "speedup": f"{per_frame_baseline / per_frame_venus:.1f}x",
          "partitions": system.stats["partitions"],
          "clusters": system.stats["clusters"]})


if __name__ == "__main__":
    run()
