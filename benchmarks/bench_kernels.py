"""Kernel microbenchmarks: jnp reference path timed on this host (the
Pallas path targets TPU; interpret mode is not a performance proxy, so we
time the XLA-compiled reference and report shapes + bytes touched)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ops


def run() -> None:
    key = jax.random.key(0)
    ks = jax.random.split(key, 8)

    b, h, hkv, d, c = 4, 32, 8, 128, 8192
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, c, hkv, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, c, hkv, d), jnp.bfloat16)
    valid = jnp.ones((b, c), bool)
    jf = jax.jit(lambda: ops.decode_attention(
        q, k, v, valid, scale=d ** -0.5, q_per_kv=h // hkv))
    s = time_call(lambda: jf().block_until_ready())
    emit("kernels/decode_attention_8k", s,
         {"kv_bytes": 2 * b * c * hkv * d * 2})

    n, dim = 8192, 768
    query = jax.random.normal(ks[3], (1, dim))
    index = jax.random.normal(ks[4], (n, dim))
    vmask = jnp.ones((n,), bool)
    jf = jax.jit(lambda: ops.similarity(query, index, tau=0.07,
                                        valid=vmask)[1])
    s = time_call(lambda: jf().block_until_ready())
    emit("kernels/similarity_8k", s, {"index_mb": n * dim * 4 / 1e6})

    frames = jax.random.uniform(ks[5], (32, 224, 224, 3))
    jf = jax.jit(lambda: ops.scene_score(frames, (1.0, 1.0, 1.0, 2.0)))
    s = time_call(lambda: jf().block_until_ready())
    emit("kernels/scene_score_224", s,
         {"per_frame_us": f"{s / 32 * 1e6:.1f}"})


if __name__ == "__main__":
    run()
