"""Paper Fig. 12: end-to-end query latency breakdown per processing step.

Venus steps (measured on this host + modeled comm/cloud): query embed,
similarity, sampling, expand, upload, VLM. Vanilla steps include the
query-time embedding backlog (frames not yet embedded when the query
arrives)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.scenario import build_scenario, per_frame_embeddings
from repro.core.costmodel import venus_query_latency


def run() -> None:
    sc = build_scenario(n_scenes=10, seed=41)
    world, oracle, system = sc.world, sc.oracle, sc.system
    queries = world.make_queries(8, seed=43)

    agg = {}
    n_up = []
    for q in queries:
        qe = oracle.embed_query(q)
        res = system.query(q.text, query_emb=qe)
        b = venus_query_latency(measured_edge_s=res.timings,
                                n_frames_uploaded=len(res.frame_ids))
        n_up.append(len(res.frame_ids))
        for k, v in b.parts.items():
            agg.setdefault(k, []).append(v)
    for k, v in agg.items():
        emit(f"fig12/venus/{k}", float(np.mean(v)))
    emit("fig12/venus/total", float(np.sum([np.mean(v)
                                            for v in agg.values()])),
         {"frames_uploaded": f"{np.mean(n_up):.1f}"})

    # vanilla: embedding backlog at query time (10% of stream pending)
    t0 = time.perf_counter()
    per_frame_embeddings(world, oracle, stride=10)
    backlog_s = time.perf_counter() - t0
    emit("fig12/vanilla/embed_backlog", backlog_s)


if __name__ == "__main__":
    run()
