"""Beyond-paper ablation: AKR sensitivity to τ (temperature) and θ
(mass threshold) — the paper fixes τ and θ; we sweep them to map the
relevance/diversity/cost frontier."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from benchmarks.scenario import build_scenario, coverage
from repro.core.pipeline import VenusConfig


def run() -> None:
    base = build_scenario(n_scenes=10, seed=51)
    world, oracle = base.world, base.oracle
    queries = world.make_queries(12, seed=53)

    for tau in (0.03, 0.07, 0.15):
        for theta in (0.7, 0.9):
            sys_ = base.system
            sys_.cfg = VenusConfig(tau=tau, theta=theta)
            covs, nsel = [], []
            for q in queries:
                qe = oracle.embed_query(q)
                res = sys_.query(q.text, query_emb=qe)
                covs.append(coverage(world, q, res.frame_ids))
                nsel.append(res.n_drawn)
            emit(f"akr_scaling/tau{tau}_theta{theta}", 0.0,
                 {"coverage": f"{np.mean(covs):.3f}",
                  "mean_draws": f"{np.mean(nsel):.1f}"})


if __name__ == "__main__":
    run()
