"""Shared benchmark utilities: timing + CSV emission + JSON recording.

Every benchmark prints ``name,us_per_call,derived`` rows (harness
contract) — ``derived`` carries the benchmark's headline metric
(accuracy, coverage, speedup, ...) as ``key=value|key=value``. A
benchmark that wants a machine-readable artifact installs a sink with
``set_sink([])``: every subsequent ``emit`` row is also appended to the
sink as a dict, ready to ``json.dump`` (see
``bench_multistream.py --json`` → ``BENCH_multistream.json``).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional


def time_call(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


_SINK: Optional[List[dict]] = None


def set_sink(sink: Optional[List[dict]]) -> None:
    """Install (or clear, with None) a list that records every emitted
    row as ``{"name", "seconds", "derived"}`` for JSON artifacts."""
    global _SINK
    _SINK = sink


def emit(name: str, seconds: float, derived: Dict | None = None, *,
         value: Optional[float] = None) -> None:
    """Record one bench row. ``value`` is the row's headline scalar for
    trajectory tracking when the row isn't a timing (a speedup, a
    reduction factor, a recall) — without it, a metric row emitted with
    ``seconds=0.0`` would land in the cross-run trajectory as a
    meaningless 0.0 (see ``write_json_artifact``)."""
    d = "|".join(f"{k}={v}" for k, v in (derived or {}).items())
    if _SINK is not None:
        row = {"name": name, "seconds": seconds,
               "derived": dict(derived or {})}
        if value is not None:
            row["value"] = float(value)
        _SINK.append(row)
    print(f"{name},{seconds * 1e6:.1f},{d}")
