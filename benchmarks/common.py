"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (harness
contract) — ``derived`` carries the benchmark's headline metric
(accuracy, coverage, speedup, ...) as ``key=value|key=value``.
"""

from __future__ import annotations

import time
from typing import Callable, Dict


def time_call(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn() in seconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: Dict | None = None) -> None:
    d = "|".join(f"{k}={v}" for k, v in (derived or {}).items())
    print(f"{name},{seconds * 1e6:.1f},{d}")
