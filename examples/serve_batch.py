"""Serve a small model with batched requests (end-to-end driver).

Runs the paper's step ⑦ as a real serving workload: the continuous-
batching engine hosts the (reduced) Qwen2-VL backbone — the paper's own
cloud VLM — behind ``VenusService``. Each request is a ``StreamQuery``
(any registered retrieval strategy); one service tick compiles ALL of
them into ONE query plan, the planner fuses compatible specs into
execution groups (one similarity scan each), and the retrieved keyframes
become the VLM's vision inputs (patch-embedding stubs).

Each scan's operand is the session manager's grow-in-place
``MemoryArena``: ingestion appended the index rows into shared device
super-buffers, so querying consumes them as-is — no device-side restack
of session memory ever sits between a request and its answer (the
driver prints the service's ``stack_rebuilds`` counter, which must read
0; PR 2's version-cached per-query-group stack rebuild is gone).

  PYTHONPATH=src python examples/serve_batch.py --requests 6
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.pipeline import VenusConfig, VenusSystem
from repro.data.video import OracleEmbedder, VideoWorld, WorldConfig
from repro.models.transformer import Transformer
from repro.serving.engine import ServingEngine
from repro.serving.venus_service import StreamQuery, VenusService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    # --- edge side: Venus ingests the stream ------------------------------
    # a deployment-shaped config: sliding-window eviction means this
    # stream's device index stays bounded however long it runs — past
    # memory_capacity it keeps ingesting and answers from its newest
    # rows (ring memory, O(1) eviction; the raw-frame archive is the
    # paper's append-only NVMe layer)
    world = VideoWorld(WorldConfig(n_scenes=10, seed=4))
    oracle = OracleEmbedder(world, dim=64)
    venus = VenusSystem(VenusConfig(eviction="sliding_window"),
                        oracle, embed_dim=64)
    for i in range(0, world.total_frames, 64):
        venus.ingest(world.frames[i:i + 64])
    venus.flush()

    # --- cloud side: smoke Qwen2-VL behind the serving engine -------------
    cfg = get_smoke_config("qwen2-vl-7b")
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=args.slots, max_len=512)
    svc = VenusService(venus.manager, eng, max_frames=4)

    # one StreamQuery per request; alternate AKR with the greedy Top-K
    # baseline so the tick's plan has a real strategy mix to fuse
    rng = np.random.default_rng(0)
    queries = []
    for i, q in enumerate(world.make_queries(args.requests, seed=7)):
        strategy, budget = (("akr", None) if i % 2 == 0 else ("topk", 4))
        queries.append(StreamQuery(
            rid=i, sid=venus.sid, text=q.text,
            prompt_tokens=rng.integers(3, cfg.vocab_size, size=24),
            query_emb=oracle.embed_query(q),
            strategy=strategy, budget=budget,
            max_new_tokens=args.max_new))

    plan = svc.plan(queries)
    print(plan.describe())

    t0 = time.perf_counter()
    done = svc.answer(queries)
    wall = time.perf_counter() - t0
    tok = sum(len(r.generated) for r in done)
    for r in done:
        print(f"req {r.rid}: {len(r.generated)} tokens, "
              f"ttft {(r.first_token_at - r.submitted_at) * 1e3:.0f} ms")
    stats = svc.io_stats()
    print(f"[serve_batch] {tok} tokens / {wall:.2f}s "
          f"= {tok / wall:.1f} tok/s with continuous batching; "
          f"{plan.n_scans} scans for {len(queries)} requests; "
          f"{stats['stack_rebuilds']} stack rebuilds (arena: appends "
          f"in place)")

    # --- lifecycle: the stream ends; its arena slot is recycled -----------
    final = svc.close_stream(venus.sid)
    replacement = svc.create_stream()     # reuses the freed slot
    stats = svc.io_stats()
    print(f"[serve_batch] closed stream after {final['frames_seen']} "
          f"frames; slot recycled for stream {replacement} "
          f"(releases={stats['arena_slot_releases']}, "
          f"reuses={stats['arena_slot_reuses']}, "
          f"grows={stats['arena_grows']} — no growth on churn)")


if __name__ == "__main__":
    main()
