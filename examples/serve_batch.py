"""Serve a small model with batched requests (end-to-end driver).

Runs the paper's step ⑦ as a real serving workload: the continuous-
batching engine hosts the (reduced) Qwen2-VL backbone — the paper's own
cloud VLM — and answers a stream of requests whose "vision" inputs are
the keyframes Venus selected (patch-embedding stubs).

  PYTHONPATH=src python examples/serve_batch.py --requests 6
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.pipeline import VenusConfig, VenusSystem, patchify
from repro.data.video import OracleEmbedder, VideoWorld, WorldConfig
from repro.models.transformer import Transformer
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    # --- edge side: Venus picks keyframes ---------------------------------
    world = VideoWorld(WorldConfig(n_scenes=10, seed=4))
    oracle = OracleEmbedder(world, dim=64)
    venus = VenusSystem(VenusConfig(), oracle, embed_dim=64)
    for i in range(0, world.total_frames, 64):
        venus.ingest(world.frames[i:i + 64])
    venus.flush()

    # --- cloud side: smoke Qwen2-VL behind the serving engine -------------
    cfg = get_smoke_config("qwen2-vl-7b")
    model = Transformer(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(cfg, params, batch_slots=args.slots, max_len=512)

    rng = np.random.default_rng(0)
    queries = world.make_queries(args.requests, seed=7)
    reqs = []
    for i, q in enumerate(queries):
        res = venus.query(q.text, query_emb=oracle.embed_query(q))
        frames = world.frames[res.frame_ids[:4]] if len(res.frame_ids) \
            else world.frames[:1]
        # vision stub: patchify selected keyframes into the VLM's
        # embedding space, truncated to the config's token budget
        pe = np.asarray(patchify(frames, 8, cfg.d_model))
        pe = pe.reshape(-1, cfg.d_model)[: cfg.vision_tokens]
        if pe.shape[0] < cfg.vision_tokens:
            pe = np.pad(pe, ((0, cfg.vision_tokens - pe.shape[0]), (0, 0)))
        reqs.append(Request(
            rid=i,
            tokens=rng.integers(3, cfg.vocab_size, size=24),
            max_new_tokens=args.max_new,
            vision_embeds=pe.astype(np.float32)))

    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    tok = sum(len(r.generated) for r in done)
    for r in done:
        print(f"req {r.rid}: {len(r.generated)} tokens, "
              f"ttft {(r.first_token_at - r.submitted_at) * 1e3:.0f} ms")
    print(f"[serve_batch] {tok} tokens / {wall:.2f}s "
          f"= {tok / wall:.1f} tok/s with continuous batching")


if __name__ == "__main__":
    main()
