"""End-to-end online video QA: queries arrive DURING the stream.

Simulates the paper's deployment: the camera streams continuously;
queries land at arbitrary timestamps and can only use what has been
ingested so far. Reports per-query response latency decomposed like the
paper's Fig. 12 (measured edge compute + modeled upload/VLM terms) and
answer coverage against ground truth.

  PYTHONPATH=src python examples/online_video_qa.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.costmodel import venus_query_latency
from repro.core.pipeline import VenusConfig, VenusSystem
from repro.data.video import OracleEmbedder, VideoWorld, WorldConfig


def main() -> None:
    world = VideoWorld(WorldConfig(n_scenes=12, seed=11))
    oracle = OracleEmbedder(world, dim=64)
    system = VenusSystem(VenusConfig(), oracle, embed_dim=64)

    chunk = 25                       # 1 "second" of 25 FPS video
    query_times = {8: 0, 20: 1, 35: 2}   # second -> query id
    queries = world.make_queries(3, seed=5)

    for sec, i in enumerate(range(0, world.total_frames, chunk)):
        system.ingest(world.frames[i:i + chunk])
        if sec in query_times:
            q = queries[query_times[sec]]
            res = system.query(q.text, query_emb=oracle.embed_query(q))
            lat = venus_query_latency(
                measured_edge_s=res.timings,
                n_frames_uploaded=len(res.frame_ids))
            seen = {int(world.scene_of_frame[f]) for f in res.frame_ids}
            rel = [s for s in q.relevant_scenes
                   if world.scenes[s].end <= (i + chunk)]
            cov = (len(set(rel) & seen) / len(rel)) if rel else float("nan")
            print(f"t={sec:3d}s  query '{q.text}'")
            print(f"   -> {len(res.frame_ids)} frames "
                  f"(AKR drew {res.n_drawn}), coverage so far: {cov:.2f}")
            print(f"   -> {lat}")
    system.flush()
    print(f"\nfinal memory: {system.memory.size} indexed vectors for "
          f"{world.total_frames} frames")


if __name__ == "__main__":
    main()
