"""Train the Venus MEM (dual-tower multimodal embedder) contrastively.

The end-to-end training driver: SigLIP pairwise loss over synthetic
(frame, caption) pairs from the procedural world, AdamW + cosine
schedule, checkpointing. Default runs the ~smoke MEM for speed; pass
``--model small`` for the ~100M-class tower (a few hundred steps on a
real accelerator; on this CPU host budget a few seconds/step).

  PYTHONPATH=src python examples/train_mem.py --steps 60
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import venus_mem
from repro.core.pipeline import patchify
from repro.data.text import tokenize_batch
from repro.data.video import VideoWorld, WorldConfig
from repro.models.mem import MEM
from repro.training import (TrainHParams, adamw_init, make_mem_train_step)
from repro.training import checkpoint as ckpt


def make_batch(world, rng, batch, mem_cfg):
    """Distinct-scene (frame, caption) pairs for the pairwise loss."""
    scenes = rng.choice(len(world.scenes), size=batch, replace=False)
    frames, texts = [], []
    for s in scenes:
        sc = world.scenes[s]
        f = int(rng.integers(sc.w_start, sc.w_end))     # evidence frame
        frames.append(world.frames[f])
        texts.append(f"{sc.text} {' '.join(sc.objects)}")
    patches = patchify(np.stack(frames), 8, mem_cfg.vision.d_model)
    toks, mask = tokenize_batch(texts, mem_cfg.text.vocab_size, 16)
    return {"patches": patches, "tokens": jnp.asarray(toks),
            "mask": jnp.asarray(mask)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--model", choices=["smoke", "small", "large"],
                    default="smoke")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    mem_cfg = {"smoke": venus_mem.smoke_config,
               "small": venus_mem.small_config,
               "large": venus_mem.config}[args.model]()
    world = VideoWorld(WorldConfig(n_scenes=16, seed=2))
    mem = MEM(mem_cfg)
    params = mem.init(jax.random.key(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_mem_train_step(mem, TrainHParams(
        base_lr=3e-4, warmup=max(args.steps // 10, 1),
        total_steps=args.steps, remat=False)))

    rng = np.random.default_rng(0)
    for i in range(args.steps):
        batch = make_batch(world, rng, args.batch, mem_cfg)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch, jnp.asarray(i))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['contrastive_acc']):.3f} "
                  f"({time.perf_counter() - t0:.2f}s)")
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params},
                  {"model": mem_cfg.name, "steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
