"""Quickstart: the full Venus loop in ~60 seconds on CPU.

Streams a procedural video into the Venus ingestion pipeline (scene
segmentation → clustering → MEM embedding → hierarchical memory), then
answers natural-language queries through the declarative query-plan API:
every query is a ``QuerySpec`` (here AKR vs greedy Top-K per question),
the planner fuses compatible specs into execution groups, and ONE
similarity scan per group answers everything.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

import jax

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.configs.venus_mem import smoke_config
from repro.core.aux_models import DetectorStub, OCRStub
from repro.core.pipeline import MEMEmbedder, QuerySpec, VenusConfig, \
    VenusSystem, patchify
from repro.data.text import tokenize_batch
from repro.data.video import VideoWorld, WorldConfig
from repro.models.mem import MEM
from repro.training import TrainHParams, adamw_init, make_mem_train_step


def _pretrain_mem(mem, mem_cfg, world, steps=80, batch=8):
    params = mem.init(jax.random.key(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_mem_train_step(mem, TrainHParams(
        base_lr=1e-3, warmup=5, total_steps=steps, remat=False)))
    rng = np.random.default_rng(0)
    acc = 0.0
    for i in range(steps):
        scenes = rng.choice(len(world.scenes), size=batch, replace=False)
        frames, texts = [], []
        for s in scenes:
            sc = world.scenes[s]
            f = int(rng.integers(sc.w_start, sc.w_end))
            frames.append(world.frames[f])
            texts.append(f"find {sc.text} {' '.join(sc.objects)}")
        patches = patchify(np.stack(frames), 8, mem_cfg.vision.d_model)
        toks, mask = tokenize_batch(texts, mem_cfg.text.vocab_size, 16)
        b = {"patches": patches, "tokens": jnp.asarray(toks),
             "mask": jnp.asarray(mask)}
        params, opt, m = step_fn(params, opt, b, jnp.asarray(i))
        acc = float(m["contrastive_acc"])
    print(f"MEM pretrained {steps} steps; contrastive acc {acc:.2f}")
    return params


def main() -> None:
    # 1. a synthetic camera: 8 scenes with ground-truth events
    world = VideoWorld(WorldConfig(n_scenes=8, seed=42))
    print(f"stream: {world.total_frames} frames, {len(world.scenes)} "
          f"scenes, events "
          f"{[s.event for s in world.scenes]}")

    # 2. a tiny MEM, briefly trained contrastively on (frame, caption)
    #    pairs so the joint embedding space is meaningful
    mem_cfg = smoke_config()
    mem = MEM(mem_cfg)
    params = _pretrain_mem(mem, mem_cfg, world, steps=80)
    embedder = MEMEmbedder(mem, params)
    system = VenusSystem(
        VenusConfig(), embedder, embed_dim=mem_cfg.embed_dim,
        aux_models=[OCRStub(), DetectorStub()],
        annotation_fn=world.annotations)

    # 3. ingestion stage: stream chunks like a camera would deliver them
    for i in range(0, world.total_frames, 50):
        system.ingest(world.frames[i:i + 50])
    system.flush()
    s = system.stats
    print(f"ingested: {s['partitions']} partitions, {s['clusters']} "
          f"clusters; embedded only {s['frames_embedded']}/"
          f"{s['frames_seen']} frames "
          f"({100 * s['frames_embedded'] / s['frames_seen']:.1f}%)")

    # 4. querying stage: ONE declarative plan answers every question
    #    twice — Venus AKR (adaptive budget) vs the greedy Top-K
    #    baseline — fused into two execution groups (one scan each)
    queries = world.make_queries(3, seed=1)
    specs = [QuerySpec(sid=0, text=q.text, strategy="akr")
             for q in queries]
    specs += [QuerySpec(sid=0, text=q.text, strategy="topk", budget=8)
              for q in queries]
    plan = system.plan(specs)
    print("\n" + plan.describe())
    results = system.execute(plan)
    for i, q in enumerate(queries):
        res, topk = results[i], results[len(queries) + i]
        scenes = sorted({int(world.scene_of_frame[f])
                         for f in res.frame_ids})
        tk_scenes = sorted({int(world.scene_of_frame[f])
                            for f in topk.frame_ids})
        print(f"\nquery: '{q.text}' (relevant scenes "
              f"{q.relevant_scenes})")
        print(f"  venus/AKR: {res.n_drawn} draws -> "
              f"{len(res.frame_ids)} frames from scenes {scenes} "
              f"(mass {res.mass:.2f})")
        print(f"  top-k:     8 frames from scenes {tk_scenes}")
        print(f"  timings: " + ", ".join(
            f"{k}={v * 1e3:.1f}ms" for k, v in res.timings.items()))


if __name__ == "__main__":
    main()
